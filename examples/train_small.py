"""End-to-end training driver: train a small LM for a few hundred steps
with the full substrate — AdamW, cosine schedule, microbatching,
checkpoint/auto-resume (kill it mid-run and re-launch: it continues).

  PYTHONPATH=src python examples/train_small.py --steps 200
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="tiny-lm")
    ap.add_argument("--batch", type=int, default=12)
    ap.add_argument("--seq", type=int, default=192)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="artifacts/train_small")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data import batches, token_stream
    from repro.train.optimizer import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch).replace(dtype="float32", remat="none")
    toks = token_stream("wiki", 400_000)
    data = batches(toks, args.batch, args.seq, seed=0)
    tr = Trainer(
        cfg,
        TrainerConfig(steps=args.steps, ckpt_every=50,
                      ckpt_dir=args.ckpt_dir, log_every=10, warmup=20,
                      microbatches=args.microbatches,
                      opt=AdamWConfig(lr=1.5e-3, weight_decay=0.01,
                                      master_fp32=False)),
        data, dtype="float32")
    out = tr.run()
    print(f"done: {out}")


if __name__ == "__main__":
    main()
