"""Quickstart: train a tiny LM, quantize it with GPTQT (the paper's
two-step method) and its baselines, compare perplexity.

  PYTHONPATH=src python examples/quickstart.py [--steps 300]
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--bits", type=int, default=3)
    args = ap.parse_args()

    from benchmarks.common import calib_batches_for, eval_ppl
    from repro.core import quantize_model
    from repro.data.pretrained import get_trained_lm
    from repro.quant import QuantSpec

    cfg, params = get_trained_lm("tiny-lm", steps=args.steps)
    base = eval_ppl(cfg, params, "wiki")
    print(f"\nfp32 baseline ppl: {base:.3f}\n")
    calib = calib_batches_for("wiki")

    print(f"{'method':12s} {'w-bits':>6s} {'ppl':>10s}")
    for method in ("rtn", "bcq", "gptq", "gptqt"):
        spec = QuantSpec.from_config(cfg.quant, method=method,
                                     bits=args.bits)
        qp, rep = quantize_model(cfg, params, calib, spec=spec)
        ppl = eval_ppl(cfg, qp, "wiki")
        print(f"{method:12s} {args.bits:6d} {ppl:10.3f}")
    print("\nGPTQT should track GPTQ or better; BCQ/RTN degrade most "
          "(paper Tab. I ordering).")


if __name__ == "__main__":
    main()
