"""End-to-end driver: quantize a trained LM to packed 3-bit GPTQT binary
coding and serve batched requests through the continuous-batching engine
(the paper's deployment mode — weight-only quantized decode).

  PYTHONPATH=src:. python examples/serve_quantized.py

Multi-device quickstart (`--sharded`): the same flow over a 2-way data
mesh faked on CPU — quantize, save the packed artifact, load it back
*directly onto the mesh* (the v3 manifest carries per-leaf
PartitionSpecs), and serve with the paged KV pool partitioned into one
page-pool shard per data-axis device. Greedy outputs are checked
token-for-token against the single-device engine.

  PYTHONPATH=src:. python examples/serve_quantized.py --sharded
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

if "--sharded" in sys.argv:
    # must precede the first jax import: fake two host devices
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2"
                               ).strip()


def main():
    import jax
    from benchmarks.common import calib_batches_for
    from repro.core import quantize_model
    from repro.data import ByteTokenizer
    from repro.data.pretrained import get_trained_lm
    from repro.quant import QuantSpec, QuantizedTensor
    from repro.serve import Request, ServeEngine

    cfg, params = get_trained_lm("tiny-lm")
    tok = ByteTokenizer()

    print("quantizing to packed 3-bit GPTQT binary coding ...")
    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed")
    qparams, _ = quantize_model(cfg, params, calib_batches_for("wiki"),
                                spec=spec)

    def tree_bytes(t):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))

    print(f"dense params:  {tree_bytes(params)/1e6:8.2f} MB (fp32)")
    print(f"packed params: {tree_bytes(qparams)/1e6:8.2f} MB "
          f"(GPTQT w3 binary coding)")

    prompts = [
        "the ancient city", "a famous museum", "this railway connected",
        "the council governed", "another region", "the early dynasty",
    ]
    reqs = [Request(prompt=tok.encode(p), max_new_tokens=24)
            for p in prompts]

    for label, ps, kw in (("dense", params, {}),
                          ("gptqt-w3", qparams, {}),
                          ("gptqt-w3+paged", qparams,
                           dict(cache_kind="paged", page_size=32))):
        eng = ServeEngine(cfg, ps, batch_size=3, max_len=128,
                          dtype="float32", **kw)
        t0 = time.time()
        done = eng.run([Request(prompt=r.prompt.copy(),
                                max_new_tokens=r.max_new_tokens)
                        for r in reqs])
        dt = time.time() - t0
        tput = eng.stats["tokens"] / max(eng.stats["decode_s"], 1e-9)
        print(f"\n[{label}] {eng.stats['tokens']} tokens in {dt:.2f}s "
              f"(decode throughput {tput:.1f} tok/s on CPU, "
              f"ttft {eng.stats['ttft_avg_s']:.3f}s)")
        for r, p in list(zip(done, prompts))[:3]:
            print(f"  '{p}' -> '{tok.decode(r.out)}'")

    if "--sharded" in sys.argv:
        sharded_quickstart(cfg, qparams, reqs, tok, prompts)


def sharded_quickstart(cfg, qparams, reqs, tok, prompts):
    """Serve the packed model over a 2-way data mesh: save the packed
    artifact, load it straight onto the mesh, shard the paged pool, and
    check greedy outputs against the single-device paged engine."""
    import tempfile

    import jax
    from repro.ckpt.packed import save_packed, load_packed
    from repro.launch.mesh import make_serve_mesh
    from repro.quant import QuantSpec
    from repro.serve import Request, ServeEngine

    assert len(jax.devices()) >= 2, "run with --sharded from the start"
    mesh = make_serve_mesh(data=2, model=1)
    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed")
    art = tempfile.mkdtemp() + "/packed-w3"
    save_packed(art, qparams, spec=spec, meta={"arch": cfg.name})
    # per-leaf placement from the manifest's PartitionSpecs: no
    # host-side full-tree materialization, no re-quantization
    mparams, _, _ = load_packed(art, mesh=mesh)

    def run(params, mesh=None):
        # batch_size splits evenly over the data shards (2 here)
        eng = ServeEngine(cfg, params, batch_size=4, max_len=128,
                          dtype="float32", cache_kind="paged",
                          page_size=32, mesh=mesh)
        done = eng.run([Request(prompt=r.prompt.copy(),
                                max_new_tokens=r.max_new_tokens)
                        for r in reqs])
        return [r.out for r in done], eng

    want, _ = run(qparams)
    got, eng = run(mparams, mesh)
    kv = eng.kv
    print(f"\n[sharded 2x1] page pool: {kv.n_shards} shards x "
          f"{kv.pages_per_shard} pages each "
          f"({kv.usable_in_shard(0) * kv.page_size} tokens/shard); "
          f"outputs match single-device: {got == want}")
    for out, p in list(zip(got, prompts))[:2]:
        print(f"  '{p}' -> '{tok.decode(out)}'")
    assert got == want, "sharded decode must be token-identical"


if __name__ == "__main__":
    main()
