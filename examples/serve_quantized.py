"""End-to-end driver: quantize a trained LM to packed 3-bit GPTQT binary
coding and serve batched requests through the continuous-batching engine
(the paper's deployment mode — weight-only quantized decode).

  PYTHONPATH=src:. python examples/serve_quantized.py
"""
from __future__ import annotations

import time

import numpy as np


def main():
    import jax
    from benchmarks.common import calib_batches_for
    from repro.core import quantize_model
    from repro.data import ByteTokenizer
    from repro.data.pretrained import get_trained_lm
    from repro.quant import QuantSpec, QuantizedTensor
    from repro.serve import Request, ServeEngine

    cfg, params = get_trained_lm("tiny-lm")
    tok = ByteTokenizer()

    print("quantizing to packed 3-bit GPTQT binary coding ...")
    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed")
    qparams, _ = quantize_model(cfg, params, calib_batches_for("wiki"),
                                spec=spec)

    def tree_bytes(t):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))

    print(f"dense params:  {tree_bytes(params)/1e6:8.2f} MB (fp32)")
    print(f"packed params: {tree_bytes(qparams)/1e6:8.2f} MB "
          f"(GPTQT w3 binary coding)")

    prompts = [
        "the ancient city", "a famous museum", "this railway connected",
        "the council governed", "another region", "the early dynasty",
    ]
    reqs = [Request(prompt=tok.encode(p), max_new_tokens=24)
            for p in prompts]

    for label, ps, kw in (("dense", params, {}),
                          ("gptqt-w3", qparams, {}),
                          ("gptqt-w3+paged", qparams,
                           dict(cache_kind="paged", page_size=32))):
        eng = ServeEngine(cfg, ps, batch_size=3, max_len=128,
                          dtype="float32", **kw)
        t0 = time.time()
        done = eng.run([Request(prompt=r.prompt.copy(),
                                max_new_tokens=r.max_new_tokens)
                        for r in reqs])
        dt = time.time() - t0
        tput = eng.stats["tokens"] / max(eng.stats["decode_s"], 1e-9)
        print(f"\n[{label}] {eng.stats['tokens']} tokens in {dt:.2f}s "
              f"(decode throughput {tput:.1f} tok/s on CPU, "
              f"ttft {eng.stats['ttft_avg_s']:.3f}s)")
        for r, p in list(zip(done, prompts))[:3]:
            print(f"  '{p}' -> '{tok.decode(r.out)}'")


if __name__ == "__main__":
    main()
