"""Ablation sweeps on a trained tiny LM: intermediate bit-width (Fig. 4)
and re-exploration range (Tab. VI).

  PYTHONPATH=src python examples/quantize_sweep.py
"""
from __future__ import annotations


def main():
    from benchmarks.common import eval_ppl, quantized_ppl
    from repro.data.pretrained import get_trained_lm

    cfg, params = get_trained_lm("tiny-lm")
    print(f"fp32 ppl: {eval_ppl(cfg, params, 'wiki'):.3f}\n")

    print("Fig.4 analogue — intermediate bits (final = 3):")
    for ib in (3, 4, 5, 6):
        ppl, dt = quantized_ppl(cfg, params, "wiki", "gptqt", 3,
                                intermediate_bits=ib, reexplore_points=17)
        print(f"  n={ib}: ppl {ppl:8.3f}   ({dt:.1f}s quantize)")

    print("\nTab.VI analogue — re-exploration range (n=5, k=3):")
    for rng in (0, 1, 2):
        ppl, dt = quantized_ppl(cfg, params, "wiki", "gptqt", 3,
                                intermediate_bits=5, reexplore_range=rng,
                                reexplore_points=17)
        print(f"  range={rng}: ppl {ppl:8.3f}   ({dt:.1f}s quantize)")


if __name__ == "__main__":
    main()
