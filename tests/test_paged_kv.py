"""Paged-KV serving subsystem: kernel vs oracle, allocator invariants,
dense-vs-paged engine equivalence, preemption, and capacity-vs-dense."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention
from repro.models import init_params
from repro.serve import OutOfPages, PagedKVCache, Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def _tiny_cfg():
    return get_config("tiny-lm").replace(dtype="float32", n_layers=2,
                                         d_model=64, d_ff=128, remat="none")


def _reqs(cfg, n, max_new=6, base_len=12):
    out = []
    for i in range(n):
        L = base_len + (i % 3)          # mixed prompt lengths
        out.append(Request(prompt=(np.arange(L) * 7 + i).astype(np.int32)
                           % cfg.vocab_size, max_new_tokens=max_new))
    return out


def _run(cfg, params, reqs, **kw):
    eng = ServeEngine(cfg, params, dtype="float32", **kw)
    eng.run(reqs)
    return [r.out for r in reqs], eng


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,cap", [(None, None), (10, None),
                                        (None, 30.0), (7, 50.0)])
def test_paged_attention_kernel_matches_ref(window, cap):
    rng = np.random.default_rng(0)
    B, Hkv, rep, hd, P, page, T = 3, 2, 4, 64, 9, 16, 4
    q = jnp.asarray(rng.standard_normal((B, Hkv, rep, hd)).astype(np.float32))
    kp = jnp.asarray(rng.standard_normal((P, page, Hkv, hd)).astype(np.float32))
    vp = jnp.asarray(rng.standard_normal((P, page, Hkv, hd)).astype(np.float32))
    bt = jnp.asarray(rng.integers(1, P, (B, T)).astype(np.int32))
    ctx = jnp.asarray([1, 17, T * page], jnp.int32)   # 1 token .. full
    want = ref.paged_attention_ref(q, kp, vp, bt, ctx, window=window, cap=cap)
    got = paged_attention(q, kp, vp, bt, ctx, window=window, cap=cap,
                          interpret=True)
    assert float(jnp.abs(got - want).max()) < 1e-5


@pytest.mark.parametrize("page", [8, 16, 32])
def test_paged_attention_kernel_parity_page_size_sweep(page):
    """Kernel vs oracle across page sizes and ragged context lengths,
    including lengths straddling a page boundary by one token in either
    direction (the kernel's per-page masking edge)."""
    rng = np.random.default_rng(page)
    Hkv, rep, hd, T = 2, 2, 64, 4
    P = T + 3
    ctx = [1, page - 1, page, page + 1, 2 * page + 1, T * page]
    B = len(ctx)
    q = jnp.asarray(rng.standard_normal((B, Hkv, rep, hd)).astype(np.float32))
    kp = jnp.asarray(rng.standard_normal((P, page, Hkv, hd)).astype(np.float32))
    vp = jnp.asarray(rng.standard_normal((P, page, Hkv, hd)).astype(np.float32))
    bt = jnp.asarray(rng.integers(1, P, (B, T)).astype(np.int32))
    ctx = jnp.asarray(ctx, jnp.int32)
    want = ref.paged_attention_ref(q, kp, vp, bt, ctx)
    got = paged_attention(q, kp, vp, bt, ctx, interpret=True)
    assert float(jnp.abs(got - want).max()) < 1e-5


# ---------------------------------------------------------------------------
# allocator invariants
# ---------------------------------------------------------------------------

def _check_invariants(kv):
    owned = [p for s in range(kv.max_seqs) for p in kv.owned_pages(s)]
    assert 0 not in owned, "null page must never be allocated"
    # refcount conservation: live pages (counted once, however many
    # rows/index nodes reference them) + free == usable
    assert kv.live_pages + kv.free_page_count == kv.usable_pages
    assert set(owned).issubset({p for p in range(kv.n_pages)
                                if kv.refcount(p) > 0})
    for s in range(kv.max_seqs):
        mine = kv.owned_pages(s)
        assert len(mine) == len(set(mine)), "page twice in one row"
        assert (kv.block_tables[s, :len(mine)] == mine).all()
        assert (kv.block_tables[s, len(mine):] == 0).all()


def test_allocator_alloc_free_invariants():
    cfg = _tiny_cfg()
    kv = PagedKVCache(cfg, n_pages=9, page_size=8, max_seqs=3,
                      max_pages_per_seq=4, dtype="float32")
    s0, s1 = kv.alloc_slot(), kv.alloc_slot()
    kv.ensure(s0, 20)                       # 3 pages
    kv.ensure(s1, 8)                        # 1 page
    _check_invariants(kv)
    assert kv.used_pages == 4 and kv.utilization() == 4 / 8
    kv.ensure(s0, 20)                       # idempotent
    assert kv.used_pages == 4
    with pytest.raises(OutOfPages):
        kv.ensure(s1, 33)                   # > max_pages_per_seq
    with pytest.raises(OutOfPages):
        s2 = kv.alloc_slot()
        kv.ensure(s2, 8 * 5)                # > free pages
    _check_invariants(kv)                   # failed ensure allocates nothing
    kv.release(s0)
    _check_invariants(kv)
    assert kv.free_page_count == 7          # only s1's single page is live
    assert kv.high_water == 4


def test_truncate_frees_trailing_pages_and_respects_sharing():
    """Speculative rollback primitive: truncate(slot, n) keeps exactly
    pages_for(n) pages, zeroes the freed block-table tail, and unrefs
    (not frees) pages another reader still holds."""
    cfg = _tiny_cfg()
    kv = PagedKVCache(cfg, n_pages=9, page_size=4, max_seqs=3,
                      max_pages_per_seq=5, dtype="float32")
    s0 = kv.alloc_slot()
    kv.ensure(s0, 18)                       # 5 pages
    v0 = kv.bt_version[s0]
    assert kv.truncate(s0, 9) == 2          # 18 -> 9 tokens: 3 pages kept
    _check_invariants(kv)
    assert len(kv.owned_pages(s0)) == 3
    assert kv.bt_version[s0] > v0           # mirror must re-sync the row
    assert kv.truncate(s0, 9) == 0          # idempotent at the boundary
    assert kv.bt_version[s0] == v0 + 1
    # mid-page truncation keeps the partial tail page
    assert kv.truncate(s0, 7) == 1 and len(kv.owned_pages(s0)) == 2
    # a shared page is released from this row but stays live for the
    # other reader (COW/prefix sharing during speculation)
    s1 = kv.alloc_slot()
    kv.share(s1, kv.owned_pages(s0))
    free0 = kv.free_page_count
    assert kv.truncate(s0, 4) == 1          # drops s0's 2nd page
    _check_invariants(kv)
    assert kv.free_page_count == free0      # survivor: s1 still refs it
    assert len(kv.owned_pages(s1)) == 2
    kv.release(s0)
    kv.release(s1)
    assert kv.free_page_count == kv.usable_pages


def test_compact_remaps_pages_preserving_content():
    cfg = _tiny_cfg()
    kv = PagedKVCache(cfg, n_pages=9, page_size=4, max_seqs=2,
                      max_pages_per_seq=4, dtype="float32")
    s0, s1 = kv.alloc_slot(), kv.alloc_slot()
    kv.ensure(s0, 8)
    kv.ensure(s1, 8)
    kv.release(s0)                          # leaves holes in the id space
    kv.ensure(s1, 16)

    # stamp each owned page with its (slot, index) signature
    def stamp(pool):
        for j, pid in enumerate(kv.owned_pages(s1)):
            pool = jax.tree.map(
                lambda a: a.at[:, pid].set(float(10 + j)) if a.ndim == 5 else a,
                pool)
        return pool
    kv.pool = stamp(kv.pool)

    def gather(pool):
        leaf = jax.tree.leaves(pool)[0]     # (G, P, page, Hkv, hd)
        ids = kv.block_tables[s1][:len(kv.owned_pages(s1))]
        return np.asarray(leaf[:, np.asarray(ids)])

    before = gather(kv.pool)
    kv.compact()
    _check_invariants(kv)
    after = gather(kv.pool)
    np.testing.assert_array_equal(before, after)
    # live pages now occupy the densest prefix
    assert sorted(kv.owned_pages(s1)) == list(range(1, 5))


# ---------------------------------------------------------------------------
# engine equivalence + scheduler behaviour
# ---------------------------------------------------------------------------

def test_paged_matches_dense_greedy():
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    want, _ = _run(cfg, p, _reqs(cfg, 4), batch_size=2, max_len=64)
    got, eng = _run(cfg, p, _reqs(cfg, 4), batch_size=2, max_len=64,
                    cache_kind="paged", page_size=16)
    assert got == want
    # after the run only the radix prefix index retains pages; dropping
    # it returns every page to the free list
    _check_invariants(eng.kv)
    assert eng.kv.live_pages == eng.stats["prefix_cached_pages"]
    eng._prefix.clear()
    assert eng.kv.free_page_count == eng.kv.usable_pages  # all released


def test_chunked_prefill_matches_dense_greedy():
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    want, _ = _run(cfg, p, _reqs(cfg, 3), batch_size=2, max_len=64)
    got, eng = _run(cfg, p, _reqs(cfg, 3), batch_size=2, max_len=64,
                    cache_kind="paged", page_size=16, prefill_chunk=5)
    assert got == want


def test_paged_engine_through_interpret_kernel():
    """Force the Pallas kernel (interpret mode off-TPU) for engine decode
    — the full wiring model -> kernel, not just the oracle comparison."""
    from repro.models import attention as attn_mod
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    want, _ = _run(cfg, p, _reqs(cfg, 2, max_new=4), batch_size=2,
                   max_len=48)
    attn_mod.FORCE_PAGED_KERNEL = True
    try:
        got, _ = _run(cfg, p, _reqs(cfg, 2, max_new=4), batch_size=2,
                      max_len=48, cache_kind="paged", page_size=16)
    finally:
        attn_mod.FORCE_PAGED_KERNEL = None
    assert got == want


def test_preemption_by_eviction_resumes_exactly():
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    mk = lambda: [Request(prompt=(np.arange(6) * 3 + i).astype(np.int32)
                          % cfg.vocab_size, max_new_tokens=14)
                  for i in range(2)]
    want, _ = _run(cfg, p, mk(), batch_size=2, max_len=64)
    # pool of 4 usable pages; both sequences admitted (1 page each) but
    # together outgrow the pool mid-decode -> LIFO eviction + recompute
    got, eng = _run(cfg, p, mk(), batch_size=2, max_len=64,
                    cache_kind="paged", page_size=8, n_pages=5)
    assert eng.sched.preemptions > 0
    assert got == want


def test_paged_matches_dense_with_sliding_window():
    """Window layers can't use the rolling-buffer prefill scatter — the
    paged engine must route them through the absolute-position extend
    path. Prompt longer than the window exercises the rotation."""
    from repro.configs.base import LayerSpec
    cfg = _tiny_cfg().replace(
        pattern=(LayerSpec(kind="attn", mlp="dense", window=16),))
    p = init_params(cfg, KEY)
    mk = lambda: [Request(prompt=(np.arange(40) * 3 + i).astype(np.int32)
                          % cfg.vocab_size, max_new_tokens=6)
                  for i in range(2)]
    want, _ = _run(cfg, p, mk(), batch_size=2, max_len=64)
    got, _ = _run(cfg, p, mk(), batch_size=2, max_len=64,
                  cache_kind="paged", page_size=16)
    assert got == want


def test_sequence_truncates_at_pool_bound_instead_of_crashing():
    """A request whose growth would outrun the whole pool truncates at
    the pool's single-sequence capacity (like dense at max_len) — it
    must not crash the run after preemption regrows its prompt."""
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    eng = ServeEngine(cfg, p, batch_size=1, max_len=32, dtype="float32",
                      cache_kind="paged", page_size=4, n_pages=5)
    r = Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=25)
    eng.run([r])
    # capacity = 4 usable pages * 4 = 16 tokens -> 4 prompt + 12 new
    assert r.done and len(r.out) == 12


def test_unservable_prompt_rejected_upfront():
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    eng = ServeEngine(cfg, p, batch_size=1, max_len=128, dtype="float32",
                      cache_kind="paged", page_size=64)   # 2 usable pages
    with pytest.raises(ValueError, match="pages"):
        eng.run([Request(prompt=np.arange(80, dtype=np.int32) % 200,
                         max_new_tokens=4)])


def test_requests_beyond_pool_capacity_all_complete():
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    reqs = _reqs(cfg, 6, max_new=4)
    done, eng = _run(cfg, p, reqs, batch_size=2, max_len=48,
                     cache_kind="paged", page_size=16, n_pages=5)
    assert all(len(r.out) == 4 and r.done for r in reqs)
    assert eng.stats["n_done"] == 6
    assert eng.stats["ttft_avg_s"] > 0 and eng.stats["tpot_avg_s"] > 0


def test_paged_sustains_more_concurrency_than_dense_budget():
    """Acceptance criterion: under the dense engine's byte budget
    (batch_size * max_len KV slots) the paged engine runs more than
    batch_size concurrent sequences, verified via page accounting."""
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    dense_slots, max_len = 2, 64
    budget_tokens = dense_slots * max_len          # 128 KV slots
    page = 16
    eng = ServeEngine(cfg, p, batch_size=4, max_len=max_len,
                      dtype="float32", cache_kind="paged", page_size=page,
                      n_pages=budget_tokens // page + 1)   # +1 null page
    reqs = [Request(prompt=(np.arange(8) + i).astype(np.int32)
                    % cfg.vocab_size, max_new_tokens=6) for i in range(4)]
    seen = []
    orig = eng._decode_tick
    eng._decode_tick = lambda: (seen.append(len(eng.sched.running)), orig())
    eng.run(reqs)
    assert all(len(r.out) == 6 for r in reqs)
    assert max(seen) > dense_slots                 # more live than dense fits
    assert eng.kv.high_water <= budget_tokens // page  # within the budget


def test_max_pages_per_seq_zero_raises():
    """0 is a configuration error (no sequence could ever hold a page),
    not a request for the default cap — the falsy-fallback regression."""
    with pytest.raises(ValueError, match="max_pages_per_seq"):
        PagedKVCache(None, n_pages=8, page_size=4, max_seqs=2,
                     max_pages_per_seq=0, create_pool=False)
