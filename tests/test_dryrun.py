"""Dry-run deliverable tests: a sample of (arch x shape x mesh) cells must
lower+compile on the production meshes (512 fake devices) — run in
subprocesses because XLA_FLAGS must precede jax init. Marked slow; the
full 32-cell sweep is driven by `python -m repro.launch.dryrun --all`."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).parents[1]


def _run_cell(arch, shape, extra=()):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, *extra]
    r = subprocess.run(cmd, capture_output=True, text=True,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=ROOT, timeout=1200)
    ok = "[OK ]" in r.stdout
    assert ok, f"{arch}/{shape} failed:\n{r.stdout[-1500:]}\n{r.stderr[-1500:]}"


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("qwen3-0.6b", "train_4k"),
    ("mixtral-8x7b", "decode_32k"),
    ("falcon-mamba-7b", "long_500k"),
])
def test_single_pod_cells(arch, shape):
    _run_cell(arch, shape)


@pytest.mark.slow
def test_multi_pod_cell():
    _run_cell("qwen3-0.6b", "train_4k", ("--multipod",))


@pytest.mark.slow
def test_quantized_decode_cell():
    _run_cell("qwen3-0.6b", "decode_32k", ("--quant", "3"))


def test_roofline_parser_units():
    from repro.roofline.analysis import parse_collectives, _array_bytes
    hlo = """
  %ag = bf16[256,128]{1,0} all-gather(%x), replica_groups=[16,16]<=[256]T(1,0), dimensions={0}
  %ar = f32[1024]{0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %fusion = f32[8]{0} fusion(%all-gather-operand), kind=kLoop
  %cp = collective-permute-start(f32[64]{0} %z), source_target_pairs={{0,1}}
"""
    st = parse_collectives(hlo)
    # all-gather: 256*128*2 bytes * 15/16 ; all-reduce: 4096 * 2*3/4
    ag = 256 * 128 * 2 * 15 / 16
    ar = 4096 * 2 * 3 / 4
    assert abs(st.by_op["all-gather"]["bytes"] - ag) < 1
    assert abs(st.by_op["all-reduce"]["bytes"] - ar) < 1
    assert "fusion" not in st.by_op
    assert _array_bytes("(f32[2,2], bf16[4])") == 16 + 8


def test_input_specs_cover_all_cells():
    from repro.configs import ASSIGNED, runnable_shapes
    from repro.launch.dryrun import input_specs
    n = 0
    for name, cfg in ASSIGNED.items():
        for s in runnable_shapes(cfg):
            spec = input_specs(cfg, s)
            assert isinstance(spec, dict) and spec
            n += 1
    assert n == 32  # documented cell count (DESIGN.md §4)
