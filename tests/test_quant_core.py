"""Unit + property tests for the GPTQT quantization core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CPU CI image without hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core import (bcq_alternating, bcq_greedy, enumerate_bc_choices,
                        gptq_solve, hessian_from_inputs, linear_levels,
                        minmse_grid, output_error, quantize_rtn, row_grid)
from repro.core.binary_coding import choice_levels_int, sign_combos
from repro.core.gptqt import gptqt_quantize


def _data(n=64, k=64, t=256, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((k, k)) / np.sqrt(k)
    X = rng.standard_normal((t, k)) @ (np.eye(k) + 1.5 * A)
    W = rng.standard_normal((k, n))
    H, _ = hessian_from_inputs([jnp.asarray(X, jnp.float32)])
    return jnp.asarray(W.T, jnp.float32), H


# ---------------------------------------------------------------------------
# grids / RTN
# ---------------------------------------------------------------------------

def test_rtn_levels_cover_range():
    Wt, _ = _data()
    wq, q = quantize_rtn(Wt, 3)
    assert q.min() >= 0 and q.max() <= 7
    # reconstruction error bounded by half a step per element
    S, _ = row_grid(Wt, 3)
    assert float(jnp.max(jnp.abs(wq - Wt) / S[:, None])) <= 0.5 + 1e-5


def test_linear_levels_match_rtn():
    """RTN == nearest-level quantization against the linear grid."""
    Wt, _ = _data()
    S, c = row_grid(Wt, 3)
    levels = linear_levels(S, c, 3)
    wq, _ = quantize_rtn(Wt, 3)
    idx = jnp.argmin(jnp.abs(Wt[:, :, None] - levels[:, None, :]), -1)
    wq2 = jnp.take_along_axis(levels, idx.reshape(Wt.shape[0], -1), 1)
    np.testing.assert_allclose(wq, wq2.reshape(Wt.shape), rtol=1e-6)


def test_minmse_never_worse_than_plain_mse():
    Wt, _ = _data(seed=3)
    S0, c0 = row_grid(Wt, 3)
    lv0 = linear_levels(S0, c0, 3)
    S1, c1 = minmse_grid(Wt, 3)
    lv1 = linear_levels(S1, c1, 3)

    def mse(lv):
        d = jnp.min(jnp.abs(Wt[:, :, None] - lv[:, None, :]), -1)
        return float(jnp.sum(d * d))
    assert mse(lv1) <= mse(lv0) + 1e-6


# ---------------------------------------------------------------------------
# BCQ
# ---------------------------------------------------------------------------

def test_bcq_greedy_monotone_residual():
    Wt, _ = _data()
    a1, _ = bcq_greedy(Wt, 1)
    for bits in (2, 3, 4):
        wq, alphas, signs = bcq_alternating(Wt, bits, iters=5)
        err = float(jnp.sum((wq - Wt) ** 2))
        if bits > 2:
            assert err <= prev + 1e-4, f"bits={bits} err up"
        prev = err


def test_bcq_alternating_improves_over_greedy():
    Wt, _ = _data(seed=1)
    alphas, signs = bcq_greedy(Wt, 3)
    wq_g = jnp.einsum("ink,ni->nk", signs, alphas)
    wq_a, _, _ = bcq_alternating(Wt, 3, iters=10)
    assert float(jnp.sum((wq_a - Wt) ** 2)) <= float(jnp.sum((wq_g - Wt) ** 2)) + 1e-5


# ---------------------------------------------------------------------------
# BCchoice enumeration (paper Fig. 3 structure)
# ---------------------------------------------------------------------------

def test_paper_example_choice_is_enumerated():
    """[0,1,6,7] (paper's 3-bit -> 2-bit example) must appear."""
    E, J = enumerate_bc_choices(3, 2)
    levels = np.asarray(choice_levels_int(E, J, 2))
    found = any(sorted(lv.tolist()) == [0., 1., 6., 7.] for lv in levels)
    assert found


@given(st.integers(3, 5), st.integers(2, 3))
@settings(max_examples=10, deadline=None)
def test_choices_are_valid_binary_codings(n, k):
    E, J = enumerate_bc_choices(n, k, max_candidates=512)
    levels = np.asarray(choice_levels_int(E, J, k))
    # all integer levels within [0, 2^n - 1]
    assert np.allclose(levels, np.round(levels))
    assert levels.min() >= 0 and levels.max() <= 2 ** n - 1


# ---------------------------------------------------------------------------
# GPTQ solver
# ---------------------------------------------------------------------------

def test_gptq_beats_rtn_on_correlated_data():
    Wt, H = _data(seed=2)
    S, c = row_grid(Wt, 3)
    levels = linear_levels(S, c, 3)
    wq_rtn, _ = quantize_rtn(Wt, 3)
    wq_gptq, _ = gptq_solve(Wt, H, levels)
    assert output_error(Wt, wq_gptq, H) < output_error(Wt, wq_rtn, H)


def test_gptq_identity_hessian_equals_rtn():
    """With H = I (uncorrelated inputs) and no actorder, compensation is
    zero-mean and GPTQ reduces to nearest-level per column."""
    Wt, _ = _data()
    H = jnp.eye(Wt.shape[1])
    S, c = row_grid(Wt, 3)
    levels = linear_levels(S, c, 3)
    wq, _ = gptq_solve(Wt, H, levels, actorder=False, percdamp=0.0)
    wq_rtn, _ = quantize_rtn(Wt, 3)
    np.testing.assert_allclose(np.asarray(wq), np.asarray(wq_rtn), atol=1e-4)


def test_gptq_output_on_levels():
    Wt, H = _data()
    S, c = row_grid(Wt, 3)
    levels = linear_levels(S, c, 3)
    wq, idx = gptq_solve(Wt, H, levels)
    picked = jnp.take_along_axis(levels, idx.reshape(Wt.shape[0], -1), 1)
    np.testing.assert_allclose(np.asarray(wq),
                               np.asarray(picked.reshape(Wt.shape)), atol=1e-5)


# ---------------------------------------------------------------------------
# GPTQT end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits,ibits", [(2, 4), (3, 5)])
def test_gptqt_beats_plain_bcq(bits, ibits):
    Wt, H = _data(seed=4)
    res = gptqt_quantize(Wt, H, bits=bits, intermediate_bits=ibits)
    wq_bcq, _, _ = bcq_alternating(Wt, bits)
    assert output_error(Wt, res.wq_t, H) < output_error(Wt, wq_bcq, H)


def test_gptqt_fusion_is_exact():
    """Eq. 11: fused binary coding reproduces the solver output exactly."""
    Wt, H = _data(seed=5)
    res = gptqt_quantize(Wt, H, bits=3, intermediate_bits=5)
    dq = res.qt.dequant(jnp.float32)        # (K, N)
    np.testing.assert_array_equal(np.asarray(dq.T), np.asarray(res.wq_t))


def test_gptqt_levels_are_binary_coding_trees():
    """Every row's final level set must be {beta ± alpha_1 ± ... ± alpha_k}."""
    Wt, H = _data(seed=6)
    res = gptqt_quantize(Wt, H, bits=3, intermediate_bits=5)
    combos = jnp.asarray(sign_combos(3))
    alphas = res.qt.alphas[0]                # (N, k)
    betas = res.qt.betas[0]                  # (N,)
    want = betas[:, None] + alphas @ combos.T
    np.testing.assert_allclose(np.asarray(res.levels), np.asarray(want),
                               rtol=1e-6)


def test_gptqt_hist_matches_exact_search_quality():
    """Histogram-accelerated search should be within a few percent of the
    exact scorer on output error."""
    Wt, H = _data(n=32, k=48, seed=7)
    r_exact = gptqt_quantize(Wt, H, bits=3, intermediate_bits=4, exact=True)
    r_hist = gptqt_quantize(Wt, H, bits=3, intermediate_bits=4, exact=False)
    e1 = output_error(Wt, r_exact.wq_t, H)
    e2 = output_error(Wt, r_hist.wq_t, H)
    assert e2 <= e1 * 1.10 + 1e-6


@given(st.integers(0, 2))
@settings(max_examples=3, deadline=None)
def test_reexplore_scale_within_eq7_bounds(rng_range):
    Wt, H = _data(n=16, k=32, seed=8)
    n = 4
    res = gptqt_quantize(Wt, H, bits=2, intermediate_bits=n,
                         reexplore_range=rng_range, reexplore_points=9)
    S0, _ = row_grid(Wt, n)
    mult = np.asarray(res.scale / S0)
    top = 2.0 ** n - 1
    lo = top / (2.0 ** (n + rng_range) - 1) - 1e-5
    hi = top / (2.0 ** (max(n - rng_range, 1)) - 1) + 1e-5 if rng_range else 1.0 + 1e-5
    assert (mult >= lo).all() and (mult <= hi + 1.0).all()
