"""Unit + property tests for the GPTQT quantization core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # not installed: property tests below are gated out
    given = settings = st = None

from repro.core import (bcq_alternating, bcq_greedy, enumerate_bc_choices,
                        gptq_solve, gptq_solve_refresh, group_rows,
                        hessian_from_inputs, linear_levels, minmse_grid,
                        n_k_groups, output_error, quantize_rtn, row_grid)
from repro.core.binary_coding import choice_levels_int, sign_combos
from repro.core.gptqt import gptqt_quantize


def _data(n=64, k=64, t=256, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((k, k)) / np.sqrt(k)
    X = rng.standard_normal((t, k)) @ (np.eye(k) + 1.5 * A)
    W = rng.standard_normal((k, n))
    H, _ = hessian_from_inputs([jnp.asarray(X, jnp.float32)])
    return jnp.asarray(W.T, jnp.float32), H


# ---------------------------------------------------------------------------
# grids / RTN
# ---------------------------------------------------------------------------

def test_rtn_levels_cover_range():
    Wt, _ = _data()
    wq, q = quantize_rtn(Wt, 3)
    assert q.min() >= 0 and q.max() <= 7
    # reconstruction error bounded by half a step per element
    S, _ = row_grid(Wt, 3)
    assert float(jnp.max(jnp.abs(wq - Wt) / S[:, None])) <= 0.5 + 1e-5


def test_linear_levels_match_rtn():
    """RTN == nearest-level quantization against the linear grid."""
    Wt, _ = _data()
    S, c = row_grid(Wt, 3)
    levels = linear_levels(S, c, 3)
    wq, _ = quantize_rtn(Wt, 3)
    idx = jnp.argmin(jnp.abs(Wt[:, :, None] - levels[:, None, :]), -1)
    wq2 = jnp.take_along_axis(levels, idx.reshape(Wt.shape[0], -1), 1)
    np.testing.assert_allclose(wq, wq2.reshape(Wt.shape), rtol=1e-6)


def test_minmse_never_worse_than_plain_mse():
    Wt, _ = _data(seed=3)
    S0, c0 = row_grid(Wt, 3)
    lv0 = linear_levels(S0, c0, 3)
    S1, c1 = minmse_grid(Wt, 3)
    lv1 = linear_levels(S1, c1, 3)

    def mse(lv):
        d = jnp.min(jnp.abs(Wt[:, :, None] - lv[:, None, :]), -1)
        return float(jnp.sum(d * d))
    assert mse(lv1) <= mse(lv0) + 1e-6


# ---------------------------------------------------------------------------
# BCQ
# ---------------------------------------------------------------------------

def test_bcq_greedy_monotone_residual():
    Wt, _ = _data()
    a1, _ = bcq_greedy(Wt, 1)
    for bits in (2, 3, 4):
        wq, alphas, signs = bcq_alternating(Wt, bits, iters=5)
        err = float(jnp.sum((wq - Wt) ** 2))
        if bits > 2:
            assert err <= prev + 1e-4, f"bits={bits} err up"
        prev = err


def test_bcq_alternating_improves_over_greedy():
    Wt, _ = _data(seed=1)
    alphas, signs = bcq_greedy(Wt, 3)
    wq_g = jnp.einsum("ink,ni->nk", signs, alphas)
    wq_a, _, _ = bcq_alternating(Wt, 3, iters=10)
    assert float(jnp.sum((wq_a - Wt) ** 2)) <= float(jnp.sum((wq_g - Wt) ** 2)) + 1e-5


# ---------------------------------------------------------------------------
# BCchoice enumeration (paper Fig. 3 structure)
# ---------------------------------------------------------------------------

def test_paper_example_choice_is_enumerated():
    """[0,1,6,7] (paper's 3-bit -> 2-bit example) must appear."""
    E, J = enumerate_bc_choices(3, 2)
    levels = np.asarray(choice_levels_int(E, J, 2))
    found = any(sorted(lv.tolist()) == [0., 1., 6., 7.] for lv in levels)
    assert found


if given is not None:
    @given(st.integers(3, 5), st.integers(2, 3))
    @settings(max_examples=10, deadline=None)
    def test_choices_are_valid_binary_codings(n, k):
        E, J = enumerate_bc_choices(n, k, max_candidates=512)
        levels = np.asarray(choice_levels_int(E, J, k))
        # all integer levels within [0, 2^n - 1]
        assert np.allclose(levels, np.round(levels))
        assert levels.min() >= 0 and levels.max() <= 2 ** n - 1


# ---------------------------------------------------------------------------
# GPTQ solver
# ---------------------------------------------------------------------------

def test_gptq_beats_rtn_on_correlated_data():
    Wt, H = _data(seed=2)
    S, c = row_grid(Wt, 3)
    levels = linear_levels(S, c, 3)
    wq_rtn, _ = quantize_rtn(Wt, 3)
    wq_gptq, _ = gptq_solve(Wt, H, levels)
    assert output_error(Wt, wq_gptq, H) < output_error(Wt, wq_rtn, H)


def test_gptq_identity_hessian_equals_rtn():
    """With H = I (uncorrelated inputs) and no actorder, compensation is
    zero-mean and GPTQ reduces to nearest-level per column."""
    Wt, _ = _data()
    H = jnp.eye(Wt.shape[1])
    S, c = row_grid(Wt, 3)
    levels = linear_levels(S, c, 3)
    wq, _ = gptq_solve(Wt, H, levels, actorder=False, percdamp=0.0)
    wq_rtn, _ = quantize_rtn(Wt, 3)
    np.testing.assert_allclose(np.asarray(wq), np.asarray(wq_rtn), atol=1e-4)


def test_gptq_output_on_levels():
    Wt, H = _data()
    S, c = row_grid(Wt, 3)
    levels = linear_levels(S, c, 3)
    wq, idx = gptq_solve(Wt, H, levels)
    picked = jnp.take_along_axis(levels, idx.reshape(Wt.shape[0], -1), 1)
    np.testing.assert_allclose(np.asarray(wq),
                               np.asarray(picked.reshape(Wt.shape)), atol=1e-5)


# ---------------------------------------------------------------------------
# GPTQT end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits,ibits", [(2, 4), (3, 5)])
def test_gptqt_beats_plain_bcq(bits, ibits):
    Wt, H = _data(seed=4)
    res = gptqt_quantize(Wt, H, bits=bits, intermediate_bits=ibits)
    wq_bcq, _, _ = bcq_alternating(Wt, bits)
    assert output_error(Wt, res.wq_t, H) < output_error(Wt, wq_bcq, H)


def test_gptqt_fusion_is_exact():
    """Eq. 11: fused binary coding reproduces the solver output exactly."""
    Wt, H = _data(seed=5)
    res = gptqt_quantize(Wt, H, bits=3, intermediate_bits=5)
    dq = res.qt.dequant(jnp.float32)        # (K, N)
    np.testing.assert_array_equal(np.asarray(dq.T), np.asarray(res.wq_t))


def test_gptqt_levels_are_binary_coding_trees():
    """Every row's final level set must be {beta ± alpha_1 ± ... ± alpha_k}."""
    Wt, H = _data(seed=6)
    res = gptqt_quantize(Wt, H, bits=3, intermediate_bits=5)
    combos = jnp.asarray(sign_combos(3))
    alphas = res.qt.alphas[0]                # (N, k)
    betas = res.qt.betas[0]                  # (N,)
    want = betas[:, None] + alphas @ combos.T
    np.testing.assert_allclose(np.asarray(res.levels), np.asarray(want),
                               rtol=1e-6)


def test_gptqt_hist_matches_exact_search_quality():
    """Histogram-accelerated search should be within a few percent of the
    exact scorer on output error."""
    Wt, H = _data(n=32, k=48, seed=7)
    r_exact = gptqt_quantize(Wt, H, bits=3, intermediate_bits=4, exact=True)
    r_hist = gptqt_quantize(Wt, H, bits=3, intermediate_bits=4, exact=False)
    e1 = output_error(Wt, r_exact.wq_t, H)
    e2 = output_error(Wt, r_hist.wq_t, H)
    assert e2 <= e1 * 1.10 + 1e-6


# ---------------------------------------------------------------------------
# group-wise scaling (per-K-group grids through every solver)
# ---------------------------------------------------------------------------

def test_group_rows_layout_and_validation():
    Wt, _ = _data(n=8, k=64)
    Wg, G = group_rows(Wt, 16)
    assert G == 4 and Wg.shape == (32, 16)
    # row (n, g) holds columns [g*16, (g+1)*16) of original row n
    np.testing.assert_array_equal(np.asarray(Wg[5]),
                                  np.asarray(Wt[1, 16:32]))
    with pytest.raises(ValueError, match="divide"):
        n_k_groups(64, 48)
    with pytest.raises(ValueError, match=">= 0"):
        n_k_groups(64, -2)


def test_grouped_rtn_equals_per_group_reference():
    """Group-wise RTN == per-row RTN applied group by group."""
    Wt, _ = _data(n=16, k=64, seed=10)
    gs = 16
    wq, q = quantize_rtn(Wt, 3, group_size=gs)
    for g in range(64 // gs):
        blk = Wt[:, g * gs:(g + 1) * gs]
        wq_blk, _ = quantize_rtn(blk, 3)
        np.testing.assert_allclose(np.asarray(wq[:, g * gs:(g + 1) * gs]),
                                   np.asarray(wq_blk), rtol=1e-6)


def test_grouped_rtn_reduces_weight_mse():
    """Finer scale groups track the weight distribution better: MSE must
    not increase, and on heteroscedastic rows it strictly drops."""
    rng = np.random.default_rng(0)
    # per-group spread so per-channel scales are badly matched
    Wt = jnp.asarray(rng.standard_normal((16, 128)) *
                     np.repeat(rng.uniform(0.1, 4.0, (16, 4)), 32, axis=1),
                     jnp.float32)
    wq0, _ = quantize_rtn(Wt, 3)
    wq1, _ = quantize_rtn(Wt, 3, group_size=32)
    e0 = float(jnp.sum((wq0 - Wt) ** 2))
    e1 = float(jnp.sum((wq1 - Wt) ** 2))
    assert e1 < e0


def test_gptq_grouped_identity_hessian_equals_grouped_rtn():
    """Group-boundary unit test: with H = I and no actorder the solver
    must quantize each column against ITS group's grid — i.e. reduce to
    group-wise RTN exactly at and across boundaries."""
    Wt, _ = _data(n=16, k=64)
    H = jnp.eye(64)
    gs = 16
    Wg, G = group_rows(Wt, gs)
    S, c = row_grid(Wg, 3)
    levels = linear_levels(S, c, 3).reshape(16, G, -1)
    wq, _ = gptq_solve(Wt, H, levels, actorder=False, percdamp=0.0)
    wq_rtn, _ = quantize_rtn(Wt, 3, group_size=gs)
    np.testing.assert_allclose(np.asarray(wq), np.asarray(wq_rtn), atol=1e-4)


def test_gptq_grouped_actorder_uses_original_group_grids():
    """actorder permutes the sweep; each column must still quantize
    against its ORIGINAL group's level set (static-groups convention)."""
    Wt, H = _data(n=16, k=64, seed=11)
    gs = 16
    Wg, G = group_rows(Wt, gs)
    S, c = row_grid(Wg, 3)
    levels3 = linear_levels(S, c, 3).reshape(16, G, -1)
    wq, idx = gptq_solve(Wt, H, levels3, actorder=True)
    # every output value must lie on its own (row, group) grid
    lv = np.asarray(levels3)
    wqn = np.asarray(wq)
    for n in range(16):
        for col in range(64):
            assert np.min(np.abs(lv[n, col // gs] - wqn[n, col])) < 1e-5


def test_gptq_refresh_identity_hessian_equals_grouped_rtn():
    """With H = I there is no compensation, so the refreshed grid equals
    the static per-group grid and the sweep reduces to grouped RTN."""
    Wt, _ = _data(n=16, k=64, seed=12)
    H = jnp.eye(64)
    wq, _ = gptq_solve_refresh(Wt, H, bits=3, group_size=16, percdamp=0.0)
    wq_rtn, _ = quantize_rtn(Wt, 3, group_size=16)
    np.testing.assert_allclose(np.asarray(wq), np.asarray(wq_rtn), atol=1e-4)


def test_gptq_refresh_tracks_compensated_residual():
    """On correlated data the refreshed grids see the compensated
    residuals; the result must still beat plain grouped RTN on output
    error (the whole point of the GPTQ sweep)."""
    Wt, H = _data(seed=13)
    wq, _ = gptq_solve_refresh(Wt, H, bits=3, group_size=16)
    wq_rtn, _ = quantize_rtn(Wt, 3, group_size=16)
    assert output_error(Wt, wq, H) < output_error(Wt, wq_rtn, H)


def test_grouped_bcq_shapes_and_error():
    Wt, _ = _data(n=16, k=64, seed=14)
    wq1, a1, s1 = bcq_alternating(Wt, 3)
    wq4, a4, s4 = bcq_alternating(Wt, 3, group_size=16)
    assert a1.shape == (16, 3) and a4.shape == (16, 4, 3)
    assert s4.shape == (3, 16, 64)
    # 4x the scale freedom must not hurt the fit
    assert float(jnp.sum((wq4 - Wt) ** 2)) <= \
        float(jnp.sum((wq1 - Wt) ** 2)) + 1e-5


def test_gptqt_grouped_beats_per_channel():
    """Acceptance: gptqt with groups achieves strictly lower
    reconstruction error than G=1 on the synthetic-Hessian fixture."""
    Wt, H = _data(seed=4)
    r1 = gptqt_quantize(Wt, H, bits=3, intermediate_bits=5)
    rg = gptqt_quantize(Wt, H, bits=3, intermediate_bits=5, group_size=16)
    assert output_error(Wt, rg.wq_t, H) < output_error(Wt, r1.wq_t, H)


def test_gptqt_grouped_fusion_is_exact():
    """Eq. 11 fusion with true G scale leaves: packed dequant must equal
    the solver output bit-for-bit, and the QT must carry G = K/gs."""
    Wt, H = _data(seed=5)
    rg = gptqt_quantize(Wt, H, bits=3, intermediate_bits=5, group_size=32)
    assert rg.qt.n_groups == 2 and rg.qt.group_size == 32
    assert rg.qt.alphas.shape == (2, Wt.shape[0], 3)
    dq = rg.qt.dequant(jnp.float32)
    np.testing.assert_array_equal(np.asarray(dq.T), np.asarray(rg.wq_t))
    # per-(row, group) level sets are binary-coding trees
    combos = jnp.asarray(sign_combos(3))
    want = rg.qt.betas[..., None] + jnp.einsum(
        "gnk,lk->gnl", rg.qt.alphas, combos)             # (G, N, L)
    np.testing.assert_allclose(np.asarray(jnp.swapaxes(want, 0, 1)),
                               np.asarray(rg.levels), rtol=1e-6)


def test_gptqt_grouped_quantized_matmul_matches_dequant():
    """The serving path: grouped QT matmul (reference dispatch) must
    agree with explicit dequant @ x."""
    Wt, H = _data(seed=15)
    rg = gptqt_quantize(Wt, H, bits=2, intermediate_bits=4, group_size=16)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (5, Wt.shape[1])).astype(np.float32))
    y = rg.qt.quantized_matmul(x)
    w = rg.qt.dequant(jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-4)


def test_gptqt_nondivisible_group_size_raises():
    Wt, H = _data(n=8, k=64)
    with pytest.raises(ValueError, match="divide"):
        gptqt_quantize(Wt, H, bits=2, intermediate_bits=4, group_size=48)


if given is not None:
    @given(st.integers(0, 2))
    @settings(max_examples=3, deadline=None)
    def test_reexplore_scale_within_eq7_bounds(rng_range):
        Wt, H = _data(n=16, k=32, seed=8)
        n = 4
        res = gptqt_quantize(Wt, H, bits=2, intermediate_bits=n,
                             reexplore_range=rng_range, reexplore_points=9)
        S0, _ = row_grid(Wt, n)
        mult = np.asarray(res.scale / S0)
        top = 2.0 ** n - 1
        lo = top / (2.0 ** (n + rng_range) - 1) - 1e-5
        hi = top / (2.0 ** (max(n - rng_range, 1)) - 1) + 1e-5 if rng_range else 1.0 + 1e-5
        assert (mult >= lo).all() and (mult <= hi + 1.0).all()
