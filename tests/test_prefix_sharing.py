"""Shared-prefix paged KV: radix prefix index + copy-on-write pages.

Acceptance criterion (ISSUE 2): with two requests sharing a 256-token
prefix, the second request's prefill processes only suffix tokens,
allocates only suffix pages, and its greedy output is token-identical
to the no-sharing path.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.models import init_params
from repro.serve import (PagedKVCache, RadixPrefixCache, Request,
                         ServeEngine)

KEY = jax.random.PRNGKey(0)


def _tiny_cfg():
    return get_config("tiny-lm").replace(dtype="float32", n_layers=2,
                                         d_model=64, d_ff=128, remat="none")


def _prompt(prefix, i, n=8):
    tail = (np.arange(n, dtype=np.int32) * 7 + i + 1) % 199
    return np.concatenate([prefix, tail]).astype(np.int32)


def _outs(reqs):
    return [r.out for r in reqs]


# ---------------------------------------------------------------------------
# acceptance: 256-token shared prefix
# ---------------------------------------------------------------------------

def test_256_token_shared_prefix_skips_prefill_and_pages():
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    page = 32
    prefix = (np.arange(256, dtype=np.int32) * 3 + 5) % cfg.vocab_size
    mk = lambda: [Request(prompt=_prompt(prefix, i), max_new_tokens=6)
                  for i in range(2)]

    base = ServeEngine(cfg, p, batch_size=2, max_len=512, dtype="float32",
                       cache_kind="paged", page_size=page,
                       prefix_sharing=False)
    want = mk()
    base.run(want)

    eng = ServeEngine(cfg, p, batch_size=2, max_len=512, dtype="float32",
                      cache_kind="paged", page_size=page,
                      prefix_sharing=True)
    got = mk()
    eng.run(got)

    # token-identical to the no-sharing path
    assert _outs(got) == _outs(want)
    # the second request prefilled only its 8 suffix tokens: total
    # prefill work is one full prompt + one suffix
    L = 256 + 8
    assert base.stats["prefill_tokens"] == 2 * L
    assert eng.stats["prefill_tokens"] == L + 8
    assert eng.stats["prefix_hits"] == 1
    assert eng.stats["prefix_tokens_saved"] == 256
    # and allocated only suffix pages: the 256/32 = 8 prefix pages were
    # attached by reference, not taken from the free list
    assert base.kv.pages_allocated - eng.kv.pages_allocated == 256 // page
    # aligned prefix -> pure sharing, no copy-on-write needed
    assert eng.stats["cow_forks"] == 0


# ---------------------------------------------------------------------------
# copy-on-write fork on mid-page matches
# ---------------------------------------------------------------------------

def test_partial_page_match_forks_copy_on_write():
    """A finished request's last (partial) page is retained by the
    index; a second request matching into it must fork it before its
    own suffix tokens land there — outputs stay exact."""
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    prefix = (np.arange(40, dtype=np.int32) * 3 + 5) % cfg.vocab_size
    mk = lambda i: [Request(prompt=_prompt(prefix, i), max_new_tokens=5)]

    def serve(sharing):
        eng = ServeEngine(cfg, p, batch_size=1, max_len=128,
                          dtype="float32", cache_kind="paged",
                          page_size=16, prefix_sharing=sharing)
        a, b = mk(0), mk(1)
        eng.run(a)          # A finishes -> its pages (incl. the partial
        eng.run(b)          # tail) seed the index for B
        return _outs(a) + _outs(b), eng

    want, _ = serve(False)
    got, eng = serve(True)
    assert got == want
    assert eng.stats["prefix_hits"] >= 1
    # B matched 40 tokens = 2 full pages + 8 tokens into a shared page
    assert eng.stats["prefix_tokens_saved"] >= 40
    assert eng.stats["cow_forks"] >= 1
    # no page is writable while shared: after the run every live page
    # is referenced only by the index
    kv = eng.kv
    assert kv.live_pages + kv.free_page_count == kv.usable_pages
    assert kv.live_pages == eng.stats["prefix_cached_pages"]


def test_identical_prompt_rerun_is_a_full_cache_hit():
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    eng = ServeEngine(cfg, p, batch_size=1, max_len=128, dtype="float32",
                      cache_kind="paged", page_size=16)
    prompt = (np.arange(40, dtype=np.int32) * 5 + 2) % cfg.vocab_size
    a = [Request(prompt=prompt.copy(), max_new_tokens=5)]
    eng.run(a)
    t0 = eng.stats["prefill_tokens"]
    b = [Request(prompt=prompt.copy(), max_new_tokens=5)]
    eng.run(b)
    assert _outs(a) == _outs(b)
    # all but the last prompt token come from the index (the last one
    # must run to produce the first-token logits)
    assert eng.stats["prefill_tokens"] - t0 == 1
    assert eng.stats["prefix_tokens_saved"] >= 39


# ---------------------------------------------------------------------------
# scheduler interactions
# ---------------------------------------------------------------------------

def test_preemption_resume_rematches_own_prefix():
    """Preemption drops a sequence's page references but the index
    keeps its full prompt pages alive — the resumed request re-matches
    them, making recompute-on-resume cheaper AND staying exact."""
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    mk = lambda: [Request(prompt=(np.arange(10) * 3 + i).astype(np.int32)
                          % cfg.vocab_size, max_new_tokens=14)
                  for i in range(2)]
    want = mk()
    ServeEngine(cfg, p, batch_size=2, max_len=64, dtype="float32").run(want)
    eng = ServeEngine(cfg, p, batch_size=2, max_len=64, dtype="float32",
                      cache_kind="paged", page_size=8, n_pages=6)
    got = mk()
    eng.run(got)
    assert eng.sched.preemptions > 0
    assert _outs(got) == _outs(want)


def test_index_pages_are_reclaimed_under_pressure():
    """Index-retained pages must never wedge admission: when the pool
    is dominated by cached prefixes, admission reclaims them (LRU)
    instead of stalling."""
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    eng = ServeEngine(cfg, p, batch_size=2, max_len=48, dtype="float32",
                      cache_kind="paged", page_size=8, n_pages=7)
    # distinct prompts: each finished request parks pages in the index,
    # so later admissions must evict cached pages to proceed
    reqs = [Request(prompt=(np.arange(10) + 17 * i).astype(np.int32)
                    % cfg.vocab_size, max_new_tokens=4) for i in range(5)]
    eng.run(reqs)
    assert all(r.done and len(r.out) == 4 for r in reqs)
    assert eng._prefix.evictions > 0
    kv = eng.kv
    assert kv.live_pages + kv.free_page_count == kv.usable_pages


def test_dense_engine_unaffected_by_prefix_flag():
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    eng = ServeEngine(cfg, p, batch_size=2, max_len=48, dtype="float32",
                      prefix_sharing=True)
    assert eng._prefix is None      # dense has no pages to share
    r = [Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=3)]
    eng.run(r)
    assert len(r[0].out) == 3


# ---------------------------------------------------------------------------
# paged + MLA: latent pages, not K/V pages
# ---------------------------------------------------------------------------

def test_paged_engine_on_mla_config_pages_the_latent():
    """MLA rides the paged engine (tests/test_model_zoo_serve.py has the
    conformance matrix); here: the pool's pages hold the compressed
    latent — (kv_lora + rope) floats per token — not 2*H*hd K/V."""
    cfg = smoke_config("minicpm3-4b").replace(dtype="float32")
    assert cfg.mla is not None
    eng = ServeEngine(cfg, None, cache_kind="paged", page_size=8)
    n_attn = sum(s.kind == "attn" for s in cfg.pattern) * (
        cfg.n_layers // len(cfg.pattern))
    latent = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
    assert eng.kv.bytes_per_page() == latent * 4 * 8 * n_attn


# ---------------------------------------------------------------------------
# radix index unit behaviour (no engine, no device pool)
# ---------------------------------------------------------------------------

def _bare_kv(n_pages=17, page=4, seqs=4):
    return PagedKVCache(None, n_pages=n_pages, page_size=page,
                        max_seqs=seqs, create_pool=False)


def test_radix_lookup_and_partial_match():
    kv = _bare_kv()
    idx = RadixPrefixCache(kv)
    s = kv.alloc_slot()
    toks = list(range(100, 111))            # 11 tokens, page=4
    kv.ensure(s, len(toks))                 # 3 pages
    pages = kv.owned_pages(s)
    idx.insert(np.asarray(toks), pages)
    # full match through the chain incl. the partial tail
    n, got = idx.lookup(np.asarray(toks))
    assert n == 11 and got == pages
    # mid-page divergence: 6 matching tokens -> 1 full page + 2 into
    # the second (the borrower would COW-fork it)
    n, got = idx.lookup(np.asarray(toks[:6] + [999, 998]))
    assert n == 6 and got == pages[:2]
    # divergence at token 0 -> no match
    n, got = idx.lookup(np.asarray([7, 8, 9]))
    assert n == 0 and got == []
    # max_tokens cap (the engine always leaves >= 1 token to prefill)
    n, got = idx.lookup(np.asarray(toks), max_tokens=8)
    assert n == 8 and got == pages[:2]


def test_radix_eviction_is_leaf_first_lru_and_respects_refcounts():
    kv = _bare_kv()
    idx = RadixPrefixCache(kv)
    s = kv.alloc_slot()
    kv.ensure(s, 8)
    a = kv.owned_pages(s)
    idx.insert(np.arange(8), a)             # chain of 2 full nodes
    kv.release(s)                           # index-only now
    s2 = kv.alloc_slot()
    kv.ensure(s2, 4)
    b = kv.owned_pages(s2)
    idx.insert(np.asarray([50, 51, 52, 53]), b)
    kv.release(s2)
    idx.lookup(np.arange(8))                # chain `a` is now MRU
    assert idx.cached_pages() == 3
    freed = idx.evict(1)
    assert freed == 1
    # LRU branch (b) went first; the hot chain survives
    assert idx.lookup(np.arange(8))[0] == 8
    assert idx.lookup(np.asarray([50, 51, 52, 53]))[0] == 0
    # leaf-first: evicting the deep chain frees the leaf before the root
    assert idx.evict(10) == 2
    assert idx.cached_pages() == 0
    assert kv.free_page_count == kv.usable_pages


def test_radix_eviction_is_hit_rate_aware_cold_first():
    kv = _bare_kv()
    idx = RadixPrefixCache(kv)
    # chain `hot`: inserted FIRST (older) but earns lookup hits
    s = kv.alloc_slot()
    kv.ensure(s, 4)
    hot = kv.owned_pages(s)
    idx.insert(np.asarray([1, 2, 3, 4]), hot)
    kv.release(s)
    # chain `cold`: inserted later (more recent tick), never looked up
    s2 = kv.alloc_slot()
    kv.ensure(s2, 4)
    cold = kv.owned_pages(s2)
    idx.insert(np.asarray([50, 51, 52, 53]), cold)
    kv.release(s2)
    for _ in range(3):                       # warm the hot chain
        assert idx.lookup(np.asarray([1, 2, 3, 4]))[0] == 4
    # re-insert cold so its last_used tick is the newest of all nodes:
    # pure LRU would now evict `hot`; hit-aware eviction must not
    idx.insert(np.asarray([50, 51, 52, 53]), cold)
    assert idx.cached_pages() == 2
    assert idx.evict(1) == 1
    # cold-first: the recent-but-never-hit chain dies, the hot one lives
    assert idx.lookup(np.asarray([1, 2, 3, 4]))[0] == 4
    assert idx.lookup(np.asarray([50, 51, 52, 53]))[0] == 0
    assert idx.evictions >= 1


def test_radix_hit_rate_counters():
    kv = _bare_kv()
    idx = RadixPrefixCache(kv)
    s = kv.alloc_slot()
    kv.ensure(s, 4)
    idx.insert(np.asarray([1, 2, 3, 4]), kv.owned_pages(s))
    kv.release(s)
    assert idx.lookups == 0 and idx.hit_rate == 0.0
    idx.lookup(np.asarray([1, 2, 3, 4]))     # match
    idx.lookup(np.asarray([9, 9, 9, 9]))     # miss
    assert idx.lookups == 2
    # `hits` counts admissions the scheduler served from the index; the
    # miss lookup must not move it
    idx.hits += 1                            # scheduler contract for the match
    assert idx.hit_rate == pytest.approx(0.5)


def test_radix_survives_compact_remap():
    cfg = _tiny_cfg()
    kv = PagedKVCache(cfg, n_pages=9, page_size=4, max_seqs=2,
                      max_pages_per_seq=4, dtype="float32")
    idx = RadixPrefixCache(kv)
    s0, s1 = kv.alloc_slot(), kv.alloc_slot()
    kv.ensure(s0, 4)
    kv.ensure(s1, 8)
    idx.insert(np.asarray([1, 2, 3, 4]), kv.owned_pages(s0))
    kv.release(s0)                          # hole at page id 1
    kv.compact()
    # the index's page ids were remapped with the pool move
    n, pages = idx.lookup(np.asarray([1, 2, 3, 4, 9]))
    assert n == 4
    assert pages[0] in {p for sl in (s1,) for p in kv.owned_pages(sl)} \
        or kv.refcount(pages[0]) == 1
    assert kv.live_pages + kv.free_page_count == kv.usable_pages
    # a fresh slot can attach the remapped page and fork it on write
    s2 = kv.alloc_slot()
    kv.share(s2, pages)
    kv.ensure(s2, 6)
    copies = kv.cow_for_write(s2, 2, 6)
    assert len(copies) == 1 and copies[0][0] == pages[0]
    assert kv.refcount(kv.owned_pages(s2)[0]) == 1


def test_lookup_count_false_keeps_hit_rate_counters():
    kv = _bare_kv()
    idx = RadixPrefixCache(kv)
    s = kv.alloc_slot()
    kv.ensure(s, 8)
    idx.insert(np.asarray([1, 2, 3, 4, 5, 6, 7, 8]), kv.owned_pages(s))
    kv.release(s)
    n, _ = idx.lookup(np.asarray([1, 2, 3, 4]))
    node = idx.root.children[(0, (1, 2, 3, 4))]   # (shard, edge tokens)
    assert n == 4 and idx.lookups == 1 and node.hits == 1
    tick = node.last_used
    n, _ = idx.lookup(np.asarray([1, 2, 3, 4]), count=False)
    assert n == 4
    # the retry is the same admission: counters frozen, recency moves
    assert idx.lookups == 1 and node.hits == 1
    assert node.last_used > tick


def test_reclaim_rounds_count_one_lookup_per_admission():
    """try_admit re-runs the prefix match after every reclaim round; a
    two-round admission must still be ONE lookup in the hit-rate stats
    (the old per-round counting inflated the denominator and the node
    warmth)."""
    from repro.serve.scheduler import Scheduler

    cfg = _tiny_cfg()
    kv = PagedKVCache(cfg, n_pages=5, page_size=4, max_seqs=2,
                      dtype="float32")
    idx = RadixPrefixCache(kv)
    sched = Scheduler(kv, prefix=idx)
    s = kv.alloc_slot()
    kv.ensure(s, 8)
    idx.insert(np.asarray([1, 2, 3, 4, 5, 6, 7, 8]), kv.owned_pages(s))
    kv.release(s)
    assert kv.free_page_count == 2          # 4 usable, 2 index-retained
    # 11 unmatched tokens need 4 pages (prompt+decode+watermark) > 2
    # free -> the index is reclaimed, then the match re-runs before
    # admission succeeds
    sched.submit(Request(prompt=np.arange(100, 111).astype(np.int32),
                         max_new_tokens=2))
    assert sched.try_admit() is not None
    assert idx.lookups == 1
