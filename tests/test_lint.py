"""repro-lint framework tests: one positive (fires) and one negative
(stays quiet) fixture tree per rule, the baseline round-trip, and the
pin that the real tree matches the committed baseline exactly.

Fixture trees are built under tmp_path with the same layout the rules
expect (src/repro/kernels, docs/, tests/ ...) — AnalysisContext is
rooted at an arbitrary directory precisely so rules are testable on
synthetic mini-trees.
"""
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.baseline import (load_baseline, partition,
                                     render_baseline)
from repro.analysis.context import AnalysisContext
from repro.analysis.finding import Finding, sort_findings
from repro.analysis.registry import available_rules, get_rule, run_rules

REPO = Path(__file__).resolve().parents[1]
BASELINE = REPO / "tools" / "repro_lint_baseline.txt"


def tree(root, files: dict):
    """Materialize {relpath: source} under root, return a context."""
    for rel, src in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src)
    return AnalysisContext(root)


def run(rule_id, ctx):
    return get_rule(rule_id).run(ctx)


# ---------------------------------------------------------------------------
# framework basics
# ---------------------------------------------------------------------------

def test_all_builtin_rules_registered():
    assert available_rules() == ["R001", "R002", "R003", "R004",
                                 "R005", "R006", "R007", "R008"]


def test_finding_ordering_and_key():
    a = Finding("R002", "b.py", 9, "zzz")
    b = Finding("R001", "a.py", 1, "mmm")
    assert sort_findings([a, b]) == [b, a]
    assert a.key() == "R002\tb.py\tzzz"          # line-free: move-stable
    assert "b.py:9" in a.render()


def test_parse_failure_is_a_finding_not_a_crash(tmp_path):
    ctx = tree(tmp_path, {"src/repro/kernels/bad.py": "def f(:\n"})
    assert run("R001", ctx) == []
    fails = ctx.parse_failures()
    assert len(fails) == 1 and fails[0].rule_id == "R000"


# ---------------------------------------------------------------------------
# R001 kernel/oracle parity
# ---------------------------------------------------------------------------

_KERNEL = "def my_kernel(x, codes, *, block_m=None, acc=None):\n    return x\n"


def test_r001_missing_oracle_fires(tmp_path):
    ctx = tree(tmp_path, {
        "src/repro/kernels/foo.py": _KERNEL,
        "src/repro/kernels/ref.py": "def other_ref(x):\n    return x\n",
    })
    msgs = [f.message for f in run("R001", ctx)]
    assert any("no `my_kernel_ref` oracle" in m for m in msgs)


def test_r001_oracle_and_test_satisfy(tmp_path):
    ctx = tree(tmp_path, {
        "src/repro/kernels/foo.py": _KERNEL,
        "src/repro/kernels/ref.py":
            "def my_kernel_ref(x, codes, *, acc=None):\n    return x\n",
        "tests/test_foo.py":
            "from repro.kernels.foo import my_kernel\n"
            "from repro.kernels.ref import my_kernel_ref\n",
    })
    assert run("R001", ctx) == []


def test_r001_signature_drift_fires(tmp_path):
    ctx = tree(tmp_path, {
        "src/repro/kernels/foo.py": _KERNEL,
        # positional order swapped and a non-tuning kwarg dropped
        "src/repro/kernels/ref.py":
            "def my_kernel_ref(codes, x):\n    return x\n",
        "tests/test_foo.py": "import my_kernel, my_kernel_ref\n",
    })
    msgs = [f.message for f in run("R001", ctx)]
    assert any("not a prefix" in m for m in msgs)
    assert any("missing from oracle" in m for m in msgs)


def test_r001_missing_test_fires(tmp_path):
    ctx = tree(tmp_path, {
        "src/repro/kernels/foo.py": "def my_kernel(x):\n    return x\n",
        "src/repro/kernels/ref.py":
            "def my_kernel_ref(x):\n    return x\n",
    })
    msgs = [f.message for f in run("R001", ctx)]
    assert any("kernel-vs-oracle test missing" in m for m in msgs)


# ---------------------------------------------------------------------------
# R002 jit ownership
# ---------------------------------------------------------------------------

def test_r002_stray_jit_fires(tmp_path):
    ctx = tree(tmp_path, {
        "src/repro/serve/engine2.py":
            "import jax\nstep = jax.jit(lambda x: x)\n",
    })
    assert any("outside" in f.message for f in run("R002", ctx))


def test_r002_owner_and_aliases(tmp_path):
    ctx = tree(tmp_path, {
        # the owner may jit; an alias elsewhere still fires
        "src/repro/serve/compile_cache.py":
            "import jax\nf = jax.jit(lambda x: x)\n",
        "src/repro/quant/sneaky.py":
            "from jax import jit as J\ng = J(lambda x: x)\n",
    })
    findings = run("R002", ctx)
    assert [f.file for f in findings] == ["src/repro/quant/sneaky.py"]


def test_r002_speculative_step_jits_must_live_in_compile_cache(tmp_path):
    """The speculative draft/verify steps are jitted wrappers like any
    other engine step: an engine module jitting them directly (instead
    of borrowing from serve/compile_cache.py) escapes the process-wide
    warmup sharing and fires."""
    ctx = tree(tmp_path, {
        "src/repro/serve/spec_engine.py": (
            "import jax\n"
            "from repro.models import verify_paged\n"
            "draft_step = jax.jit(lambda p, c, t: t)\n"
            "verify_step = jax.jit(verify_paged, donate_argnums=(1,))\n"),
        "src/repro/serve/compile_cache.py":
            "import jax\nf = jax.jit(lambda x: x)\n",
    })
    findings = run("R002", ctx)          # one finding per offending file
    assert [f.file for f in findings] == ["src/repro/serve/spec_engine.py"]
    assert all("outside" in f.message for f in findings)


def test_r002_nonliteral_static_args_fire_even_in_owner(tmp_path):
    ctx = tree(tmp_path, {
        "src/repro/serve/compile_cache.py":
            "import jax\nNAMES = ('a',)\n"
            "f = jax.jit(lambda a: a, static_argnames=NAMES)\n",
    })
    assert any("not a literal" in f.message for f in run("R002", ctx))


# ---------------------------------------------------------------------------
# R003 tracer hygiene
# ---------------------------------------------------------------------------

_JIT_HDR = "import jax\nimport functools\n"


def test_r003_branch_on_traced_param_fires(tmp_path):
    ctx = tree(tmp_path, {"src/repro/quant/f.py": _JIT_HDR + (
        "@jax.jit\n"
        "def f(x):\n"
        "    y = x + 1\n"
        "    if y > 0:\n"
        "        return y\n"
        "    return int(x)\n")})
    msgs = [f.message for f in run("R003", ctx)]
    assert any("Python `if`" in m for m in msgs)
    assert any("int() forces" in m for m in msgs)


def test_r003_shape_metadata_is_static(tmp_path):
    ctx = tree(tmp_path, {"src/repro/quant/f.py": _JIT_HDR + (
        "@functools.partial(jax.jit, static_argnames=('n',))\n"
        "def f(x, n):\n"
        "    M, K = x.shape\n"
        "    if M != n:\n"
        "        x = x[:n]\n"
        "    for _ in range(len(x.shape)):\n"
        "        pass\n"
        "    return x\n")})
    assert run("R003", ctx) == []


def test_r003_pallas_kernel_body(tmp_path):
    ctx = tree(tmp_path, {"src/repro/kernels/k.py": (
        "from jax.experimental import pallas as pl\n"
        "import functools\n"
        "def _kern(x_ref, o_ref, *, bk):\n"
        "    v = x_ref[0, 0]\n"
        "    while v > 0:\n"
        "        v = v - 1\n"
        "def entry(x):\n"
        "    return pl.pallas_call(functools.partial(_kern, bk=8))(x)\n")})
    msgs = [f.message for f in run("R003", ctx)]
    assert any("Python `while`" in m for m in msgs)


# ---------------------------------------------------------------------------
# R004 tiling contracts
# ---------------------------------------------------------------------------

def test_r004_magic_literal_fires(tmp_path):
    ctx = tree(tmp_path, {"src/repro/kernels/k.py": (
        "def k(x, block_m=100):\n"
        "    return g(x, block_k=48)\n")})
    msgs = [f.message for f in run("R004", ctx)]
    assert any("magic literal 100" in m for m in msgs)
    assert any("magic literal 48" in m for m in msgs)


def test_r004_named_constants_checked_and_satisfy(tmp_path):
    ctx = tree(tmp_path, {"src/repro/kernels/k.py": (
        "BLOCK_M = 128\nBLOCK_N = 256\nBLOCK_K = 96\n"
        "GROUP_SIZE = 64\n"
        "def k(x, block_m=BLOCK_M, block_k=None):\n"
        "    return x\n")})
    assert run("R004", ctx) == []
    ctx2 = tree(tmp_path / "bad", {"src/repro/kernels/k.py": (
        "BLOCK_M = 100\nBLOCK_N = 100\nBLOCK_K = 100\nGROUP_SIZE = 100\n")})
    msgs = [f.message for f in run("R004", ctx2)]
    assert len(msgs) == 4 and any("SUBLANE" in m for m in msgs) \
        and any("LANE" in m for m in msgs) \
        and any("pack word" in m for m in msgs)


def test_r004_layout_constants_owned_by_hw(tmp_path):
    ctx = tree(tmp_path, {"src/repro/quant/p.py": "WORD = 32\n"})
    assert any("redefines layout constant WORD" in f.message
               for f in run("R004", ctx))


# ---------------------------------------------------------------------------
# R005 registry/docs + EngineStats completeness
# ---------------------------------------------------------------------------

_QREG = ("from repro.quant.registry import register_quantizer\n"
         "@register_quantizer('zap')\n"
         "class Zap:\n    pass\n")


def test_r005_undocumented_name_fires(tmp_path):
    ctx = tree(tmp_path, {"src/repro/core/q.py": _QREG,
                          "docs/QUANT.md": "# quantizers\n"})
    assert any("`zap` not documented" in f.message
               for f in run("R005", ctx))


def test_r005_documented_name_satisfies(tmp_path):
    ctx = tree(tmp_path, {"src/repro/core/q.py": _QREG,
                          "docs/QUANT.md": "| `zap` | zaps |\n"})
    assert run("R005", ctx) == []


def test_r005_unpopulated_stats_field_fires(tmp_path):
    ctx = tree(tmp_path, {"src/repro/serve/stats.py": (
        "from dataclasses import dataclass\n"
        "@dataclass(frozen=True)\n"
        "class EngineStats:\n"
        "    tokens: int = 0\n"
        "    ghost: int = 0\n"
        "    @classmethod\n"
        "    def capture(cls, engine):\n"
        "        return cls(**{'tokens': 1})\n")})
    msgs = [f.message for f in run("R005", ctx)]
    assert any("EngineStats.ghost is never populated" in m for m in msgs)
    assert not any("tokens" in m for m in msgs)


def test_r005_speculation_stats_fields_must_be_populated(tmp_path):
    """The speculative counters are EngineStats fields like any other:
    declaring them without wiring capture() fires per missing field, and
    the fully-wired form (the real stats.py shape) stays quiet."""
    decl = ("from dataclasses import dataclass\n"
            "@dataclass(frozen=True)\n"
            "class EngineStats:\n"
            "    speculate_k: int = 0\n"
            "    draft_tokens: int = 0\n"
            "    accepted_tokens: int = 0\n"
            "    acceptance_rate: float = 0.0\n"
            "    @classmethod\n"
            "    def capture(cls, engine):\n")
    ctx = tree(tmp_path / "bad", {"src/repro/serve/stats.py": decl + (
        "        return cls(**{'speculate_k': 1})\n")})
    msgs = [f.message for f in run("R005", ctx)]
    for f in ("draft_tokens", "accepted_tokens", "acceptance_rate"):
        assert any(f"EngineStats.{f} is never populated" in m
                   for m in msgs), (f, msgs)
    assert not any("speculate_k" in m for m in msgs)
    ctx2 = tree(tmp_path / "ok", {"src/repro/serve/stats.py": decl + (
        "        s = dict(engine.stats)\n"
        "        return cls(**{'speculate_k': 1,\n"
        "                      'draft_tokens': s.get('draft_tokens', 0),\n"
        "                      'accepted_tokens': 0,\n"
        "                      'acceptance_rate': 0.0})\n")})
    assert run("R005", ctx2) == []


# ---------------------------------------------------------------------------
# R006 sharding coverage
# ---------------------------------------------------------------------------

_SHARDING = "KNOWN = {'k', 'v', 'ln'}\n"


def test_r006_unknown_leaf_fires(tmp_path):
    ctx = tree(tmp_path, {
        "src/repro/models/m.py": (
            "def init_m(cfg):\n"
            "    return {'mystery': zeros(), 'wq': zeros(),\n"
            "            'sub': init_other(cfg)}\n"),
        "src/repro/dist/sharding.py": _SHARDING,
    })
    findings = run("R006", ctx)
    msgs = [f.message for f in findings]
    assert any("`mystery`" in m for m in msgs)
    # w* leaves match the matmul rule; init_* values are subtrees
    assert not any("wq" in m or "sub" in m for m in msgs)


def test_r006_known_and_subscript_leaves(tmp_path):
    ctx = tree(tmp_path, {
        "src/repro/models/m.py": (
            "def init_cache(cfg):\n"
            "    c = {'k': zeros(), 'v': zeros()}\n"
            "    c['ln'] = zeros()\n"
            "    return c\n"
            "def forward(p):\n"
            "    return {'not_checked': p}\n"),   # not an init_ function
        "src/repro/dist/sharding.py": _SHARDING,
    })
    assert run("R006", ctx) == []


# ---------------------------------------------------------------------------
# R007 docs links
# ---------------------------------------------------------------------------

def test_r007_dangling_refs_fire(tmp_path):
    ctx = tree(tmp_path, {
        "docs/GUIDE.md": ("see [x](missing.md) and `src/repro/gone.py` "
                          "for details\n"),
        "README.md": "[ok](docs/GUIDE.md) and `docs/GUIDE.md`\n",
    })
    findings = run("R007", ctx)
    assert {f.message.split("(")[0].strip() for f in findings} == {
        "dangling link", "stale file reference `src/repro/gone.py`"}
    assert all(f.file == "docs/GUIDE.md" for f in findings)


def test_r007_resolving_refs_satisfy(tmp_path):
    ctx = tree(tmp_path, {
        "docs/GUIDE.md": "[readme](../README.md) runs `tools/x.py` "
                         "and skips https://example.com plus `a.json`\n",
        "README.md": "hello\n",
        "tools/x.py": "pass\n",
    })
    assert run("R007", ctx) == []


# ---------------------------------------------------------------------------
# R008 no test shims
# ---------------------------------------------------------------------------

def test_r008_shim_module_and_sys_modules_fire(tmp_path):
    ctx = tree(tmp_path, {
        "tests/_thing_fallback.py": "st = None\n",
        "tests/test_a.py": ("import sys\n"
                            "sys.modules['hypothesis'] = object()\n"),
    })
    msgs = [f.message for f in run("R008", ctx)]
    assert any("fallback/shim module" in m for m in msgs)
    assert any("sys.modules" in m for m in msgs)


def test_r008_importerror_gate_is_fine(tmp_path):
    ctx = tree(tmp_path, {"tests/test_a.py": (
        "try:\n"
        "    from hypothesis import given, settings, strategies as st\n"
        "except ImportError:\n"
        "    given = settings = st = None\n")})
    assert run("R008", ctx) == []


# ---------------------------------------------------------------------------
# baseline round-trip
# ---------------------------------------------------------------------------

def test_baseline_roundtrip_and_partition(tmp_path):
    f1 = Finding("R004", "a.py", 3, "bad tile")
    f2 = Finding("R007", "b.md", 9, "dangling link (x)")
    path = tmp_path / "base.txt"
    path.write_text(render_baseline([f1, f2], {f1.key(): "grandfathered"}))
    base = load_baseline(path)
    assert base[f1.key()] == "grandfathered" and base[f2.key()] == ""

    f3 = Finding("R002", "c.py", 1, "stray jit")
    new, suppressed, stale = partition([f1, f3], base)
    assert new == [f3] and suppressed == [f1] and stale == [f2.key()]

    # determinism: same findings, same bytes
    assert render_baseline([f2, f1], base) == render_baseline([f1, f2],
                                                              base)


def test_baseline_rejects_malformed_lines(tmp_path):
    p = tmp_path / "base.txt"
    p.write_text("R004 a.py no-tabs-here\n")
    with pytest.raises(ValueError, match="malformed"):
        load_baseline(p)


# ---------------------------------------------------------------------------
# the real tree
# ---------------------------------------------------------------------------

def test_full_tree_matches_committed_baseline_exactly():
    """The repo itself is lint-clean modulo the committed baseline: no
    new findings AND no stale suppressions. This is the same contract
    the CI step enforces via the CLI exit code."""
    ctx = AnalysisContext(REPO)
    findings = ctx.parse_failures() + run_rules(ctx)
    new, _, stale = partition(findings, load_baseline(BASELINE))
    assert new == [], "\n".join(f.render() for f in new)
    assert stale == [], "\n".join(stale)


def test_cli_exits_nonzero_on_injected_violation(tmp_path):
    """End-to-end through the CLI: a bad fixture tree must fail, and
    --update-baseline must make the same tree pass."""
    root = tmp_path / "mini"
    (root / "src/repro/kernels").mkdir(parents=True)
    (root / "src/repro/kernels/k.py").write_text("BLOCK_K = 100\n")
    base = tmp_path / "base.txt"

    cmd = [sys.executable, str(REPO / "tools" / "repro_lint.py"),
           "--root", str(root), "--baseline", str(base)]
    r = subprocess.run(cmd, capture_output=True, text=True)
    assert r.returncode == 1 and "BLOCK_K" in r.stdout

    r = subprocess.run(cmd + ["--update-baseline"], capture_output=True,
                       text=True)
    assert r.returncode == 0
    r = subprocess.run(cmd, capture_output=True, text=True)
    assert r.returncode == 0, r.stdout
