"""Perf-trajectory bench subsystem: percentile statistics, the Metric
record, the versioned BENCH schema (round-trip + future-version
refusal), the deterministic noise-band diff gate, and the runner's
fail-path bookkeeping. Everything here is host-only and fast — these
tests pin the contracts CI's bench-quick job relies on."""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro.bench import (BenchContext, Metric, Scenario, SCHEMA_VERSION,
                         BenchSchemaError, counter, info, latency, make_doc,
                         percentile, run_one, summarize, throughput,
                         validate, write_doc)
from repro.bench.diff import (Verdict, diff_all, diff_docs, diff_metric,
                              relative_worsening)
from repro.bench.metrics import TIMING_NOISE
from repro.bench.schema import load_dir, load_doc


# ---------------------------------------------------------------------------
# percentile statistics
# ---------------------------------------------------------------------------

def test_percentile_matches_numpy_on_seeded_samples():
    rng = np.random.default_rng(42)
    for n in (1, 2, 3, 10, 101, 1000):
        samples = rng.lognormal(mean=-7, sigma=1.0, size=n).tolist()
        for q in (0, 10, 50, 90, 99, 100):
            ours = percentile(samples, q)
            ref = float(np.percentile(samples, q))  # default: linear interp
            assert ours == pytest.approx(ref, rel=1e-12), (n, q)


def test_percentile_rejects_bad_input():
    with pytest.raises(ValueError):
        percentile([], 50)
    with pytest.raises(ValueError):
        percentile([1.0], 101)
    with pytest.raises(ValueError):
        percentile([1.0], -1)


def test_summarize_fields():
    s = summarize([3.0, 1.0, 2.0])
    assert s["n"] == 3 and s["min"] == 1.0 and s["max"] == 3.0
    assert s["mean"] == pytest.approx(2.0)
    assert s["p50"] == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# Metric helpers
# ---------------------------------------------------------------------------

def test_metric_helpers_conventions():
    lat = latency([0.2, 0.1, 0.3])
    assert lat.value == pytest.approx(0.2)          # p50
    assert lat.noise == TIMING_NOISE and not lat.higher_is_better
    assert lat.percentiles["p99"] == pytest.approx(
        float(np.percentile([0.1, 0.2, 0.3], 99)))
    tput = throughput(123.0)
    assert tput.higher_is_better and tput.noise == TIMING_NOISE
    cnt = counter(7)
    assert cnt.noise == 0.0                         # exact at any scale
    inf = info(3.5)
    assert inf.noise is None                        # never gated
    with pytest.raises(ValueError):
        Metric(1.0, noise=-0.1)


# ---------------------------------------------------------------------------
# BENCH schema: round-trip + future-version refusal
# ---------------------------------------------------------------------------

def _doc(metrics=None, **kw):
    return make_doc("unit_scenario",
                    metrics if metrics is not None
                    else {"lat_s": latency([0.01, 0.02, 0.03]),
                          "hits": counter(5, higher_is_better=True),
                          "note": info(1.0)},
                    **kw)


def test_schema_roundtrip(tmp_path):
    doc = _doc(wall_s=1.5, quick=True, quant={"method": "gptqt", "bits": 3})
    path = write_doc(tmp_path / "BENCH_unit_scenario.json", doc)
    loaded = load_doc(path)
    assert loaded == doc
    assert loaded["bench_schema_version"] == SCHEMA_VERSION
    assert loaded["metrics"]["lat_s"]["percentiles"]["p50"] == \
        doc["metrics"]["lat_s"]["percentiles"]["p50"]
    assert loaded["metrics"]["note"]["noise"] is None
    assert loaded["machine"]["platform"] and loaded["git_sha"]
    by_name = load_dir(tmp_path)
    assert set(by_name) == {"unit_scenario"}


def test_schema_refuses_future_version(tmp_path):
    doc = _doc()
    doc["bench_schema_version"] = SCHEMA_VERSION + 1
    with pytest.raises(BenchSchemaError, match="future format"):
        validate(doc)
    # and via file I/O: a future file on disk must refuse to load
    p = tmp_path / "BENCH_unit_scenario.json"
    p.write_text(json.dumps(doc))
    with pytest.raises(BenchSchemaError, match="future format"):
        load_doc(p)


def test_schema_rejects_malformed():
    doc = _doc()
    for mutate in (
        lambda d: d.pop("bench_schema_version"),
        lambda d: d.update(bench_schema_version="1"),     # not an int
        lambda d: d.update(status="flaky"),
        lambda d: d.pop("machine"),
        lambda d: d["metrics"].update(bad={"unit": "s"}),  # no value
        lambda d: d["metrics"]["hits"].update(noise=-1.0),
    ):
        d = json.loads(json.dumps(doc))
        mutate(d)
        with pytest.raises(BenchSchemaError):
            validate(d)


# ---------------------------------------------------------------------------
# diff gate: direction-aware noise bands, deterministic verdicts
# ---------------------------------------------------------------------------

def test_relative_worsening_direction_aware():
    # lower is better: run growing is bad
    assert relative_worsening(10.0, 12.0, False) == pytest.approx(0.2)
    assert relative_worsening(10.0, 8.0, False) == pytest.approx(-0.2)
    # higher is better: run shrinking is bad
    assert relative_worsening(10.0, 8.0, True) == pytest.approx(0.2)
    # zero baseline: any worsening is infinite, exact zero stays ok
    assert relative_worsening(0.0, 1.0, False) == float("inf")
    assert relative_worsening(0.0, 0.0, False) == 0.0


def test_diff_metric_bands_and_scale():
    base = {"value": 100.0, "noise": 0.5, "higher_is_better": False}
    ok = diff_metric("s", "m", base, {"value": 149.0})
    assert ok.status == "ok" and not ok.failed
    bad = diff_metric("s", "m", base, {"value": 151.0})
    assert bad.status == "regressed" and bad.failed
    # widening the band (noisy CPU runner) forgives the same delta
    assert diff_metric("s", "m", base, {"value": 151.0},
                       noise_scale=2.0).status == "ok"
    # counters (noise 0) stay exact at ANY scale
    cnt = {"value": 4.0, "noise": 0.0, "higher_is_better": False}
    assert diff_metric("s", "m", cnt, {"value": 4.0},
                       noise_scale=100.0).status == "ok"
    assert diff_metric("s", "m", cnt, {"value": 5.0},
                       noise_scale=100.0).status == "regressed"
    # improvements never fail, even huge ones
    assert diff_metric("s", "m", base, {"value": 1.0}).status == "ok"
    # info metrics (noise null) are never gated
    assert diff_metric("s", "m", {"value": 1.0, "noise": None},
                       {"value": 99.0}).status == "info"
    # a metric the run no longer reports is a failure, not a skip
    assert diff_metric("s", "m", base, None).status == "missing"


def _pair(tmp_path, base_metrics, run_metrics):
    bdir, rdir = tmp_path / "base", tmp_path / "run"
    write_doc(bdir / "BENCH_s.json", make_doc("s", base_metrics))
    write_doc(rdir / "BENCH_s.json", make_doc("s", run_metrics))
    return load_dir(bdir), load_dir(rdir)


def test_diff_gate_identical_rerun_passes(tmp_path):
    metrics = {"lat_s": latency([0.01, 0.02]), "forks": counter(0)}
    baselines, runs = _pair(tmp_path, metrics, metrics)
    verdicts = diff_all(baselines, runs)
    assert verdicts and not any(v.failed for v in verdicts)
    # determinism: the same document pair always yields the same verdicts
    assert diff_all(baselines, runs) == verdicts


def test_diff_gate_doctored_regression_fails(tmp_path):
    baselines, runs = _pair(
        tmp_path,
        {"forks": counter(0), "lat_s": latency([0.010, 0.011])},
        {"forks": counter(3), "lat_s": latency([0.010, 0.011])})
    failed = [v for v in diff_all(baselines, runs) if v.failed]
    assert [v.metric for v in failed] == ["forks"]
    assert failed[0].worse_by == float("inf")       # 0 -> 3 counter


def test_diff_gate_missing_scenario_and_failed_run(tmp_path):
    bdir = tmp_path / "base"
    write_doc(bdir / "BENCH_s.json", make_doc("s", {"x": counter(1)}))
    baselines = load_dir(bdir)
    # run directory lost the scenario entirely
    assert diff_all(baselines, {}) == [Verdict("s", "", "missing")]
    # run exists but the scenario failed: its numbers gate nothing
    rdir = tmp_path / "run"
    write_doc(rdir / "BENCH_s.json",
              make_doc("s", {}, status="fail", error="boom"))
    verdicts = diff_docs(baselines["s"], load_dir(rdir)["s"])
    assert [v.status for v in verdicts] == ["missing"]


# ---------------------------------------------------------------------------
# runner: scenario failure is recorded, not swallowed
# ---------------------------------------------------------------------------

def test_run_one_records_failure_with_traceback():
    def boom(ctx):
        raise RuntimeError("scenario exploded")
    r = run_one(Scenario(name="boom", fn=boom), BenchContext())
    assert r.status == "fail" and not r.ok
    assert "scenario exploded" in r.error and "RuntimeError" in r.error
    doc = make_doc(r.name, r.metrics, status=r.status, error=r.error,
                   wall_s=r.wall_s)
    validate(doc)                        # fail docs are schema-valid too
    assert doc["status"] == "fail" and "exploded" in doc["error"]


def test_run_one_rejects_non_metric_returns():
    r = run_one(Scenario(name="bad", fn=lambda ctx: {"x": 1.0}),
                BenchContext())
    assert r.status == "fail" and "dict[str, Metric]" in r.error


def test_exit_code_semantics():
    from repro.bench import exit_code
    ok = run_one(Scenario(name="ok", fn=lambda ctx: {"x": counter(1)}),
                 BenchContext())
    bad = run_one(Scenario(name="bad", fn=lambda ctx: 1 / 0),
                  BenchContext())
    assert exit_code([ok]) == 0
    assert exit_code([ok, bad]) == 1
    assert exit_code([]) == 1            # an empty run must not gate green
