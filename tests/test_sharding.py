"""Distribution-layer unit tests on a small host mesh (4 fake devices via
subprocess would be heavy; these validate the RULES, and a 4-device
in-process mesh exercises pjit end-to-end numerically)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, smoke_config
from repro.dist.sharding import (batch_pspec, cache_pspec, param_pspec,
                                 params_shardings)
from repro.launch.mesh import make_production_mesh  # noqa: F401 (import ok)


class FakeMesh:
    """Shape-only stand-in so rule tests don't need 256 devices."""

    def __init__(self, shape, names):
        self.axis_names = names
        import numpy as _np
        self.devices = _np.empty(shape)


MESH = FakeMesh((16, 16), ("data", "model"))


def _spec(cfg, params_path_leaf):
    path, leaf = params_path_leaf
    return param_pspec(cfg, path, leaf, MESH)


def test_param_rules_qwen():
    cfg = get_config("qwen3-4b")
    p = jax.eval_shape(lambda k: __import__("repro.models.model",
                                            fromlist=["init_params"])
                       .init_params(cfg, k), jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_leaves_with_path(p)
    by_name = {}
    for path, leaf in flat:
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        by_name[name] = param_pspec(cfg, path, leaf, MESH)
    assert by_name["wq"] == P(None, "data", "model")
    assert by_name["wo"] == P(None, "model", "data")
    assert by_name["embed"] == P("model", "data")
    assert by_name["ln"] == P(None, None)


def test_expert_rules_ep_vs_tp():
    # qwen3-moe: 128 experts % 16 == 0 -> EP (E on model)
    cfg = get_config("qwen3-moe-235b-a22b")
    leaf = jax.ShapeDtypeStruct((cfg.n_groups, 128, 4096, 1536), jnp.bfloat16)
    path = (jax.tree_util.DictKey("blocks"), jax.tree_util.DictKey("L0"),
            jax.tree_util.DictKey("moe"), jax.tree_util.DictKey("wg"))
    assert param_pspec(cfg, path, leaf, MESH)[1] == "model"
    # mixtral: 8 experts % 16 != 0 -> TP inside experts
    cfg2 = get_config("mixtral-8x7b")
    leaf2 = jax.ShapeDtypeStruct((32, 8, 4096, 14336), jnp.bfloat16)
    spec2 = param_pspec(cfg2, path, leaf2, MESH)
    assert spec2[1] is None and spec2[3] == "model"


def test_divisibility_guard_drops_axis():
    cfg = get_config("minicpm3-4b")
    # vocab 73448 % 16 != 0 -> model axis dropped on embed vocab dim
    leaf = jax.ShapeDtypeStruct((73448, 2560), jnp.bfloat16)
    path = (jax.tree_util.DictKey("embed"),)
    spec = param_pspec(cfg, path, leaf, MESH)
    assert spec[0] is None


def test_kv_cache_seq_sharding_for_batch1():
    cfg = get_config("mixtral-8x7b")
    path = (jax.tree_util.DictKey("L0"), jax.tree_util.DictKey("k"))
    # B=128, kv_heads=8 < model=16: batch on data, SEQUENCE on model
    # (flash-decode partial softmax; EXPERIMENTS.md §Perf H1)
    leaf = jax.ShapeDtypeStruct((32, 128, 8, 4096, 128), jnp.bfloat16)
    s = cache_pspec(cfg, path, leaf, MESH)
    assert s[1] == "data" and s[3] == "model"
    # B=1: sequence over BOTH axes
    leaf1 = jax.ShapeDtypeStruct((32, 1, 8, 4096, 128), jnp.bfloat16)
    s1 = cache_pspec(cfg, path, leaf1, MESH)
    assert s1[1] is None and s1[3] == ("data", "model")
    # divisible kv heads (gemma2 kv=16): heads on model, seq unsharded
    cfg2 = get_config("gemma2-27b")
    leaf2 = jax.ShapeDtypeStruct((23, 128, 16, 4096, 128), jnp.bfloat16)
    s2 = cache_pspec(cfg2, path, leaf2, MESH)
    assert s2[2] == "model" and s2[3] is None


def test_qt_leaves_shard_like_dense():
    from repro.quant.abstract import quantized_leaf_abstract
    cfg = get_config("qwen3-4b")
    qt = quantized_leaf_abstract(
        jax.ShapeDtypeStruct((cfg.n_groups, 2560, 4096), jnp.bfloat16), 3)
    base = (jax.tree_util.DictKey("blocks"), jax.tree_util.DictKey("L0"),
            jax.tree_util.DictKey("attn"), jax.tree_util.DictKey("wq"))
    flat = jax.tree_util.tree_flatten_with_path(qt)[0]
    specs = {str(p[-1]): param_pspec(cfg, base + p, l, MESH) for p, l in flat}
    assert specs[".codes"] == P(None, None, "data", "model")
    assert specs[".alphas"] == P(None, None, "model", None)
    assert specs[".betas"] == P(None, None, "model")


def test_batch_pspec_fallbacks():
    pod_mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    assert batch_pspec(pod_mesh, 256) == P(("pod", "data"), None)
    assert batch_pspec(pod_mesh, 16) == P("data", None)  # 16 % 32 != 0
    assert batch_pspec(pod_mesh, 1) == P(None, None)


@pytest.mark.slow
def test_four_device_pjit_numeric():
    """End-to-end numeric check under a real (2,2) mesh in a subprocess
    with 4 fake devices: sharded forward == single-device forward."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import smoke_config
from repro.models import init_params, forward
from repro.dist.sharding import params_shardings, inputs_shardings
from repro.configs.base import ShapeSpec

cfg = smoke_config("qwen3-0.6b").replace(dtype="float32", d_model=64,
                                         n_heads=4, n_kv_heads=2, head_dim=16)
p = init_params(cfg, jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
want, _ = forward(cfg, p, toks)
mesh = jax.make_mesh((2, 2), ("data", "model"))
with mesh:
    psh = params_shardings(cfg, p, mesh)
    pp = jax.device_put(p, psh)
    f = jax.jit(lambda p_, t_: forward(cfg, p_, t_)[0], in_shardings=(psh, None))
    got = f(pp, toks)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)
print("PJIT-NUMERIC-OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**__import__("os").environ,
                                        "PYTHONPATH": "src"},
                       cwd=__import__("pathlib").Path(__file__).parents[1],
                       timeout=300)
    assert "PJIT-NUMERIC-OK" in r.stdout, r.stderr[-2000:]
