"""Self-speculative decoding exactness (serve/engine.py:_spec_decode_tick).

The invariant under test everywhere: with greedy acceptance, speculative
decode is token-identical to the dense-engine oracle for ANY draft —
the verify pass overwrites every speculatively-written K/V slot with the
target's own K/V before reading it (models/attention.py scatters before
gathering, causally masked), so a rejected draft leaves nothing behind
that the next tick can observe. Two draft regimes bracket the space:

  perfect      — draft_params IS the target: every proposal accepted,
                 ticks shrink by ~(k+1)x, rollback path never fires
  adversarial  — differently-seeded params: ~0 acceptance, every tick
                 speculates k tokens and rolls all of them back (the
                 page-boundary truncate path fires constantly)
"""
import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serve import Request, ServeEngine

KEY = jax.random.PRNGKey(0)
BATCH, MAX_LEN = 3, 48


def _cfg():
    return get_config("tiny-lm").replace(dtype="float32", n_layers=2,
                                         d_model=64, d_ff=128, remat="none")


_state = {}


def _setup():
    if _state:
        return _state
    cfg = _cfg()
    _state["cfg"] = cfg
    _state["params"] = init_params(cfg, KEY)
    # adversarial draft: a differently-seeded model proposes tokens the
    # target essentially never agrees with -> every tick rolls back
    _state["adversarial"] = init_params(cfg, jax.random.PRNGKey(1))
    _state["dense"] = ServeEngine(cfg, _state["params"], batch_size=BATCH,
                                  max_len=MAX_LEN, dtype="float32")
    _state["spec"] = {}
    return _state


def _spec_engine(k, draft, page_size=8):
    """Speculative engines are cached per (k, draft, page_size): the jit
    wrappers come from the process-wide compile cache but engine setup
    still costs allocator + mirror construction."""
    state = _setup()
    key = (k, draft, page_size)
    if key not in state["spec"]:
        dp = state["params"] if draft == "perfect" else state["adversarial"]
        state["spec"][key] = ServeEngine(
            state["cfg"], state["params"], batch_size=BATCH,
            max_len=MAX_LEN, dtype="float32", cache_kind="paged",
            page_size=page_size, speculate=k, draft_params=dp)
    return state["spec"][key]


def _reqs(n=3, seed=0, max_new=12):
    rng = np.random.default_rng(seed)
    cfg = _setup()["cfg"]
    return [(rng.integers(1, cfg.vocab_size,
                          int(rng.integers(4, 14))).astype(np.int32),
             max_new) for _ in range(n)]


def _serve(eng, reqs):
    rs = [Request(prompt=p.copy(), max_new_tokens=n) for p, n in reqs]
    eng.run(rs)
    return [r.out for r in rs]


def _check_pool(kv):
    assert kv.live_pages + kv.free_page_count == kv.usable_pages
    for s in range(kv.max_seqs):
        assert not kv.owned_pages(s)


@pytest.mark.parametrize("k", [1, 2, 4])
@pytest.mark.parametrize("draft", ["perfect", "adversarial"])
def test_speculative_matches_dense_oracle(k, draft):
    state = _setup()
    eng = _spec_engine(k, draft)
    reqs = _reqs(seed=10 * k)
    want = _serve(state["dense"], reqs)
    d0, a0 = eng.stats["draft_tokens"], eng.stats["accepted_tokens"]
    got = _serve(eng, reqs)
    assert got == want, (k, draft)
    _check_pool(eng.kv)
    drafted = eng.stats["draft_tokens"] - d0
    accepted = eng.stats["accepted_tokens"] - a0
    assert drafted > 0
    rate = accepted / drafted
    if draft == "perfect":
        assert rate == 1.0          # the draft IS the target
    else:
        assert rate < 0.5           # rollback path exercised hard


def test_perfect_draft_cuts_ticks():
    """k=4 with a perfect draft must finish in far fewer target ticks
    than vanilla decode — the speedup mechanism itself, independent of
    wall-clock noise. Every accepted tick emits k+1 tokens."""
    state = _setup()
    reqs = _reqs(n=2, seed=3, max_new=16)
    vanilla = ServeEngine(state["cfg"], state["params"], batch_size=BATCH,
                          max_len=MAX_LEN, dtype="float32",
                          cache_kind="paged", page_size=8)
    want = _serve(vanilla, reqs)
    t_vanilla = vanilla.stats["ticks"]
    eng = _spec_engine(4, "perfect")
    t0 = eng.stats["ticks"]
    got = _serve(eng, reqs)
    assert got == want
    assert (eng.stats["ticks"] - t0) * 2 <= t_vanilla


def test_page_boundary_rollbacks_stay_exact():
    """Tiny pages + zero-acceptance draft: every tick writes draft K/V
    across a page boundary, allocates the pages for it, then truncates
    them all back. Outputs must still match the oracle and the pool must
    balance — the truncate path (serve/kv_cache.py) is the whole test."""
    state = _setup()
    eng = _spec_engine(4, "adversarial", page_size=4)
    # prompt lengths straddling page multiples: pos lands on/next to a
    # boundary so speculative writes always cross into a fresh page
    reqs = [((np.arange(L) * 3 + L).astype(np.int32)
             % state["cfg"].vocab_size, 10) for L in (3, 4, 5, 8, 9)]
    want = _serve(state["dense"], reqs)
    alloc0 = eng.kv.pages_allocated
    got = _serve(eng, reqs)
    assert got == want
    _check_pool(eng.kv)
    # speculation really over-allocated (then returned) boundary pages:
    # strictly more page traffic than the tokens kept needed
    kept_pages = sum(eng.kv.pages_for(len(p) + n) for p, n in reqs)
    assert eng.kv.pages_allocated - alloc0 > kept_pages


def test_shared_prefix_with_speculation():
    """Prefix sharing composes with speculation: attached shared pages
    fork copy-on-write before draft K/V lands in them, and rollbacks
    never truncate below the accepted position, so the radix index stays
    consistent across requests."""
    state = _setup()
    eng = _spec_engine(2, "adversarial")
    base = (np.arange(12) * 5 + 1).astype(np.int32) % state["cfg"].vocab_size
    reqs = [(np.concatenate([base, np.asarray([7 + i], np.int32)]), 8)
            for i in range(4)]
    want = _serve(state["dense"], reqs)
    eng._prefix.clear()
    h0 = eng.stats.get("prefix_hits", 0)
    got = _serve(eng, reqs)
    assert got == want
    assert eng.stats["prefix_hits"] > h0
    _check_pool(eng.kv)


def test_typical_acceptance_perfect_draft_exact_lossy_otherwise():
    """accept_rule='typical': a perfect draft proposes the target's own
    argmax, which always clears the tau threshold -> still exact. An
    adversarial draft may keep sub-argmax tokens the target deems
    typical — allowed to diverge, but must emit full-length outputs and
    keep the pool balanced."""
    state = _setup()
    reqs = _reqs(n=2, seed=42, max_new=10)
    want = _serve(state["dense"], reqs)
    exact = ServeEngine(state["cfg"], state["params"], batch_size=BATCH,
                        max_len=MAX_LEN, dtype="float32",
                        cache_kind="paged", page_size=8, speculate=2,
                        draft_params=state["params"],
                        accept_rule="typical")
    assert _serve(exact, reqs) == want
    lossy = ServeEngine(state["cfg"], state["params"], batch_size=BATCH,
                        max_len=MAX_LEN, dtype="float32",
                        cache_kind="paged", page_size=8, speculate=2,
                        draft_params=state["adversarial"],
                        accept_rule="typical")
    outs = _serve(lossy, reqs)
    assert [len(o) for o in outs] == [n for _, n in reqs]
    _check_pool(lossy.kv)


def test_quantized_self_draft_is_free_and_exact():
    """The real artifact story: GPTQT-packed params serve as their own
    draft (leading code planes + re-fit scales). Speculative output is
    token-identical to the non-speculative paged engine on the same
    quantized params, and the draft tree adds exactly its scale bytes —
    the sign codes and every unquantized leaf are shared by reference."""
    from repro.core import quantize_model
    from repro.quant import QuantSpec, QuantizedTensor
    from repro.quant.draft import draft_extra_bytes
    cfg = _cfg()
    p = init_params(cfg, KEY)
    calib = [jax.random.randint(jax.random.fold_in(KEY, i), (2, 48), 0,
                                cfg.vocab_size) for i in range(2)]
    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed")
    qp, _ = quantize_model(cfg, p, calib, spec=spec)
    reqs = _reqs(n=3, seed=5, max_new=8)
    plain = ServeEngine(cfg, qp, batch_size=BATCH, max_len=MAX_LEN,
                        dtype="float32", cache_kind="paged", page_size=8)
    want = _serve(plain, reqs)
    eng = ServeEngine(cfg, qp, batch_size=BATCH, max_len=MAX_LEN,
                      dtype="float32", cache_kind="paged", page_size=8,
                      speculate=2, draft_bits=2)   # auto draft from codes
    assert _serve(eng, reqs) == want
    _check_pool(eng.kv)
    extra = draft_extra_bytes(qp, eng.draft_params)
    scale_bytes = sum(
        int(l.alphas.size) * l.alphas.dtype.itemsize
        + int(l.betas.size) * l.betas.dtype.itemsize
        for l in jax.tree.leaves(
            eng.draft_params,
            is_leaf=lambda x: isinstance(x, QuantizedTensor))
        if isinstance(l, QuantizedTensor))
    assert extra == scale_bytes
    # the draft really runs at fewer active planes over the same codes
    for leaf in jax.tree.leaves(
            eng.draft_params,
            is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        if isinstance(leaf, QuantizedTensor):
            assert leaf.bits == 2 and leaf.stored_bits == 3


def test_engine_stats_speculation_fields():
    """EngineStats.capture populates the speculation counters and the
    derived acceptance_rate; a non-speculative engine reports zeros."""
    state = _setup()
    eng = _spec_engine(2, "perfect")
    _serve(eng, _reqs(n=2, seed=9, max_new=6))
    st = eng.stats_snapshot()
    assert st.speculate_k == 2 and st.draft_bits == 2
    assert st.draft_tokens > 0
    assert st.accepted_tokens == eng.stats["accepted_tokens"]
    assert st.acceptance_rate == st.accepted_tokens / st.draft_tokens
    plain = state["dense"].stats_snapshot()
    assert plain.speculate_k == 0 and plain.draft_tokens == 0
    assert plain.acceptance_rate == 0.0


def test_speculative_trace_amortization():
    """One engine, wildly varying accept/rollback counts per tick: the
    draft and verify jits must each hold ONE trace (fixed k+1 token
    width; per-row n_valid/live masks carry the variation), and the COW
    copy jit's pow2 bucketing bounds its growth by the bucket count, not
    the number of distinct fork-list lengths."""
    eng = _spec_engine(4, "adversarial")
    sizes0 = {n: getattr(eng, n)._cache_size()
              for n in ("_draft_propose", "_verify", "_copy")}
    for seed in range(3):
        _serve(eng, _reqs(n=4, seed=seed, max_new=9))
    # shared-prefix wave: COW forks of varying counts on top of rollback
    base = (np.arange(10) + 2).astype(np.int32)
    _serve(eng, [(np.concatenate([base[:c], np.asarray([c], np.int32)]), 5)
                 for c in (4, 6, 8, 10)])
    grow = {n: getattr(eng, n)._cache_size() - sizes0[n]
            for n in sizes0}
    assert grow["_draft_propose"] <= 1
    assert grow["_verify"] <= 1
    # pow2 buckets for 1..max fork-lists: at most log2 distinct shapes
    assert grow["_copy"] <= 4
