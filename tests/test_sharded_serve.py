"""Sharded serving stack: per-shard allocator invariants, shard-local
prefix index, the shared mesh-keyed compile cache, and — when the host
exposes >= 2 devices (CI runs this file under
XLA_FLAGS=--xla_force_host_platform_device_count=2) — sharded-vs-
single-device greedy equivalence and packed-artifact mesh loading."""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serve import (OutOfPages, PagedKVCache, RadixPrefixCache,
                         Request, ServeEngine)
from repro.serve import compile_cache

KEY = jax.random.PRNGKey(0)

needs2 = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=2)")


def _tiny_cfg():
    return get_config("tiny-lm").replace(dtype="float32", n_layers=2,
                                         d_model=64, d_ff=128, remat="none")


def _mesh2():
    return jax.make_mesh((2, 1), ("data", "model"))


def _kv(n_pages=16, page_size=4, max_seqs=4, n_shards=2, **kw):
    return PagedKVCache(None, n_pages=n_pages, page_size=page_size,
                        max_seqs=max_seqs, n_shards=n_shards,
                        create_pool=False, **kw)


def _check_shard_invariants(kv):
    """The global allocator invariants, plus their per-shard versions
    and page locality (every owned page in its slot's shard)."""
    assert kv.live_pages + kv.free_page_count == kv.usable_pages
    for sh in range(kv.n_shards):
        assert kv.live_in_shard(sh) + kv.free_in_shard(sh) \
            == kv.usable_in_shard(sh)
        reserve = kv.null_page_of_shard(sh)
        assert kv.refcount(reserve) == 0
        assert reserve not in kv._free
    for s in range(kv.max_seqs):
        for pid in kv.owned_pages(s):
            assert kv.shard_of_page(pid) == kv.shard_of_slot(s)
            assert pid not in [kv.null_page_of_shard(x)
                               for x in range(kv.n_shards)]


# ---------------------------------------------------------------------------
# allocator: per-shard accounting (host-only, no devices needed)
# ---------------------------------------------------------------------------

def test_shard_geometry_and_reserve_pages():
    kv = _kv(n_pages=16, max_seqs=4, n_shards=2)
    assert kv.pages_per_shard == 8 and kv.seqs_per_shard == 2
    assert kv.usable_pages == 14 and kv.usable_in_shard(0) == 7
    assert kv.null_page_of_shard(0) == 0 and kv.null_page_of_shard(1) == 8
    assert kv.shard_of_slot(0) == 0 and kv.shard_of_slot(3) == 1
    assert kv.shard_of_page(7) == 0 and kv.shard_of_page(8) == 1
    # unsharded degenerates to the original layout
    kv1 = _kv(n_pages=9, max_seqs=3, n_shards=1)
    assert kv1.usable_pages == 8 and kv1.null_page_of_shard(0) == 0


def test_alloc_stays_in_slot_shard():
    kv = _kv()
    s0 = kv.alloc_slot(shard=0)
    s1 = kv.alloc_slot(shard=1)
    assert kv.shard_of_slot(s0) == 0 and kv.shard_of_slot(s1) == 1
    kv.ensure(s0, 10)                  # 3 pages from shard 0
    kv.ensure(s1, 6)                   # 2 pages from shard 1
    _check_shard_invariants(kv)
    assert kv.free_in_shard(0) == 4 and kv.free_in_shard(1) == 5


def test_out_of_pages_is_per_shard():
    kv = _kv(n_pages=8, page_size=4, max_seqs=2, n_shards=2,
             max_pages_per_seq=6)
    s0 = kv.alloc_slot(shard=0)
    kv.ensure(s0, 3 * 4)               # all 3 usable shard-0 pages
    with pytest.raises(OutOfPages):    # shard 1 has 3 free, irrelevant
        kv.ensure(s0, 4 * 4)
    _check_shard_invariants(kv)        # failed ensure allocated nothing
    s1 = kv.alloc_slot(shard=1)
    kv.ensure(s1, 3 * 4)               # the other shard still serves
    _check_shard_invariants(kv)


def test_cow_fork_and_release_stay_in_shard():
    kv = _kv()
    donor = kv.alloc_slot(shard=1)
    kv.ensure(donor, 8)                # 2 shard-1 pages
    fresh = kv.alloc_slot(shard=1)
    kv.share(fresh, kv.owned_pages(donor))
    copies = kv.cow_for_write(fresh, 0, 8)
    assert copies and all(kv.shard_of_page(d) == 1 for _, d in copies)
    _check_shard_invariants(kv)
    kv.release(donor)
    kv.release(fresh)
    _check_shard_invariants(kv)
    assert kv.free_in_shard(1) == kv.usable_in_shard(1)


def test_share_rejects_cross_shard_pages():
    kv = _kv()
    donor = kv.alloc_slot(shard=0)
    kv.ensure(donor, 4)
    borrower = kv.alloc_slot(shard=1)
    with pytest.raises(AssertionError, match="cross-shard"):
        kv.share(borrower, kv.owned_pages(donor))


def test_compact_remaps_within_shards():
    kv = _kv(n_pages=16, page_size=4, max_seqs=4, n_shards=2)
    slots = [kv.alloc_slot(shard=sh) for sh in (0, 1)]
    for s in slots:
        kv.ensure(s, 12)
    # free some pages to fragment, then grow again
    kv.release(slots[0])
    s0b = kv.alloc_slot(shard=0)
    kv.ensure(s0b, 8)
    kv.compact()
    _check_shard_invariants(kv)
    # compacted ids hug each shard's low range (reserve + 1 onward)
    for s in (s0b, slots[1]):
        sh = kv.shard_of_slot(s)
        lo = kv.null_page_of_shard(sh) + 1
        got = kv.owned_pages(s)
        assert got == list(range(lo, lo + len(got)))


def test_pick_shard_prefers_free_pages():
    kv = _kv(n_pages=16, page_size=4, max_seqs=4, n_shards=2)
    assert kv.pick_shard() == 0        # tie -> lowest shard
    s0 = kv.alloc_slot(shard=0)
    kv.ensure(s0, 16)
    assert kv.pick_shard() == 1        # shard 0 drained
    kv.alloc_slot(shard=1)
    kv.alloc_slot(shard=1)
    assert kv.pick_shard() == 0        # shard 1 out of slots


# ---------------------------------------------------------------------------
# prefix index: shard-local chains
# ---------------------------------------------------------------------------

def test_prefix_index_is_shard_local():
    kv = _kv(n_pages=24, page_size=4, max_seqs=4, n_shards=2)
    idx = RadixPrefixCache(kv)
    s0 = kv.alloc_slot(shard=0)
    kv.ensure(s0, 8)
    toks = np.arange(8)
    idx.insert(toks, kv.owned_pages(s0))
    kv.release(s0)
    # the chain lives on shard 0: invisible to shard-1 admissions
    n, pages = idx.lookup(toks, shard=0)
    assert n == 8 and all(kv.shard_of_page(p) == 0 for p in pages)
    assert idx.lookup(toks, shard=1) == (0, [])
    assert idx.lookup(toks)[0] == 8    # unfiltered lookup still matches
    # the same prefix can be cached independently per shard
    s1 = kv.alloc_slot(shard=1)
    kv.ensure(s1, 8)
    idx.insert(toks, kv.owned_pages(s1))
    kv.release(s1)
    n1, pages1 = idx.lookup(toks, shard=1)
    assert n1 == 8 and all(kv.shard_of_page(p) == 1 for p in pages1)
    # shard-filtered eviction only drains that shard's chains
    assert idx.evict(8, shard=1) == 2
    assert idx.lookup(toks, shard=0)[0] == 8
    assert idx.lookup(toks, shard=1) == (0, [])
    _check_shard_invariants(kv)


def test_reclaim_under_pressure_is_shard_local():
    kv = _kv(n_pages=12, page_size=4, max_seqs=4, n_shards=2)
    idx = RadixPrefixCache(kv)
    for sh in (0, 1):                  # park 2 index-only pages per shard
        s = kv.alloc_slot(shard=sh)
        kv.ensure(s, 8)
        idx.insert(np.arange(8) + 100 * sh, kv.owned_pages(s))
        kv.release(s)
    assert idx.cached_pages() == 4
    # shard-0 growth pressure reclaims only shard-0 index pages
    s = kv.alloc_slot(shard=0)
    kv.ensure(s, 5 * 4)                # needs all 5 usable shard-0 pages
    assert kv.free_in_shard(1) == 3    # shard 1's cache untouched
    assert idx.lookup(np.arange(8) + 100, shard=1)[0] == 8
    _check_shard_invariants(kv)


# ---------------------------------------------------------------------------
# shared compile cache
# ---------------------------------------------------------------------------

def test_engines_share_compiled_steps():
    """Two engines with the same config borrow the SAME jitted wrappers
    from serve/compile_cache.py, and the second engine's construction
    and run add zero XLA compilations — the acceptance criterion for
    'N engines share one warmup'."""
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    mk = lambda: [Request(prompt=(np.arange(12) * 3 + i).astype(np.int32)
                          % cfg.vocab_size, max_new_tokens=6)
                  for i in range(3)]
    kw = dict(batch_size=2, max_len=64, dtype="float32",
              cache_kind="paged", page_size=8)
    eng1 = ServeEngine(cfg, p, **kw)
    r1 = mk()
    eng1.run(r1)
    entries = compile_cache.stats()["entries"]
    sizes = {n: getattr(eng1, n)._cache_size()
             for n in ("_decode", "_prefill", "_extend", "_copy")}
    eng2 = ServeEngine(cfg, p, **kw)
    assert compile_cache.stats()["entries"] == entries
    for n in sizes:
        assert getattr(eng2, n) is getattr(eng1, n)
    r2 = mk()
    eng2.run(r2)
    assert [r.out for r in r2] == [r.out for r in r1]
    for n, before in sizes.items():
        assert getattr(eng2, n)._cache_size() == before, \
            f"{n} recompiled for an identical engine"


def test_compile_cache_keys_by_config_and_mesh():
    cfg_a = _tiny_cfg()
    cfg_b = _tiny_cfg().replace(d_ff=256)
    fa = compile_cache.get("decode_paged", cfg_a)
    assert compile_cache.get("decode_paged", cfg_a) is fa
    assert compile_cache.get("decode_paged", cfg_b) is not fa
    assert compile_cache.get("extend_paged", cfg_a) is not fa
    assert compile_cache.mesh_fingerprint(None) is None


# ---------------------------------------------------------------------------
# 2-device: equivalence + packed mesh loading (CI sharded-smoke job)
# ---------------------------------------------------------------------------

@needs2
def test_sharded_engine_matches_single_device():
    """Greedy decode over a 2-way data mesh is token-identical to the
    single-device paged engine — mixed prompt lengths, growth across
    page boundaries, more requests than slots."""
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    mk = lambda: [Request(prompt=(np.arange(10 + i % 3) * 7 + i)
                          .astype(np.int32) % cfg.vocab_size,
                          max_new_tokens=6) for i in range(5)]
    kw = dict(batch_size=2, max_len=64, dtype="float32",
              cache_kind="paged", page_size=8)
    want = mk()
    ServeEngine(cfg, p, **kw).run(want)
    mesh = _mesh2()
    eng = ServeEngine(cfg, p, mesh=mesh, **kw)
    assert eng.kv.n_shards == 2
    got = mk()
    eng.run(got)
    assert [r.out for r in got] == [r.out for r in want]
    # the pool really is partitioned: page axis split across 2 devices
    pools = [l for l in jax.tree.leaves(eng.cache)
             if l.ndim == 5 and l.shape[1] == eng.kv.n_pages]
    assert pools
    for leaf in pools:
        assert len(leaf.sharding.device_set) == 2
        assert leaf.sharding.spec[1] == "data"


@needs2
def test_sharded_engine_rejects_odd_batch():
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    with pytest.raises(ValueError, match="batch_size"):
        ServeEngine(cfg, p, batch_size=3, max_len=64, dtype="float32",
                    cache_kind="paged", page_size=8, mesh=_mesh2())


@needs2
def test_packed_artifact_loads_onto_mesh_and_serves(tmp_path):
    """The acceptance path: quantize -> save (v3 manifest) -> load
    directly onto a 2-way data mesh -> sharded paged serving matches the
    single-device engine token-for-token."""
    from repro.ckpt.packed import load_packed, save_packed
    from repro.core import quantize_model
    from repro.quant import QuantSpec, QuantizedTensor

    cfg = get_config("tiny-lm").replace(dtype="float32", n_layers=2)
    p = init_params(cfg, KEY)
    calib = [jax.random.randint(jax.random.fold_in(KEY, i), (2, 48), 0,
                                cfg.vocab_size) for i in range(2)]
    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed")
    qp, _ = quantize_model(cfg, p, calib, spec=spec)
    save_packed(tmp_path / "m", qp, spec=spec, meta={"arch": "tiny-lm"})

    mesh = _mesh2()
    lp, _, _ = load_packed(tmp_path / "m", mesh=mesh, fsdp=True)
    # every leaf committed to the mesh; fsdp keeps K-on-data, so at
    # least the big QT codes are truly split across the two devices
    split = 0
    for leaf in jax.tree.leaves(
            lp, is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        arrs = ((leaf.codes, leaf.alphas, leaf.betas)
                if isinstance(leaf, QuantizedTensor) else (leaf,))
        for a in arrs:
            assert len(a.sharding.device_set) == 2
            if a.sharding.shard_shape(a.shape) != a.shape:
                split += 1
    assert split > 0, "nothing actually sharded under fsdp=True"

    mk = lambda: [Request(prompt=(np.arange(10) * 3 + i).astype(np.int32)
                          % cfg.vocab_size, max_new_tokens=6)
                  for i in range(2)]
    kw = dict(batch_size=2, max_len=64, dtype="float32",
              cache_kind="paged", page_size=8)
    want = mk()
    ServeEngine(cfg, qp, **kw).run(want)
    got = mk()
    ServeEngine(cfg, lp, mesh=mesh, **kw).run(got)
    assert [r.out for r in got] == [r.out for r in want]


def test_reserve_page_guards_cover_every_shard():
    """share()/ref() must reject each shard's reserve page, not just
    global pid 0: shard s's reserve lives at s * pages_per_shard."""
    kv = _kv(n_pages=8, page_size=4, n_shards=2)
    reserve1 = kv.null_page_of_shard(1)
    assert reserve1 == kv.pages_per_shard and reserve1 != 0
    # a corrupt refcount on the reserve must not legitimize it — the
    # old `pid != 0` guard waved shard 1's reserve straight through
    kv._refcount[reserve1] = 1
    with pytest.raises(AssertionError):
        kv.ref(reserve1)
    s = kv.alloc_slot()
    with pytest.raises(AssertionError):
        kv.share(s, [reserve1])
    kv._refcount[reserve1] = 0
