"""Data pipeline + corpus + evaluation tests."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # not installed: property tests below are gated out
    given = settings = st = None

from repro.data import (ByteTokenizer, batches, calibration_slices,
                        eval_batches, generate_corpus, token_stream)


def test_corpora_are_deterministic_and_distinct():
    a1 = generate_corpus("wiki", 20_000, seed=0)
    a2 = generate_corpus("wiki", 20_000, seed=0)
    b = generate_corpus("ptb", 20_000, seed=0)
    assert a1 == a2
    assert a1 != b
    # distinct vocabularies (analogue of wikitext vs ptb shift)
    assert "railway" in a1 and "railway" not in b
    assert "earnings" in b and "earnings" not in a1


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "the ancient city governed a region."
    assert tok.decode(tok.encode(s)) == s
    assert tok.vocab_size == 258


if given is not None:
    @given(st.integers(1, 16), st.integers(8, 64), st.integers(0, 10 ** 6))
    @settings(max_examples=10, deadline=None)
    def test_calibration_slices_shape_and_range(n, L, seed):
        toks = token_stream("wiki", 30_000)
        sl = calibration_slices(toks, n, L, seed=seed)
        assert sl.shape == (n, L)
        assert sl.min() >= 0 and sl.max() < 256


def test_batches_are_shifted_labels():
    toks = token_stream("wiki", 30_000)
    b = next(batches(toks, 4, 32, seed=0))
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])


def test_eval_batches_cover_stream_once():
    toks = token_stream("wiki", 10_000)
    seen = 0
    for b in eval_batches(toks, 4, 64):
        seen += b["inputs"].shape[0] * 64
    assert seen == ((len(toks) - 1) // 64) * 64


def test_perplexity_of_uniform_model_is_vocab_size():
    """A zero-logits model must score ppl == vocab_size (sanity of the
    metric used in every paper table)."""
    import jax
    from repro.configs import get_config
    from repro.data.evaluate import perplexity
    from repro.models import init_params
    cfg = get_config("tiny-lm").replace(dtype="float32", n_layers=1,
                                        d_model=32, d_ff=64, n_heads=2,
                                        n_kv_heads=2, head_dim=16,
                                        remat="none")
    p = init_params(cfg, jax.random.PRNGKey(0))
    # zero the unembed path -> uniform distribution
    p["embed"] = p["embed"] * 0.0
    toks = token_stream("wiki", 8_000)
    ppl = perplexity(cfg, p, eval_batches(toks, 2, 64), max_batches=3)
    assert abs(ppl - cfg.vocab_size) / cfg.vocab_size < 1e-3
