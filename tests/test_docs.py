"""Documentation hygiene: docs/*.md (and the root *.md) must not carry
dangling relative links or references to files that no longer exist.
The check itself is repro-lint rule R007 (docs/ANALYSIS.md); this runs
it through the legacy tools/check_doc_links.py entry point so the shim
stays honest too. The full-lint gate lives in tests/test_lint.py."""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def test_docs_have_no_dangling_references():
    r = subprocess.run([sys.executable,
                        str(ROOT / "tools" / "check_doc_links.py")],
                       capture_output=True, text=True, timeout=60)
    assert r.returncode == 0, f"\n{r.stdout}{r.stderr}"


def test_architecture_doc_exists_and_is_linked():
    """The end-to-end map must exist and be reachable from both topic
    docs (QUANT.md and SERVING.md cross-link it)."""
    arch = ROOT / "docs" / "ARCHITECTURE.md"
    assert arch.exists()
    for doc in ("QUANT.md", "SERVING.md"):
        assert "ARCHITECTURE.md" in (ROOT / "docs" / doc).read_text(), \
            f"docs/{doc} should link the architecture map"
