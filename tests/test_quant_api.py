"""QuantSpec pipeline surface: registry dispatch, per-leaf override
resolution (mixed precision), streaming-vs-batch Hessian equivalence,
packed-artifact round trips, the legacy-signature shim, and the serving
follow-ups that ride along (device-resident block tables, radix index
page cap)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import HessianAccumulator, quantize_model
from repro.core.hessian import hessian_from_inputs
from repro.models import forward, init_params
from repro.quant import (OverrideRule, QuantResult, QuantSpec, Quantizer,
                         available_quantizers, get_quantizer,
                         register_quantizer)
from repro.quant.registry import _REGISTRY

KEY = jax.random.PRNGKey(0)


def _tiny():
    cfg = get_config("tiny-lm").replace(dtype="float32", n_layers=2)
    p = init_params(cfg, KEY)
    calib = [jax.random.randint(jax.random.fold_in(KEY, i), (2, 48), 0,
                                cfg.vocab_size) for i in range(2)]
    return cfg, p, calib


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_resolves_every_paper_method():
    methods = {"rtn", "bcq", "gptq", "gptq_minmse", "gptq_bcq", "gptqt"}
    assert methods <= set(available_quantizers())
    for m in methods:
        q = get_quantizer(m)
        assert q.name == m
    # only the binary-coding methods pack
    assert get_quantizer("gptqt").supports_packed
    assert get_quantizer("bcq").supports_packed
    assert not get_quantizer("rtn").supports_packed


def test_unknown_method_error_lists_registered():
    with pytest.raises(KeyError, match="gptqt"):
        get_quantizer("nope")


def test_custom_quantizer_plugs_into_quantize_model():
    @register_quantizer("keepdense")
    class KeepDense(Quantizer):
        def quantize(self, Wt, H, plan, *, orig_dtype="bfloat16"):
            return QuantResult(wq_t=Wt)      # identity "quantization"

    try:
        cfg, p, calib = _tiny()
        spec = QuantSpec.from_config(cfg.quant, method="keepdense")
        qp, rep = quantize_model(cfg, p, calib, spec=spec)
        w0 = p["blocks"]["L0"]["attn"]["wq"]
        np.testing.assert_array_equal(np.asarray(qp["blocks"]["L0"]["attn"]["wq"]),
                                      np.asarray(w0))
        assert all(st["method"] == "keepdense" for st in rep.values())
    finally:
        _REGISTRY.pop("keepdense", None)


def test_packed_mode_rejects_unpackable_method():
    cfg, p, calib = _tiny()
    spec = QuantSpec.from_config(cfg.quant, method="rtn", mode="packed")
    with pytest.raises(ValueError, match="packed"):
        quantize_model(cfg, p, calib, spec=spec)


# ---------------------------------------------------------------------------
# QuantSpec resolution
# ---------------------------------------------------------------------------

def test_override_rules_first_match_wins_and_skip():
    spec = QuantSpec(method="gptqt", bits=3, include_head=True, overrides=(
        OverrideRule("lm_head", bits=8),
        OverrideRule("blocks.L1.*", method="rtn", bits=4),
        OverrideRule("wd", skip=True),
        OverrideRule("w*", bits=2),
    ))
    assert spec.resolve("lm_head", "lm_head").bits == 8
    p = spec.resolve("blocks.L1.attn.wq", "wq")
    assert (p.method, p.bits) == ("rtn", 4)
    assert spec.resolve("blocks.L0.mlp.wd", "wd") is None
    assert spec.resolve("blocks.L0.attn.wq", "wq").bits == 2
    # unmatched leaves inherit the defaults
    assert spec.resolve("blocks.L0.mamba.in_proj", "in_proj").bits == 3
    # eligibility still gates: norms are never quantized
    assert spec.resolve("blocks.L0.ln1", "ln1") is None


def test_exclude_and_head_gating():
    spec = QuantSpec(exclude=("x_proj",))
    assert spec.resolve("blocks.L0.mamba.x_proj", "x_proj") is None
    assert spec.resolve("lm_head", "lm_head") is None       # head opt-in
    assert QuantSpec(include_head=True).resolve("lm_head", "lm_head")


def test_spec_dict_roundtrip():
    spec = QuantSpec(method="gptqt", bits=2, mode="packed",
                     exclude=("x_proj",),
                     overrides=(OverrideRule("wv", bits=4),
                                OverrideRule("wd", skip=True)))
    assert QuantSpec.from_dict(spec.to_dict()) == spec


def test_mixed_precision_quantizes_matched_leaves_at_their_bits():
    """The acceptance criterion: a spec with override rules produces
    different bit-widths for matched leaves of the SAME model."""
    cfg, p, calib = _tiny()
    spec = QuantSpec.from_config(
        cfg.quant, method="gptqt", mode="packed",
        overrides=(OverrideRule("wv", bits=2),
                   OverrideRule("blocks.L0.mlp.*", bits=4)))
    qp, rep = quantize_model(cfg, p, calib, spec=spec)
    attn0 = qp["blocks"]["L0"]["attn"]
    assert attn0["wv"].bits == 2
    assert attn0["wq"].bits == cfg.quant.bits      # default
    assert qp["blocks"]["L0"]["mlp"]["wg"].bits == 4
    assert qp["blocks"]["L0"]["mlp"]["wd"].bits == 4
    logits, _ = forward(cfg, qp, calib[0])
    assert jnp.isfinite(logits).all()


def test_abstract_path_uses_same_resolver():
    from repro.quant.abstract import quantize_params_abstract
    cfg, p, _ = _tiny()
    p_abs = jax.eval_shape(lambda: p)
    spec = QuantSpec.from_config(cfg.quant, mode="packed",
                                 overrides=(OverrideRule("wv", bits=2),))
    q_abs = quantize_params_abstract(cfg, p_abs, spec=spec)
    assert q_abs["blocks"]["L0"]["attn"]["wv"].bits == 2
    assert q_abs["blocks"]["L0"]["attn"]["wq"].bits == cfg.quant.bits
    # legacy uniform-bits call still works
    q_abs2 = quantize_params_abstract(cfg, p_abs, 2)
    assert q_abs2["blocks"]["L0"]["attn"]["wq"].bits == 2


# ---------------------------------------------------------------------------
# group-wise spec plumbing
# ---------------------------------------------------------------------------

def test_group_size_validation_is_loud():
    """The silent no-op is gone: bad group_size values raise clearly."""
    with pytest.raises(ValueError, match=">= 0"):
        QuantSpec(group_size=-64)
    with pytest.raises(ValueError, match="int"):
        QuantSpec(group_size=64.0)
    with pytest.raises(ValueError, match=">= 0"):
        OverrideRule("wv", group_size=-1)
    plan = QuantSpec(group_size=48).resolve("blocks.L0.attn.wq", "wq")
    with pytest.raises(ValueError, match="divide"):
        plan.n_groups(256)
    assert plan.n_groups(96) == 2


def test_nondivisible_group_size_names_the_leaf():
    cfg, p, calib = _tiny()
    spec = QuantSpec.from_config(cfg.quant, method="rtn", group_size=96)
    with pytest.raises(ValueError) as ei:       # 96 !| 256
        quantize_model(cfg, p, calib, spec=spec)
    assert "group_size=96" in str(ei.value)
    assert "blocks." in str(ei.value)


def test_override_rule_can_set_group_size():
    spec = QuantSpec(method="gptqt", bits=3, group_size=128, overrides=(
        OverrideRule("wv", group_size=64),
        OverrideRule("wd", group_size=0),
    ))
    assert spec.resolve("blocks.L0.attn.wv", "wv").group_size == 64
    assert spec.resolve("blocks.L0.mlp.wd", "wd").group_size == 0
    assert spec.resolve("blocks.L0.attn.wq", "wq").group_size == 128
    # serializes through dicts like every other override field
    assert QuantSpec.from_dict(spec.to_dict()) == spec


def test_grouped_quantize_model_emits_grouped_leaves():
    from repro.quant import QuantizedTensor
    cfg, p, calib = _tiny()
    spec = QuantSpec.from_config(
        cfg.quant, method="gptqt", mode="packed", group_size=128,
        overrides=(OverrideRule("wv", group_size=0),))
    qp, _ = quantize_model(cfg, p, calib, spec=spec)
    attn = qp["blocks"]["L0"]["attn"]
    assert isinstance(attn["wq"], QuantizedTensor)
    assert attn["wq"].n_groups == 2          # K=256 / 128
    assert attn["wv"].n_groups == 1          # per-leaf opt-out
    assert qp["blocks"]["L0"]["mlp"]["wd"].n_groups == 8   # K=1024 / 128
    logits, _ = forward(cfg, qp, calib[0])
    assert jnp.isfinite(logits).all()


def test_abstract_grouped_leaf_sizes_scale_memory():
    from repro.quant.abstract import quantized_leaf_abstract
    leaf = jax.ShapeDtypeStruct((512, 64), jnp.float32)
    q1 = quantized_leaf_abstract(leaf, 3)
    qg = quantized_leaf_abstract(leaf, 3, group_size=128)
    assert q1.alphas.shape == (1, 64, 3) and qg.alphas.shape == (4, 64, 3)
    assert qg.betas.shape == (4, 64)
    # the size model must charge for the extra scale copies
    assert qg.packed_bytes() - q1.packed_bytes() == 3 * (64 * 3 + 64) * 4
    with pytest.raises(ValueError, match="divide"):
        quantized_leaf_abstract(leaf, 3, group_size=100)


def test_abstract_resolver_threads_group_size():
    from repro.quant.abstract import quantize_params_abstract
    cfg, p, _ = _tiny()
    p_abs = jax.eval_shape(lambda: p)
    spec = QuantSpec.from_config(cfg.quant, mode="packed", group_size=64)
    q_abs = quantize_params_abstract(cfg, p_abs, spec=spec)
    wq = q_abs["blocks"]["L0"]["attn"]["wq"]
    assert wq.alphas.shape[-3] == wq.k_in // 64


# ---------------------------------------------------------------------------
# sensitivity sweep (FineQuant-style bit search)
# ---------------------------------------------------------------------------

def test_sensitivity_sweep_scores_and_suggests():
    from repro.quant import (format_overrides, sensitivity_sweep,
                             suggest_overrides)
    cfg, p, calib = _tiny()
    spec = QuantSpec.from_config(cfg.quant, bits=3)
    scores = sensitivity_sweep(cfg, p, calib, spec=spec)
    assert scores                                    # every eligible leaf
    paths = {s.path for s in scores}
    assert "blocks.L0.attn.wq" in paths
    for s in scores:
        # coarser quantization can only hurt: err monotone in -bits
        assert s.err[2] >= s.err[3] >= s.err[4] >= 0.0
    rules = suggest_overrides(scores, base_bits=3, bump_frac=0.3)
    assert rules and all(r.bits == 4 for r in rules)
    assert len(rules) == max(1, round(len(scores) * 0.3))
    # suggested patterns resolve against the same spec machinery
    spec2 = spec.replace(overrides=rules)
    bumped = spec2.resolve(rules[0].pattern, rules[0].pattern.rsplit(
        ".", 1)[-1])
    assert bumped.bits == 4
    src = format_overrides(rules)
    assert src.startswith("overrides = (") and "OverrideRule(" in src
    # off-grid base bits snap to the nearest scored width (no KeyError)
    rules5 = suggest_overrides(scores, base_bits=5)
    assert rules5 and all(r.bits == 6 for r in rules5)


def test_suggest_overrides_bytes_budget_greedy():
    from repro.quant import suggest_overrides
    from repro.quant.search import LeafScore, bump_cost_bytes

    def leaf(path, err3, err4, params):
        return LeafScore(path=path, err={2: err3 + 1, 3: err3, 4: err4},
                         params=params)

    # bumping w3 -> w4 costs params/8 bytes (one extra sign bitplane)
    big = leaf("blocks.L0.ffn.w1", 0.40, 0.10, 8192)    # cost 1024, gain .30
    mid = leaf("blocks.L0.attn.wq", 0.20, 0.02, 2048)   # cost  256, gain .18
    tiny = leaf("blocks.L0.attn.wv", 0.09, 0.01, 512)   # cost   64, gain .08
    flat = leaf("blocks.L0.attn.wo", 0.05, 0.05, 512)   # gain 0: never picked
    scores = [big, mid, tiny, flat]
    assert bump_cost_bytes(big, 3, 4) == 1024

    # gain/byte ranks mid (7.0e-4) > tiny (1.25e-3? no: .08/64=1.25e-3)
    # tiny: .08/64 = 1.25e-3, mid: .18/256 = 7.0e-4, big: .30/1024 = 2.9e-4
    rules = suggest_overrides(scores, base_bits=3, bytes_budget=320)
    assert [r.pattern for r in rules] == [tiny.path, mid.path]
    assert all(r.bits == 4 for r in rules)

    # a leaf too large for the remaining budget is skipped, not blocking:
    # budget 1100 takes tiny (64) + mid (256) then still fits big? 320
    # spent, 780 left < 1024 -> big skipped, nothing else fits
    rules = suggest_overrides(scores, base_bits=3, bytes_budget=1100)
    assert [r.pattern for r in rules] == [tiny.path, mid.path]

    # big budget takes every leaf with positive gain, never the flat one
    rules = suggest_overrides(scores, base_bits=3, bytes_budget=10_000)
    assert {r.pattern for r in rules} == {big.path, mid.path, tiny.path}

    # zero budget buys nothing; negative budget is an error
    assert suggest_overrides(scores, base_bits=3, bytes_budget=0) == ()
    with pytest.raises(ValueError):
        suggest_overrides(scores, base_bits=3, bytes_budget=-1)


# ---------------------------------------------------------------------------
# streaming calibration
# ---------------------------------------------------------------------------

def test_streaming_accumulator_matches_batch_hessian():
    rng = np.random.default_rng(0)
    xs = [jnp.asarray(rng.standard_normal((t, 24)), jnp.float32)
          for t in (7, 31, 64, 3)]
    H_ref, n_ref = hessian_from_inputs(xs)
    acc = HessianAccumulator(24)
    for x in xs:
        acc.update(x)
    H, n = acc.finalize()
    assert n == n_ref == sum(x.shape[0] for x in xs)
    np.testing.assert_allclose(np.asarray(H), np.asarray(H_ref), rtol=1e-6)
    # higher-rank activations fold like their 2D reshape
    acc2 = HessianAccumulator(24)
    acc2.update(jnp.stack([xs[0][:3], xs[3]]))              # (2, 3, 24)
    H2, _ = acc2.finalize()
    H3, _ = hessian_from_inputs([xs[0][:3], xs[3]])
    np.testing.assert_allclose(np.asarray(H2), np.asarray(H3), rtol=1e-5,
                               atol=1e-6)


def test_calibration_is_constant_memory_per_weight():
    """collect_hessians must hold accumulators, not activation lists:
    the per-weight state between batches is exactly one (K, K) sum."""
    from repro.core.api import collect_hessians
    cfg, p, calib = _tiny()
    hs = collect_hessians(cfg, p, calib)
    for path, g, leaf, H in hs.values():
        K = leaf.shape[-2]
        assert np.asarray(H).shape == (K, K)
        assert np.isfinite(np.asarray(H)).all()


# ---------------------------------------------------------------------------
# legacy shim
# ---------------------------------------------------------------------------

def test_legacy_signature_warns_and_matches_spec_path():
    cfg, p, calib = _tiny()
    with pytest.warns(DeprecationWarning):
        q_old, _ = quantize_model(cfg, p, calib, method="rtn")
    q_new, _ = quantize_model(
        cfg, p, calib, spec=QuantSpec.from_config(cfg.quant, method="rtn"))
    w_old = q_old["blocks"]["L0"]["attn"]["wq"]
    w_new = q_new["blocks"]["L0"]["attn"]["wq"]
    np.testing.assert_array_equal(np.asarray(w_old), np.asarray(w_new))


def test_spec_plus_legacy_kwargs_is_an_error():
    cfg, p, calib = _tiny()
    with pytest.raises(TypeError, match="not both"):
        quantize_model(cfg, p, calib, spec=QuantSpec(), method="rtn")
