"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes/dtypes/bit-widths, plus packing round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # not installed: property tests below are gated out
    given = settings = st = None

from repro.kernels import ref
from repro.kernels.bcq_matmul import bcq_expert_matmul, bcq_gemv, bcq_matmul
from repro.quant.packing import pack_signs, unpack_signs
from repro.quant.qlinear import QuantizedTensor


def _rand_qt(rng, K, N, bits, G=1):
    codes = jnp.asarray(rng.integers(0, 2 ** 32, (bits, -(-K // 32), N),
                                     dtype=np.uint32))
    alphas = jnp.asarray(rng.random((G, N, bits), dtype=np.float32) * 0.2)
    betas = jnp.asarray((rng.standard_normal((G, N)) * 0.05).astype(np.float32))
    return codes, alphas, betas


def _rand_expert_qt(rng, E, K, N, bits, G=1):
    """Expert stack: the single-matrix layout with a leading E axis."""
    codes = jnp.asarray(rng.integers(0, 2 ** 32, (E, bits, -(-K // 32), N),
                                     dtype=np.uint32))
    alphas = jnp.asarray(rng.random((E, G, N, bits), dtype=np.float32) * 0.2)
    betas = jnp.asarray(
        (rng.standard_normal((E, G, N)) * 0.05).astype(np.float32))
    return codes, alphas, betas


SHAPES = [
    (16, 64, 64, 2), (64, 128, 128, 3), (8, 256, 96, 4),
    (128, 384, 256, 3), (33, 160, 130, 3),   # ragged M/K/N
    (1, 512, 512, 2),                        # gemv-shaped
]


@pytest.mark.parametrize("M,K,N,bits", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bcq_matmul_matches_ref(M, K, N, bits, dtype):
    rng = np.random.default_rng(hash((M, K, N, bits)) % 2 ** 31)
    Kp = -(-K // 32) * 32
    codes, alphas, betas = _rand_qt(rng, Kp, N, bits)
    x = jnp.asarray(rng.standard_normal((M, Kp)).astype(np.float32)).astype(dtype)
    want = ref.bcq_matmul_ref(x.astype(jnp.float32), codes, alphas, betas, Kp)
    got = bcq_matmul(x, codes, alphas, betas, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    scale = float(jnp.abs(want).max()) + 1e-9
    assert float(jnp.abs(got.astype(jnp.float32) - want).max()) / scale < tol


def test_bcq_gemv_matches_matmul():
    rng = np.random.default_rng(0)
    codes, alphas, betas = _rand_qt(rng, 256, 320, 3)
    x = jnp.asarray(rng.standard_normal((2, 256)).astype(np.float32))
    a = bcq_gemv(x, codes, alphas, betas, interpret=True)
    b = bcq_matmul(x, codes, alphas, betas, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_bcq_gemv_matches_ref():
    rng = np.random.default_rng(7)
    codes, alphas, betas = _rand_qt(rng, 512, 384, 2)
    x = jnp.asarray(rng.standard_normal((1, 512)).astype(np.float32))
    want = ref.bcq_gemv_ref(x, codes, alphas, betas, 512)
    got = bcq_gemv(x, codes, alphas, betas, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("E", [1, 4, 8])
@pytest.mark.parametrize("group_size", [0, 64, 128])
@pytest.mark.parametrize("M", [32, 33])
def test_bcq_expert_matmul_matches_ref(E, group_size, M):
    """Batched-expert kernel vs the vmapped oracle across expert counts,
    per-channel and grouped scales, and odd/even M (padding path)."""
    K, N, bits = 256, 192, 3
    G = 1 if group_size == 0 else K // group_size
    rng = np.random.default_rng(hash((E, group_size, M)) % 2 ** 31)
    codes, alphas, betas = _rand_expert_qt(rng, E, K, N, bits, G)
    x = jnp.asarray(rng.standard_normal((E, M, K)).astype(np.float32))
    want = ref.bcq_expert_matmul_ref(x, codes, alphas, betas, K)
    got = bcq_expert_matmul(x, codes, alphas, betas, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_bcq_expert_dispatch_through_ops():
    """The ops layer routes a single-axis expert stack with a matching
    (E, C, k) activation through the batched kernel when Pallas is on,
    and through the vmapped dequant fallback otherwise — both must agree
    with the oracle."""
    from repro.kernels import ops
    E, K, N, bits, G = 4, 256, 128, 2, 4
    rng = np.random.default_rng(11)
    codes, alphas, betas = _rand_expert_qt(rng, E, K, N, bits, G)
    qt = QuantizedTensor(codes, alphas, betas, k_in=K, orig_dtype="float32")
    x = jnp.asarray(rng.standard_normal((E, 7, K)).astype(np.float32))
    want = ref.bcq_expert_matmul_ref(x, codes, alphas, betas, K)
    for force in (False, True):
        old = ops.FORCE_PALLAS
        ops.FORCE_PALLAS = force
        try:
            y = ops.bcq_apply(x, qt)
        finally:
            ops.FORCE_PALLAS = old
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_bitplane_reassociation_equivalent():
    """GPU-LUT-GEMM-style per-bitplane formulation == dequant-fused (the
    DESIGN.md §2 equivalence that justifies the TPU adaptation)."""
    rng = np.random.default_rng(1)
    codes, alphas, betas = _rand_qt(rng, 128, 96, 3)
    x = jnp.asarray(rng.standard_normal((24, 128)).astype(np.float32))
    a = ref.bcq_matmul_ref(x, codes, alphas, betas, 128)
    b = ref.bcq_matmul_bitplane_ref(x, codes, alphas, betas, 128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# packing properties
# ---------------------------------------------------------------------------

if given is not None:
    @given(st.integers(1, 4), st.integers(1, 80), st.integers(1, 9),
           st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_pack_unpack_roundtrip(bits, K, N, seed):
        rng = np.random.default_rng(seed)
        signs = rng.integers(0, 2, (bits, K, N)).astype(bool)
        packed = pack_signs(jnp.asarray(signs))
        assert packed.shape == (bits, -(-K // 32), N)
        un = np.asarray(unpack_signs(packed, K))
        np.testing.assert_array_equal(un > 0, signs)


def test_quantized_tensor_pytree_and_scan():
    """QT must survive tree ops and lax.scan slicing (stacked groups)."""
    rng = np.random.default_rng(2)
    G, K, N, bits = 3, 64, 32, 2
    codes = jnp.asarray(rng.integers(0, 2 ** 32, (G, bits, K // 32, N),
                                     dtype=np.uint32))
    alphas = jnp.asarray(rng.random((G, 1, N, bits), dtype=np.float32))
    betas = jnp.zeros((G, 1, N), jnp.float32)
    qt = QuantizedTensor(codes, alphas, betas, k_in=K, orig_dtype="float32")
    leaves, treedef = jax.tree.flatten(qt)
    assert len(leaves) == 3
    qt2 = jax.tree.unflatten(treedef, leaves)
    assert qt2.k_in == K

    x = jnp.asarray(rng.standard_normal((5, K)).astype(np.float32))

    def body(acc, qt_g):
        return acc + qt_g.quantized_matmul(x), None

    out, _ = jax.lax.scan(body, jnp.zeros((5, N)), qt)
    want = sum(np.asarray(ref.bcq_matmul_ref(
        x, codes[g], alphas[g], betas[g], K)) for g in range(G))
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("M", [12, 100, 104])
def test_bcq_matmul_odd_m_rounds_block_to_sublanes(M):
    """Regression: the small-M shortcut used to pick bm=M directly, which
    for e.g. M=100 is not a multiple of the 8-sublane tile."""
    rng = np.random.default_rng(M)
    codes, alphas, betas = _rand_qt(rng, 128, 96, 3)
    x = jnp.asarray(rng.standard_normal((M, 128)).astype(np.float32))
    want = ref.bcq_matmul_ref(x, codes, alphas, betas, 128)
    got = bcq_matmul(x, codes, alphas, betas, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# group-wise scales (per-K-group alphas/betas)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("group_size", [0, 64, 128])
@pytest.mark.parametrize("M", [32, 33])                  # even / odd M
def test_bcq_matmul_grouped_matches_ref(group_size, M):
    K, N, bits = 256, 130, 3
    G = K // group_size if group_size else 1
    rng = np.random.default_rng(group_size * 100 + M)
    codes, alphas, betas = _rand_qt(rng, K, N, bits, G)
    x = jnp.asarray(rng.standard_normal((M, K)).astype(np.float32))
    want = ref.bcq_matmul_ref(x, codes, alphas, betas, K)
    got = bcq_matmul(x, codes, alphas, betas, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_bcq_matmul_group128_acceptance_gemm():
    """Acceptance: group_size=128 on a (256, 512, 384) GEMM matches the
    jnp oracle to fp32 tolerance (interpret mode)."""
    M, K, N, bits, gs = 256, 512, 384, 3, 128
    rng = np.random.default_rng(7)
    codes, alphas, betas = _rand_qt(rng, K, N, bits, K // gs)
    x = jnp.asarray(rng.standard_normal((M, K)).astype(np.float32))
    want = ref.bcq_matmul_ref(x, codes, alphas, betas, K)
    got = bcq_matmul(x, codes, alphas, betas, interpret=True)
    scale = float(jnp.abs(want).max()) + 1e-9
    assert float(jnp.abs(got - want).max()) / scale < 2e-5


def test_bcq_matmul_group_spans_multiple_k_tiles():
    """group_size > block_k: one group covers several K-tiles, selected
    by the grid-index arithmetic in the BlockSpec index map."""
    K, N, bits, gs = 1024, 96, 2, 512
    rng = np.random.default_rng(11)
    codes, alphas, betas = _rand_qt(rng, K, N, bits, K // gs)
    x = jnp.asarray(rng.standard_normal((16, K)).astype(np.float32))
    want = ref.bcq_matmul_ref(x, codes, alphas, betas, K)
    got = bcq_matmul(x, codes, alphas, betas, block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_bcq_matmul_grouped_gemv_and_bf16():
    rng = np.random.default_rng(13)
    codes, alphas, betas = _rand_qt(rng, 256, 320, 3, G=4)
    x = jnp.asarray(rng.standard_normal((2, 256))).astype(jnp.bfloat16)
    want = ref.bcq_matmul_ref(x.astype(jnp.float32), codes, alphas, betas, 256)
    got = bcq_gemv(x, codes, alphas, betas, interpret=True)
    scale = float(jnp.abs(want).max()) + 1e-9
    assert float(jnp.abs(got.astype(jnp.float32) - want).max()) / scale < 2e-2


def test_bcq_matmul_group_not_multiple_of_block_k():
    """Regression: gs=320 (word-aligned, > block_k, not a multiple of
    it) must shrink block_k to gcd and still match the oracle — the
    ops-layer predicate admits every word-aligned grouping, so the
    kernel has to handle them all."""
    K, N, bits, gs = 1280, 64, 2, 320
    rng = np.random.default_rng(23)
    codes, alphas, betas = _rand_qt(rng, K, N, bits, K // gs)
    x = jnp.asarray(rng.standard_normal((16, K)).astype(np.float32))
    want = ref.bcq_matmul_ref(x, codes, alphas, betas, K)
    got = bcq_matmul(x, codes, alphas, betas, block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # and through the dispatch layer (what serving actually calls)
    from repro.kernels import ops
    qt = QuantizedTensor(codes, alphas, betas, k_in=K, orig_dtype="float32")
    old = ops.FORCE_PALLAS
    ops.FORCE_PALLAS = True
    try:
        y = ops.bcq_apply(x, qt)
    finally:
        ops.FORCE_PALLAS = old
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_bcq_matmul_rejects_bad_grouping():
    rng = np.random.default_rng(17)
    codes, alphas, betas = _rand_qt(rng, 256, 64, 2, G=3)  # 3 !| 256
    x = jnp.asarray(rng.standard_normal((8, 256)).astype(np.float32))
    with pytest.raises(ValueError, match="divide"):
        bcq_matmul(x, codes, alphas, betas, interpret=True)


def test_quantized_tensor_validates_group_invariant():
    rng = np.random.default_rng(19)
    codes, alphas, betas = _rand_qt(rng, 64, 8, 2, G=2)
    QuantizedTensor(codes, alphas, betas, k_in=64)          # ok
    with pytest.raises(ValueError, match="divide"):
        QuantizedTensor(codes, alphas, betas, k_in=63)      # 2 !| 63
    with pytest.raises(ValueError, match="betas"):
        QuantizedTensor(codes, alphas, betas[:1], k_in=64)  # G mismatch
    with pytest.raises(ValueError, match="alphas"):
        QuantizedTensor(codes, alphas[:, :1, :], betas[:, :1], k_in=64)
    # slicing the BITS axis is legal now: fewer alphas than stored code
    # planes is a draft view (leading planes + re-fit scales)
    qt = QuantizedTensor(codes, alphas[:, :, :1], betas, k_in=64)
    assert qt.bits == 1 and qt.stored_bits == 2


@pytest.mark.parametrize("block_m,block_n,block_k",
                         [(8, 128, 128), (32, 256, 128), (128, 128, 256)])
def test_kernel_block_shape_sweep(block_m, block_n, block_k):
    rng = np.random.default_rng(3)
    codes, alphas, betas = _rand_qt(rng, 256, 256, 3)
    x = jnp.asarray(rng.standard_normal((64, 256)).astype(np.float32))
    want = ref.bcq_matmul_ref(x, codes, alphas, betas, 256)
    got = bcq_matmul(x, codes, alphas, betas, block_m=block_m,
                     block_n=block_n, block_k=block_k, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
