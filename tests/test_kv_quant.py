"""Binary-coded KV cache: coding round-trip, fused-dequant kernel vs
oracle, bytes accounting, COW forks on quantized pages, and greedy
equality of the quantized pool against the raw fp pool on the trained
toy model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention_quant
from repro.models.attention import paged_kv_page_bytes
from repro.models.model import copy_pages, init_paged_cache, is_page_leaf
from repro.quant.kv import (kv_bytes_per_token_head, kv_dequantize,
                            kv_layout, kv_quantize)

KEY = jax.random.PRNGKey(0)


def _tiny_cfg():
    return get_config("tiny-lm").replace(dtype="float32", n_layers=2,
                                         d_model=64, d_ff=128, remat="none")


# ---------------------------------------------------------------------------
# coding round-trip
# ---------------------------------------------------------------------------

def _rel_err(x, bits, **kw):
    y = kv_dequantize(*kv_quantize(x, bits, **kw))
    return float(jnp.linalg.norm(x - y) / jnp.linalg.norm(x))


def test_kv_roundtrip_error_decays_with_bits():
    x = jax.random.normal(KEY, (32, 2, 64), jnp.float32)
    errs = [_rel_err(x, b) for b in (1, 2, 4, 8)]
    assert all(a > b for a, b in zip(errs, errs[1:])), errs
    # the alternating refinement keeps per-bit decay going where pure
    # greedy coding plateaus around 10% — 4 bits must land well below
    assert errs[2] < 0.15 and errs[3] < 0.06, errs


def test_kv_refinement_beats_greedy():
    x = jax.random.normal(KEY, (64, 64), jnp.float32)
    greedy = _rel_err(x, 4, iters=0)
    refined = _rel_err(x, 4)
    assert refined < greedy - 0.02, (greedy, refined)


def test_kv_roundtrip_grouped_scales():
    x = jax.random.normal(KEY, (16, 64), jnp.float32) * \
        jnp.linspace(0.1, 10.0, 64)          # scale varies along head_dim
    whole = _rel_err(x, 2)
    grouped = _rel_err(x, 2, kv_group_size=16)
    assert grouped < whole                   # finer scales fit the ramp


def test_kv_quantize_shapes_and_dtypes():
    x = jax.random.normal(KEY, (3, 5, 64), jnp.float32)
    codes, alphas, betas = kv_quantize(x, 4, kv_group_size=32)
    assert codes.shape == (3, 5, 4, 2) and codes.dtype == jnp.uint32
    assert alphas.shape == (3, 5, 2, 4) and alphas.dtype == jnp.float32
    assert betas.shape == (3, 5, 2) and betas.dtype == jnp.float32


def test_kv_layout_validation():
    assert kv_layout(64, 4) == (1, 2)
    assert kv_layout(64, 2, 16) == (4, 2)
    with pytest.raises(ValueError):
        kv_layout(64, 0)                     # bits < 1
    with pytest.raises(ValueError):
        kv_layout(48, 4)                     # head_dim % 32 != 0
    with pytest.raises(ValueError):
        kv_layout(64, 4, kv_group_size=24)   # group doesn't divide hd


def test_kv_bytes_per_token_head():
    assert kv_bytes_per_token_head(64, 0) == 256          # raw fp32
    assert kv_bytes_per_token_head(64, 0, dtype_itemsize=2) == 128
    assert kv_bytes_per_token_head(64, 4) == 52           # 4.9x vs fp32
    assert kv_bytes_per_token_head(64, 1) == 16
    # must agree with the actual device pool, leaf by leaf
    cfg = _tiny_cfg()
    for bits in (0, 4):
        cache = init_paged_cache(cfg, n_pages=6, page_size=8, max_seqs=2,
                                 kv_bits=bits)
        leaves = [l for l in jax.tree.leaves(cache) if is_page_leaf(l, 6)]
        assert sum(l.nbytes for l in leaves) // 6 \
            == paged_kv_page_bytes(cfg, 8, "float32", kv_bits=bits)


# ---------------------------------------------------------------------------
# fused-dequant kernel vs oracle
# ---------------------------------------------------------------------------

def _quant_pool(rng, P, page, Hkv, hd, bits):
    k = jnp.asarray(rng.standard_normal((P, page, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((P, page, Hkv, hd)), jnp.float32)
    # iters=1 keeps the sweep fast; kernel parity is about consuming the
    # codes, not about how well they were fitted
    return kv_quantize(k, bits, iters=1) + kv_quantize(v, bits, iters=1)


@pytest.mark.parametrize("page,bits", [(8, 1), (8, 4), (16, 2), (16, 4),
                                       (32, 3)])
def test_quant_kernel_matches_oracle_sweep(page, bits):
    """Kernel vs jnp oracle across page sizes x kv_bits with ragged
    context lengths straddling page boundaries. Both sides consume the
    same codes, so the tolerance is fp32-accumulation noise, not coding
    error."""
    rng = np.random.default_rng(page * 31 + bits)
    Hkv, rep, hd, T = 2, 2, 64, 4
    P = T + 3
    ctx = [1, page - 1, page, page + 1, T * page]
    B = len(ctx)
    q = jnp.asarray(rng.standard_normal((B, Hkv, rep, hd)), jnp.float32)
    pool = _quant_pool(rng, P, page, Hkv, hd, bits)
    bt = jnp.asarray(rng.integers(1, P, (B, T)).astype(np.int32))
    ctx = jnp.asarray(ctx, jnp.int32)
    want = ref.paged_attention_quant_ref(q, *pool, bt, ctx)
    got = paged_attention_quant(q, *pool, bt, ctx, interpret=True)
    assert float(jnp.abs(got - want).max()) < 1e-5


@pytest.mark.parametrize("window,cap", [(10, None), (None, 30.0),
                                        (7, 50.0)])
def test_quant_kernel_matches_oracle_window_cap(window, cap):
    rng = np.random.default_rng(7)
    B, Hkv, rep, hd, P, page, T = 3, 2, 2, 64, 7, 16, 4
    q = jnp.asarray(rng.standard_normal((B, Hkv, rep, hd)), jnp.float32)
    pool = _quant_pool(rng, P, page, Hkv, hd, 4)
    bt = jnp.asarray(rng.integers(1, P, (B, T)).astype(np.int32))
    ctx = jnp.asarray([1, 17, T * page], jnp.int32)
    want = ref.paged_attention_quant_ref(q, *pool, bt, ctx,
                                         window=window, cap=cap)
    got = paged_attention_quant(q, *pool, bt, ctx, window=window, cap=cap,
                                interpret=True)
    assert float(jnp.abs(got - want).max()) < 1e-5


def test_quant_oracle_approaches_fp_oracle_with_bits():
    """At 8 bits the dequantized pool attends like the raw pool."""
    rng = np.random.default_rng(3)
    B, Hkv, rep, hd, P, page, T = 3, 2, 2, 64, 6, 8, 3
    q = jnp.asarray(rng.standard_normal((B, Hkv, rep, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((P, page, Hkv, hd)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((P, page, Hkv, hd)), jnp.float32)
    bt = jnp.asarray(rng.integers(1, P, (B, T)).astype(np.int32))
    ctx = jnp.asarray([1, 10, T * page], jnp.int32)
    want = ref.paged_attention_ref(q, kp, vp, bt, ctx)
    errs = []
    for bits in (2, 4, 8):
        pool = kv_quantize(kp, bits) + kv_quantize(vp, bits)
        got = ref.paged_attention_quant_ref(q, *pool, bt, ctx)
        errs.append(float(jnp.abs(got - want).max()))
    # random N(0,1) K/V is the adversarial case (softmax amplifies any
    # coding error), so gate the decay, not a small absolute bound
    assert errs[0] > errs[1] > errs[2], errs
    assert errs[2] < errs[0] / 3, errs


# ---------------------------------------------------------------------------
# COW fork on quantized pages
# ---------------------------------------------------------------------------

def test_copy_pages_moves_codes_and_scales():
    """A COW fork on a quantized pool must copy every page leaf — sign
    codes AND alpha/beta scales; a fork that moved only the codes would
    dequantize the destination with the null page's zero scales."""
    cfg = _tiny_cfg()
    n_pages = 6
    cache = init_paged_cache(cfg, n_pages=n_pages, page_size=8, max_seqs=2,
                             kv_bits=4)
    key = KEY

    def fill(leaf):
        nonlocal key
        key, k = jax.random.split(key)
        if leaf.dtype == jnp.uint32:
            val = jax.random.randint(k, leaf[:, 2].shape, 0, 2**31 - 1,
                                     dtype=jnp.uint32)
        else:
            val = jax.random.normal(k, leaf[:, 2].shape, dtype=leaf.dtype)
        return leaf.at[:, 2].set(val)

    cache = jax.tree.map(
        lambda l: fill(l) if is_page_leaf(l, n_pages) else l, cache)
    out = copy_pages(cache, jnp.asarray([2], jnp.int32),
                     jnp.asarray([4], jnp.int32), n_pages)
    leaves = [l for l in jax.tree.leaves(out) if is_page_leaf(l, n_pages)]
    # k/v x codes/alphas/betas (layers stack along the scan-group axis)
    assert len(leaves) == 6
    for leaf in leaves:
        assert bool((leaf[:, 2] == leaf[:, 4]).all())
        # the source page was random, so a dst full of zeros means the
        # copy silently skipped this leaf
        assert float(jnp.abs(leaf[:, 4].astype(jnp.float32)).sum()) > 0


# ---------------------------------------------------------------------------
# end-to-end: quantized pool vs fp pool on the trained toy model
# ---------------------------------------------------------------------------

def _trained():
    from repro.data.pretrained import get_trained_lm
    return get_trained_lm("tiny-lm", steps=40)


def _serve(cfg, params, prompts, *, kv_bits, prefix_sharing=False,
           max_new=10):
    from repro.data import ByteTokenizer
    from repro.serve import Request, ServeEngine
    tok = ByteTokenizer()
    eng = ServeEngine(cfg, params, batch_size=2, max_len=160,
                      dtype="float32", cache_kind="paged", page_size=16,
                      kv_bits=kv_bits, prefix_sharing=prefix_sharing)
    reqs = [Request(prompt=tok.encode(p), max_new_tokens=max_new)
            for p in prompts]
    eng.run(reqs)
    return [list(r.out) for r in reqs], eng


def test_quantized_greedy_matches_fp():
    """The acceptance gate: 4-bit binary-coded pages produce the same
    greedy generations as raw fp32 pages on the lightly-trained toy
    model (the model the CI serve smokes train, steps=40)."""
    cfg, params = _trained()
    prompts = ["the ancient city", "a famous museum", "this railway",
               "the council"]
    fp, _ = _serve(cfg, params, prompts, kv_bits=0)
    q4, eng = _serve(cfg, params, prompts, kv_bits=4)
    assert q4 == fp
    stats = eng.stats_snapshot()
    assert stats.kv_bits == 4
    assert stats.kv_bytes_per_page == eng.kv.bytes_per_page()
    assert stats.kv_pool_bytes == eng.kv.pool_bytes()


def test_quantized_cow_fork_end_to_end():
    """Prefix sharing + COW on a quantized pool: requests sharing a
    prompt prefix then diverging must generate exactly what they
    generate with sharing disabled — and the run must actually fork
    (cow_forks > 0), or the test is vacuous."""
    cfg, params = _trained()
    prompts = ["the ancient city walls", "the ancient city gates",
               "the ancient city was"]
    shared, eng = _serve(cfg, params, prompts, kv_bits=4,
                         prefix_sharing=True)
    unshared, _ = _serve(cfg, params, prompts, kv_bits=4)
    assert shared == unshared
    assert eng.kv.cow_forks > 0
