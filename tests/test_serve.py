"""Serving engine + end-to-end system test (train -> quantize -> serve)."""
import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serve import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def _tiny_cfg():
    return get_config("tiny-lm").replace(dtype="float32", n_layers=2,
                                         d_model=64, d_ff=128, remat="none")


def test_engine_greedy_matches_manual_decode():
    """Engine output == manual prefill+decode loop (same greedy path)."""
    from repro.models import decode_step, prefill
    import jax.numpy as jnp
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    prompt = np.arange(10, 22, dtype=np.int32) % cfg.vocab_size
    eng = ServeEngine(cfg, p, batch_size=2, max_len=64, dtype="float32")
    req = Request(prompt=prompt.copy(), max_new_tokens=5)
    eng.run([req])
    # manual
    last, cache = prefill(cfg, p, jnp.asarray(prompt[None]), 64)
    toks = [int(jnp.argmax(last[0]))]
    pos = len(prompt)
    for _ in range(4):
        last, cache = decode_step(cfg, p, cache,
                                  jnp.asarray([[toks[-1]]], jnp.int32),
                                  jnp.asarray([pos], jnp.int32))
        toks.append(int(jnp.argmax(last[0])))
        pos += 1
    assert req.out == toks


def test_engine_handles_more_requests_than_slots():
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    eng = ServeEngine(cfg, p, batch_size=2, max_len=48, dtype="float32")
    reqs = [Request(prompt=(np.arange(8) + i).astype(np.int32) % 200,
                    max_new_tokens=4) for i in range(5)]
    done = eng.run(reqs)
    assert all(len(r.out) == 4 for r in done)
    assert eng.stats["tokens"] >= 5 * 3


def test_bucketed_prefill_outputs_identical():
    """Power-of-two prompt bucketing (admission retrace fix) must not
    change outputs: padding K/V is causally masked during prefill and
    overwritten by decode before the mask ever exposes it."""
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)

    def serve(bucket):
        outs = []
        for L in (9, 10, 12, 13):
            eng = ServeEngine(cfg, p, batch_size=2, max_len=64,
                              dtype="float32", bucket_prompts=bucket)
            req = Request(prompt=(np.arange(L) * 5 + L).astype(np.int32)
                          % cfg.vocab_size, max_new_tokens=5)
            eng.run([req])
            outs.append((req.out, eng))
        return outs

    bucketed = serve(True)
    exact = serve(False)
    assert [o for o, _ in bucketed] == [o for o, _ in exact]


def test_bucketed_prefill_amortizes_traces():
    """Distinct prompt lengths inside one bucket share one prefill trace.
    The prefill jit is borrowed from the process-wide compile cache
    (serve/compile_cache.py), so earlier engines with the same config
    may already have populated it — measure the growth, not the
    absolute entry count: six lengths in the 16 bucket may add at most
    the one 16-bucket trace."""
    cfg = _tiny_cfg()
    p = init_params(cfg, KEY)
    eng = ServeEngine(cfg, p, batch_size=2, max_len=64, dtype="float32")
    before = eng._prefill._cache_size()
    reqs = [Request(prompt=(np.arange(L) + 3).astype(np.int32) % 200,
                    max_new_tokens=2) for L in (9, 10, 11, 12, 14, 16)]
    eng.run(reqs)
    assert eng._prefill._cache_size() - before <= 1


@pytest.mark.slow
def test_system_end_to_end_train_quantize_serve(tmp_path):
    """The whole story: train a tiny LM, GPTQT-quantize (packed), serve,
    and check the quantized model still prefers corpus-like continuations."""
    from repro.core import quantize_model
    from repro.data import batches, calibration_slices, token_stream
    from repro.data.corpus import ByteTokenizer
    from repro.train.optimizer import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config("tiny-lm").replace(dtype="float32", n_layers=2,
                                        d_model=128, d_ff=256, remat="none")
    toks = token_stream("wiki", 60_000)
    tr = Trainer(cfg, TrainerConfig(steps=30, ckpt_every=100,
                                    ckpt_dir=str(tmp_path), log_every=100,
                                    opt=AdamWConfig(lr=2e-3,
                                                    master_fp32=False)),
                 batches(toks, 8, 96, seed=0), dtype="float32")
    out = tr.run()
    assert out["final_loss"] < 3.0   # learnable corpus

    from repro.quant import QuantSpec
    sl = calibration_slices(toks, 8, 96, seed=1)
    qp, _ = quantize_model(
        cfg, tr.params, [sl[:4], sl[4:]],
        spec=QuantSpec.from_config(cfg.quant, method="gptqt",
                                   mode="packed"))
    tok = ByteTokenizer()
    eng = ServeEngine(cfg, qp, batch_size=2, max_len=128, dtype="float32")
    req = Request(prompt=tok.encode("the ancient city "), max_new_tokens=12)
    eng.run([req])
    text = tok.decode(req.out)
    assert len(text) > 0
    # decoded bytes must be printable ascii-ish (the corpus alphabet)
    assert all(32 <= b < 127 for b in tok.encode(text))


def test_tpot_average_skips_single_token_requests():
    """TPOT has no after-first-token interval for a 1-token generation;
    the average must cover the same filtered sample list the percentile
    export sees, not be deflated by structural 0.0s."""
    from repro.serve.kv_cache import PagedKVCache
    from repro.serve.scheduler import RequestMetrics, Scheduler, _Entry

    kv = PagedKVCache(None, n_pages=8, page_size=4, max_seqs=2,
                      create_pool=False)
    sched = Scheduler(kv)

    def entry(n_gen, t_done):
        m = RequestMetrics(t_submit=0.0, t_first_token=1.0, t_done=t_done,
                           n_generated=n_gen)
        return _Entry(req=None, prompt=np.zeros(1, np.int32), metrics=m)

    entries = [entry(1, 1.0),       # single token: no TPOT sample
               entry(5, 9.0),       # 2.0 s/token
               entry(3, 3.0)]       # 1.0 s/token
    s = sched.metrics_summary(entries)
    assert s["tpot_samples_s"] == [2.0, 1.0]
    assert s["tpot_avg_s"] == pytest.approx(1.5)
    assert s["n_done"] == 3
