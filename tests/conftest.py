import os

# Tests run on the single host CPU device (the dry-run subprocesses set
# their own 512-device flag). Slightly bump the default test speed.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")

# Hypothesis example budgets: PR/tier-1 runs stay fast on the "ci"
# profile; the nightly workflow passes --hypothesis-profile=nightly
# (or HYPOTHESIS_PROFILE=nightly) to crank the property suites up.
# Images without hypothesis fall back to tests/_hypothesis_fallback.py,
# which runs a small fixed number of deterministic examples.
try:
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("ci", max_examples=50, deadline=None)
    _hyp_settings.register_profile("nightly", max_examples=400,
                                   deadline=None)
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:
    pass
