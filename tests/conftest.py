import os

# Tests run on the single host CPU device (the dry-run subprocesses set
# their own 512-device flag). Slightly bump the default test speed.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
