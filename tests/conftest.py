import os

# Tests run on the single host CPU device (the dry-run subprocesses set
# their own 512-device flag). Slightly bump the default test speed.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")

# Hypothesis example budgets: PR/tier-1 runs stay fast on the "ci"
# profile; the nightly workflow passes --hypothesis-profile=nightly
# (or HYPOTHESIS_PROFILE=nightly) to crank the property suites up.
# Without hypothesis the property suites are gated out entirely (each
# test module guards them behind `if given is not None:`); environments
# that are supposed to run them for real — the CI images — set
# REQUIRE_HYPOTHESIS=1 so a broken install fails loudly here instead
# of silently shrinking the suite. Import-substitution shims are banned
# (repro-lint R008).
try:
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("ci", max_examples=50, deadline=None)
    _hyp_settings.register_profile("nightly", max_examples=400,
                                   deadline=None)
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:
    if os.environ.get("REQUIRE_HYPOTHESIS"):
        raise
