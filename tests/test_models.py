"""Per-architecture smoke tests (reduced configs) + decode==forward
equivalence + substrate behaviours (trainer/checkpoint/serve/moe)."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, smoke_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          prefill)
from repro.models.model import loss_fn

KEY = jax.random.PRNGKey(0)


def _smoke(name):
    return smoke_config(name).replace(dtype="float32")


def _inputs(cfg, B, S, key=KEY):
    if cfg.embed_input == "tokens":
        return jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return jax.random.normal(key, (B, S, cfg.d_model))


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_arch_smoke_forward(name):
    cfg = _smoke(name)
    p = init_params(cfg, KEY)
    x = _inputs(cfg, 2, 32)
    logits, aux = forward(cfg, p, x)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not jnp.isnan(logits).any()
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_arch_smoke_train_step(name):
    cfg = _smoke(name)
    p = init_params(cfg, KEY)
    x = _inputs(cfg, 2, 16)
    labels = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    loss, met = loss_fn(cfg, p, {"inputs": x, "labels": labels})
    g = jax.grad(lambda pp: loss_fn(cfg, pp, {"inputs": x,
                                              "labels": labels})[0])(p)
    assert jnp.isfinite(loss)
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", ["qwen3-0.6b", "mixtral-8x7b",
                                  "falcon-mamba-7b", "minicpm3-4b",
                                  "gemma2-27b", "jamba-1.5-large-398b",
                                  "chameleon-34b"])
def test_decode_matches_forward(name):
    cfg = _smoke(name)
    if cfg.moe is not None:   # dropless for exactness
        cf = float(cfg.moe.n_experts) / cfg.moe.top_k
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=cf, inference_capacity_factor=cf))
    p = init_params(cfg, KEY)
    B, S, S0 = 2, 40, 36
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _ = forward(cfg, p, toks)
    last, cache = prefill(cfg, p, toks[:, :S0], 64)
    errs = [float(jnp.abs(last - full[:, S0 - 1]).max())]
    for t in range(S0, S):
        last, cache = decode_step(cfg, p, cache, toks[:, t:t + 1],
                                  jnp.full((B,), t, jnp.int32))
        errs.append(float(jnp.abs(last - full[:, t]).max()))
    assert max(errs) < 2e-4, errs


def test_sliding_window_matches_dense_mask():
    """Chunked attention with window == dense attention with window mask."""
    from repro.models.attention import _attend_chunked, _attend_dense
    rng = jax.random.PRNGKey(3)
    q = jax.random.normal(rng, (2, 64, 4, 16))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (2, 64, 2, 16))
    import repro.models.attention as A
    old = A.KV_CHUNK
    A.KV_CHUNK = 16
    try:
        a = _attend_chunked(q, k, v, causal=True, window=24, cap=None,
                            scale=0.25)
    finally:
        A.KV_CHUNK = old
    b = _attend_dense(q, k, v, causal=True, window=24, cap=None, scale=0.25)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_mamba_chunked_scan_matches_naive():
    from repro.models.mamba import selective_scan
    rng = np.random.default_rng(0)
    B, L, di, ds = 2, 37, 8, 4
    x = jnp.asarray(rng.standard_normal((B, L, di)).astype(np.float32))
    dt = jnp.asarray(rng.random((B, L, di), dtype=np.float32) * 0.1)
    A = -jnp.asarray(rng.random((di, ds), dtype=np.float32) + 0.5)
    Bm = jnp.asarray(rng.standard_normal((B, L, ds)).astype(np.float32))
    Cm = jnp.asarray(rng.standard_normal((B, L, ds)).astype(np.float32))
    h0 = jnp.zeros((B, di, ds))
    y1, hN1 = selective_scan(x, dt, A, Bm, Cm, h0, chunk=8)
    # naive reference
    h = np.zeros((B, di, ds), np.float32)
    ys = []
    for t in range(L):
        a = np.exp(np.asarray(dt[:, t, :, None] * A))
        h = a * h + np.asarray((dt[:, t] * x[:, t]))[:, :, None] * \
            np.asarray(Bm[:, t])[:, None, :]
        ys.append((h * np.asarray(Cm[:, t])[:, None, :]).sum(-1))
    y2 = np.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y1), y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hN1), h, rtol=2e-4, atol=2e-4)


def test_moe_dropless_routes_all_tokens():
    from repro.models.moe import moe_forward
    cfg = _smoke("mixtral-8x7b")
    p = init_params(cfg, KEY)
    moe_p = jax.tree.map(lambda a: a[0], p["blocks"]["L0"]["moe"])
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    out, aux = moe_forward(cfg, moe_p, x, dropless=True)
    assert out.shape == x.shape
    # with dropless capacity, output must differ from zero everywhere a
    # token was routed (all tokens -> no dropped rows)
    assert float(jnp.abs(out).sum(-1).min()) > 0


def test_remat_policies_agree():
    cfg = _smoke("qwen3-0.6b")
    p = init_params(cfg, KEY)
    x = _inputs(cfg, 2, 16)
    outs = [forward(cfg, p, x, remat=r)[0] for r in ("none", "dots", "full")]
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[2]),
                               atol=1e-5)


def test_checkpoint_roundtrip_and_crash_safety():
    from repro.ckpt import CheckpointManager
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep_n=2, async_save=False)
        cm.save(1, tree)
        cm.save(2, jax.tree.map(lambda a: a * 2, tree))
        # simulate crash: a half-written tmp dir + an uncommitted step
        import os
        from pathlib import Path
        (Path(d) / "step_00000003.tmp").mkdir()
        os.makedirs(Path(d) / "step_00000004")
        assert cm.latest_step() == 2
        restored, meta = cm.restore(tree)
        np.testing.assert_allclose(np.asarray(restored["a"]),
                                   np.asarray(tree["a"]) * 2)
        assert meta["step"] == 2


def test_trainer_resume_exact():
    """Same seed/batches: a run interrupted + resumed lands on the same
    params as an uninterrupted run (fault-tolerance correctness)."""
    from repro.data import batches, token_stream
    from repro.train.optimizer import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig
    cfg = get_config("tiny-lm").replace(dtype="float32", n_layers=2,
                                        d_model=64, d_ff=128, remat="none")
    toks = token_stream("wiki", 30_000)

    def data():
        return batches(toks, 4, 32, seed=0)

    opt = AdamWConfig(lr=1e-3, master_fp32=False)
    with tempfile.TemporaryDirectory() as d1:
        t = Trainer(cfg, TrainerConfig(steps=6, ckpt_every=100, ckpt_dir=d1,
                                       log_every=100, opt=opt), data(),
                    dtype="float32")
        t.run()
        p_full = t.params
    with tempfile.TemporaryDirectory() as d2:
        t1 = Trainer(cfg, TrainerConfig(steps=3, ckpt_every=3, ckpt_dir=d2,
                                        log_every=100, opt=opt), data(),
                     dtype="float32")
        t1.run()
        # resume: replay the data stream to position 3 like a restart would
        it = data()
        for _ in range(3):
            next(it)
        t2 = Trainer(cfg, TrainerConfig(steps=6, ckpt_every=100, ckpt_dir=d2,
                                        log_every=100, opt=opt), it,
                     dtype="float32")
        out = t2.run()
        assert out["resumed"]
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(t2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_grad_compression_close_to_exact():
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import make_train_step
    from repro.train.optimizer import adamw_init
    cfg = get_config("tiny-lm").replace(dtype="float32", n_layers=2,
                                        d_model=64, d_ff=128, remat="none")
    p = init_params(cfg, KEY)
    opt = adamw_init(p, AdamWConfig(master_fp32=False))
    batch = {"inputs": jax.random.randint(KEY, (8, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (8, 32), 0, cfg.vocab_size)}
    s_exact = make_train_step(cfg, AdamWConfig(master_fp32=False),
                              microbatches=4)
    s_int8 = make_train_step(cfg, AdamWConfig(master_fp32=False),
                             microbatches=4, grad_compress="int8")
    p1, _, m1 = s_exact(p, opt, batch)
    p2, _, m2 = s_int8(p, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    # parameter updates should be close (int8 error-feedback accumulator)
    num = sum(float(jnp.sum((a - b) ** 2)) for a, b in
              zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    den = sum(float(jnp.sum((a - c) ** 2)) for a, c in
              zip(jax.tree.leaves(p1), jax.tree.leaves(p)))
    assert num / max(den, 1e-12) < 0.05   # <5% relative deviation
