"""Packed-artifact round trips (repro/ckpt/packed.py) and the serving
follow-ups: save -> load bit-exactness, load-quantized boot producing
token-identical output without re-quantizing, device-resident block
tables, and the radix prefix-index page cap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.packed import load_packed, save_packed
from repro.configs import get_config
from repro.core import quantize_model
from repro.models import init_params
from repro.quant import OverrideRule, QuantSpec, QuantizedTensor
from repro.serve import PagedKVCache, RadixPrefixCache, Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def _tiny():
    cfg = get_config("tiny-lm").replace(dtype="float32", n_layers=2)
    p = init_params(cfg, KEY)
    calib = [jax.random.randint(jax.random.fold_in(KEY, i), (2, 48), 0,
                                cfg.vocab_size) for i in range(2)]
    return cfg, p, calib


def _leaves(tree):
    return jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, QuantizedTensor))[0]


# ---------------------------------------------------------------------------
# save -> load
# ---------------------------------------------------------------------------

def test_packed_roundtrip_is_bit_exact(tmp_path):
    cfg, p, calib = _tiny()
    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed",
                                 overrides=(OverrideRule("wv", bits=2),))
    qp, _ = quantize_model(cfg, p, calib, spec=spec)
    save_packed(tmp_path / "m", qp, spec=spec, meta={"arch": "tiny-lm"})
    lp, lspec, meta = load_packed(tmp_path / "m")
    assert lspec == spec and meta["arch"] == "tiny-lm"
    flat_q, flat_l = _leaves(qp), _leaves(lp)
    assert len(flat_q) == len(flat_l)
    for (path_q, leaf_q), (path_l, leaf_l) in zip(flat_q, flat_l):
        assert path_q == path_l
        if isinstance(leaf_q, QuantizedTensor):
            assert isinstance(leaf_l, QuantizedTensor)
            assert leaf_l.k_in == leaf_q.k_in
            assert leaf_l.orig_dtype == leaf_q.orig_dtype
            for f in ("codes", "alphas", "betas"):
                a, b = getattr(leaf_q, f), getattr(leaf_l, f)
                assert a.dtype == b.dtype
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            assert leaf_q.dtype == leaf_l.dtype
            np.testing.assert_array_equal(np.asarray(leaf_q),
                                          np.asarray(leaf_l))


def test_bf16_leaves_roundtrip(tmp_path):
    import jax.numpy as jnp
    tree = {"w": jnp.asarray(np.linspace(-2, 2, 16), jnp.bfloat16)}
    save_packed(tmp_path / "b", tree)
    out, spec, _ = load_packed(tmp_path / "b")
    assert spec is None
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out["w"].view(jnp.uint16)),
        np.asarray(tree["w"].view(jnp.uint16)))


def test_uncommitted_artifact_is_rejected(tmp_path):
    cfg, p, calib = _tiny()
    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed")
    qp, _ = quantize_model(cfg, p, calib, spec=spec)
    d = save_packed(tmp_path / "m", qp, spec=spec)
    (d / "COMMITTED").unlink()
    with pytest.raises(FileNotFoundError, match="COMMITTED"):
        load_packed(d)


def test_loaded_model_serves_identically(tmp_path):
    """--save-quantized / --load-quantized contract: the reloaded packed
    model skips calibration/GPTQ and serves token-identical output."""
    cfg, p, calib = _tiny()
    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed")
    qp, _ = quantize_model(cfg, p, calib, spec=spec)
    save_packed(tmp_path / "m", qp, spec=spec)
    lp, _, _ = load_packed(tmp_path / "m")

    mk = lambda: [Request(prompt=(np.arange(10) * 3 + i).astype(np.int32)
                          % cfg.vocab_size, max_new_tokens=8)
                  for i in range(2)]
    outs = []
    for params in (qp, lp):
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          dtype="float32")
        reqs = mk()
        eng.run(reqs)
        outs.append([r.out for r in reqs])
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# group-wise (G > 1) artifacts
# ---------------------------------------------------------------------------

def test_grouped_packed_roundtrip_and_serving(tmp_path):
    """A G>1 QuantizedTensor tree survives save/load bit-exactly and
    serves token-identically (the PR 3 round-trip, with groups)."""
    cfg, p, calib = _tiny()
    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed",
                                 group_size=64)
    qp, _ = quantize_model(cfg, p, calib, spec=spec)
    # the tree really carries grouped scale leaves
    qts = [l for _, l in _leaves(qp) if isinstance(l, QuantizedTensor)]
    assert qts and all(q.n_groups == q.k_in // 64 for q in qts)
    assert any(q.n_groups > 1 for q in qts)
    save_packed(tmp_path / "g", qp, spec=spec, meta={"arch": "tiny-lm"})
    lp, lspec, _ = load_packed(tmp_path / "g")
    assert lspec.group_size == 64
    for (pq, lq), (pl_, ll) in zip(_leaves(qp), _leaves(lp)):
        assert pq == pl_
        if isinstance(lq, QuantizedTensor):
            assert ll.n_groups == lq.n_groups
            assert ll.group_size == lq.group_size
            for f in ("codes", "alphas", "betas"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(lq, f)), np.asarray(getattr(ll, f)))

    mk = lambda: [Request(prompt=(np.arange(10) * 3 + i).astype(np.int32)
                          % cfg.vocab_size, max_new_tokens=8)
                  for i in range(2)]
    outs = []
    for params in (qp, lp):
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          dtype="float32")
        reqs = mk()
        eng.run(reqs)
        outs.append([r.out for r in reqs])
    assert outs[0] == outs[1]


def test_manifest_records_group_axis(tmp_path):
    import json
    cfg, p, calib = _tiny()
    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed",
                                 group_size=128)
    qp, _ = quantize_model(cfg, p, calib, spec=spec)
    d = save_packed(tmp_path / "m", qp, spec=spec)
    manifest = json.loads((d / "manifest.json").read_text())
    wq = manifest["tree"]["blocks"]["L0"]["attn"]["wq"]
    assert wq["kind"] == "qt"
    assert wq["group_size"] == 128
    assert wq["groups"] == wq["k_in"] // 128


def test_legacy_g1_artifact_warns_under_grouped_spec(tmp_path):
    """A pre-groups artifact (spec carries group_size but leaves are
    per-channel) must warn exactly once on load."""
    import warnings as _w

    from repro.ckpt import packed as packed_mod
    cfg, p, calib = _tiny()
    # simulate the legacy state: solvers ignored group_size -> G=1 leaves
    # but the spec recorded in the manifest still requests groups
    spec_g1 = QuantSpec.from_config(cfg.quant, method="gptqt",
                                    mode="packed")
    qp, _ = quantize_model(cfg, p, calib, spec=spec_g1)
    legacy_spec = spec_g1.replace(group_size=64)
    save_packed(tmp_path / "legacy", qp, spec=legacy_spec)
    packed_mod._WARNED_LEGACY_GROUPS = False
    with pytest.warns(UserWarning, match="per-channel"):
        load_packed(tmp_path / "legacy")
    with _w.catch_warnings():           # one-time: second load is silent
        _w.simplefilter("error")
        load_packed(tmp_path / "legacy")
    packed_mod._WARNED_LEGACY_GROUPS = False


# ---------------------------------------------------------------------------
# manifest v3: sharding metadata, bf16 scales, v2 back-compat
# ---------------------------------------------------------------------------

def test_manifest_v3_records_symbolic_shardings(tmp_path):
    """Every leaf entry carries a symbolic PartitionSpec (axis names, no
    sizes) so any later mesh can place it without re-deriving the rules;
    QT children follow the dense weight they replace."""
    import json
    cfg, p, calib = _tiny()
    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed")
    qp, _ = quantize_model(cfg, p, calib, spec=spec)
    d = save_packed(tmp_path / "m", qp, spec=spec)
    m = json.loads((d / "manifest.json").read_text())
    assert m["format_version"] == 4
    assert m["sharding"]["axes"] == ["data", "model"]
    wq = m["tree"]["blocks"]["L0"]["attn"]["wq"]
    assert wq["pspec"]["codes"][-2:] == ["data", "model"]
    assert wq["pspec"]["alphas"][-3:] == [None, "model", None]
    assert wq["pspec"]["betas"][-1] == "model"
    ln = m["tree"]["blocks"]["L0"]["ln"]
    assert all(a is None for a in ln["pspec"])   # norms replicate


def test_v2_artifact_loads_and_warns_on_mesh(tmp_path):
    """A v2 manifest (pre-sharding-metadata) must keep loading; with a
    mesh it can only replicate, and says so once."""
    import json
    import warnings as _w

    from repro.ckpt import packed as packed_mod
    cfg, p, calib = _tiny()
    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed")
    qp, _ = quantize_model(cfg, p, calib, spec=spec)
    d = save_packed(tmp_path / "m", qp, spec=spec)

    # strip the artifact back to v2: no sharding block, no pspec keys
    m = json.loads((d / "manifest.json").read_text())
    m["format_version"] = 2
    m.pop("sharding")

    def strip(node):
        if isinstance(node.get("kind"), str):
            node.pop("pspec", None)
            return
        for v in node.values():
            strip(v)
    strip(m["tree"])
    (d / "manifest.json").write_text(json.dumps(m))

    lp, lspec, _ = load_packed(d)          # meshless load: bit-exact
    for (pq, lq), (pl_, ll) in zip(_leaves(qp), _leaves(lp)):
        if isinstance(lq, QuantizedTensor):
            np.testing.assert_array_equal(np.asarray(lq.codes),
                                          np.asarray(ll.codes))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    packed_mod._WARNED_NO_PSPEC = False
    with pytest.warns(UserWarning, match="REPLICATED"):
        load_packed(d, mesh=mesh)
    with _w.catch_warnings():              # one-time warning
        _w.simplefilter("error")
        load_packed(d, mesh=mesh)
    packed_mod._WARNED_NO_PSPEC = False


def test_future_format_is_refused(tmp_path):
    import json
    cfg, p, calib = _tiny()
    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed")
    qp, _ = quantize_model(cfg, p, calib, spec=spec)
    d = save_packed(tmp_path / "m", qp, spec=spec)
    m = json.loads((d / "manifest.json").read_text())
    m["format_version"] = 99
    (d / "manifest.json").write_text(json.dumps(m))
    with pytest.raises(ValueError, match="newer"):
        load_packed(d)


def test_bf16_scales_halve_bytes_and_stay_within_tolerance(tmp_path):
    """scale_dtype='bfloat16' stores alphas/betas as bf16 bits (half the
    scale bytes of the G>1 overhead), loads back STILL bf16 in memory
    (the decode expand paths upcast per-tile, so fp32 rehydration on
    load would only double resident scale bytes), and serves
    token-identically to an engine fed the same-rounded scales
    directly."""
    cfg, p, calib = _tiny()
    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed",
                                 group_size=64)
    qp, _ = quantize_model(cfg, p, calib, spec=spec)
    d32 = save_packed(tmp_path / "f32", qp, spec=spec)
    d16 = save_packed(tmp_path / "bf16", qp, spec=spec,
                      scale_dtype="bfloat16")

    import json
    a32 = np.load(d32 / "arrays.npz")
    a16 = np.load(d16 / "arrays.npz")
    wq32 = json.loads((d32 / "manifest.json").read_text())[
        "tree"]["blocks"]["L0"]["attn"]["wq"]
    wq16 = json.loads((d16 / "manifest.json").read_text())[
        "tree"]["blocks"]["L0"]["attn"]["wq"]
    assert wq16["scale_dtype"] == "bfloat16" and "scale_dtype" not in wq32
    for f in ("alphas", "betas"):       # stored bytes exactly halved
        assert a16[wq16[f]].dtype == np.uint16
        assert a16[wq16[f]].nbytes * 2 == a32[wq32[f]].nbytes
    assert a16[wq16["codes"]].dtype == np.uint32   # codes untouched

    lp, lspec, _ = load_packed(d16)
    assert lspec.group_size == 64
    for (_, lq), (_, ll) in zip(_leaves(qp), _leaves(lp)):
        if not isinstance(lq, QuantizedTensor):
            continue
        # scales stay bf16 in memory — no fp32 rehydration on load
        assert ll.alphas.dtype == jnp.bfloat16
        assert ll.betas.dtype == jnp.bfloat16
        # exactly one bf16 rounding, no double rounding
        ref = lq.cast_scales("bfloat16")
        np.testing.assert_array_equal(np.asarray(ll.alphas),
                                      np.asarray(ref.alphas))
        np.testing.assert_array_equal(np.asarray(ll.betas),
                                      np.asarray(ref.betas))
        ll = ll.cast_scales("float32")             # for the rel check
        # and the rounding is small: bf16 keeps ~8 mantissa bits
        denom = np.abs(np.asarray(lq.alphas)) + 1e-8
        rel = np.abs(np.asarray(ll.alphas) - np.asarray(lq.alphas)) / denom
        assert float(rel.max()) < 1 / 128

    mk = lambda: [Request(prompt=(np.arange(10) * 3 + i).astype(np.int32)
                          % cfg.vocab_size, max_new_tokens=8)
                  for i in range(2)]
    rounded = jax.tree.map(
        lambda x: (x.cast_scales("bfloat16").cast_scales("float32")
                   if isinstance(x, QuantizedTensor) else x), qp,
        is_leaf=lambda x: isinstance(x, QuantizedTensor))
    outs = []
    for params in (rounded, lp):
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          dtype="float32")
        reqs = mk()
        eng.run(reqs)
        outs.append([r.out for r in reqs])
    assert outs[0] == outs[1]


def test_already_bf16_scales_save_loadable(tmp_path):
    """A tree whose QT scales are ALREADY bf16 (cast_scales) must not
    commit an unreadable artifact: npz would degrade bf16 to a void
    dtype, so save_packed stores the bits + flags the leaf even without
    an explicit scale_dtype."""
    import jax.numpy as jnp
    from repro.quant.packing import pack_signs
    rng = np.random.default_rng(0)
    signs = jnp.asarray(np.sign(rng.standard_normal((2, 32, 8))) + 0.0)
    qt = QuantizedTensor(
        codes=pack_signs(signs),
        alphas=jnp.asarray(rng.standard_normal((1, 8, 2)), jnp.float32),
        betas=jnp.asarray(rng.standard_normal((1, 8)), jnp.float32),
        k_in=32).cast_scales("bfloat16")
    d = save_packed(tmp_path / "m", {"w": qt})
    lp, _, _ = load_packed(d)           # must not raise
    assert lp["w"].alphas.dtype == jnp.bfloat16    # stays bf16 in memory
    np.testing.assert_array_equal(
        np.asarray(lp["w"].alphas.astype(jnp.float32)),
        np.asarray(qt.alphas.astype(jnp.float32)))


# ---------------------------------------------------------------------------
# manifest v4: optional draft-scale block
# ---------------------------------------------------------------------------

def test_v4_draft_block_roundtrips_refit_scales(tmp_path):
    """save_packed(draft_bits=d) stores per-leaf re-fit draft scales as
    the manifest-v4 optional block; load_draft_scales returns them
    bit-exact to the on-the-fly refit, so a --speculate boot from the
    artifact builds the identical draft tree without the solve. An
    artifact saved without the block returns None (v3-style fallback)."""
    import json

    from repro.ckpt.packed import load_draft_scales
    from repro.quant.draft import make_draft_params
    cfg, p, calib = _tiny()
    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed")
    qp, _ = quantize_model(cfg, p, calib, spec=spec)
    d = save_packed(tmp_path / "m", qp, spec=spec, draft_bits=2)
    assert load_draft_scales(
        save_packed(tmp_path / "plain", qp, spec=spec)) is None

    m = json.loads((d / "manifest.json").read_text())
    assert m["format_version"] == 4 and m["draft_bits"] == 2
    wq = m["tree"]["blocks"]["L0"]["attn"]["wq"]
    assert wq["draft"]["bits"] == 2

    lp, _, _ = load_packed(d)
    tree = load_draft_scales(d)
    assert tree is not None
    from_block = make_draft_params(lp, 2, tree)
    refit = make_draft_params(lp, 2)
    for (path, a), (_, b) in zip(_leaves(from_block), _leaves(refit)):
        if not isinstance(a, QuantizedTensor):
            continue
        assert a.bits == 2 and a.stored_bits == 3
        assert a.codes is b.codes            # shared sign planes
        np.testing.assert_array_equal(np.asarray(a.alphas),
                                      np.asarray(b.alphas))
        np.testing.assert_array_equal(np.asarray(a.betas),
                                      np.asarray(b.betas))
    # mismatched draft_bits must ignore the stored block, not misuse it
    w3 = make_draft_params(lp, 1, tree)
    for _, leaf in _leaves(w3):
        if isinstance(leaf, QuantizedTensor):
            assert leaf.bits == 1


# ---------------------------------------------------------------------------
# device-resident block tables
# ---------------------------------------------------------------------------

def test_device_block_tables_track_host_incrementally():
    cfg = get_config("tiny-lm").replace(dtype="float32", n_layers=2,
                                        d_model=64, d_ff=128, remat="none")
    p = init_params(cfg, KEY)
    mk = lambda: [Request(prompt=(np.arange(12) * 3 + i).astype(np.int32)
                          % cfg.vocab_size, max_new_tokens=8)
                  for i in range(4)]
    dense = ServeEngine(cfg, p, batch_size=2, max_len=64, dtype="float32")
    want = mk()
    dense.run(want)
    eng = ServeEngine(cfg, p, batch_size=2, max_len=64, dtype="float32",
                      cache_kind="paged", page_size=8)
    got = mk()
    eng.run(got)
    assert [r.out for r in got] == [r.out for r in want]
    # the mirror converges to the host tables after a sync, and rows the
    # allocator never touched since the last sync are not re-uploaded
    eng._sync_block_tables()
    np.testing.assert_array_equal(np.asarray(eng._bt_dev),
                                  eng.kv.block_tables)
    applied = eng._bt_applied.copy()
    eng._sync_block_tables()            # no version moved -> no-op
    np.testing.assert_array_equal(applied, eng._bt_applied)


def test_bt_versions_bump_on_every_mutation():
    kv = PagedKVCache(None, n_pages=9, page_size=4, max_seqs=2,
                      create_pool=False)
    s = kv.alloc_slot()
    v0 = kv.bt_version[s]
    kv.ensure(s, 6)
    assert kv.bt_version[s] > v0
    v1 = kv.bt_version[s]
    kv.ensure(s, 6)                     # no growth -> no bump
    assert kv.bt_version[s] == v1
    s2 = kv.alloc_slot()
    kv.share(s2, kv.owned_pages(s)[:1])
    assert kv.bt_version[s2] > 0
    v2 = kv.bt_version[s2]
    kv.cow_for_write(s2, 0, 2)          # forks the shared page
    assert kv.bt_version[s2] > v2
    v3 = kv.bt_version[s]
    kv.release(s)
    assert kv.bt_version[s] > v3


# ---------------------------------------------------------------------------
# radix prefix-index page cap
# ---------------------------------------------------------------------------

def test_prefix_index_cap_bounds_retained_pages():
    kv = PagedKVCache(None, n_pages=33, page_size=4, max_seqs=4,
                      create_pool=False)
    idx = RadixPrefixCache(kv, max_cached_pages=6)
    for i in range(10):                 # 10 distinct 8-token prefixes
        s = kv.alloc_slot()
        kv.ensure(s, 8)
        idx.insert(np.arange(8) + 100 * i, kv.owned_pages(s))
        kv.release(s)
        assert idx.cached_pages() <= 6
        assert idx.cached_pages() == idx._count_nodes()
    assert idx.evictions >= 8           # 20 inserted pages, 6 kept
    # conservation holds through cap eviction
    assert kv.live_pages + kv.free_page_count == kv.usable_pages
    assert kv.live_pages == idx.cached_pages()


def test_prefix_cap_never_evicts_pages_referenced_by_sequences():
    kv = PagedKVCache(None, n_pages=9, page_size=4, max_seqs=2,
                      create_pool=False)
    idx = RadixPrefixCache(kv, max_cached_pages=1)
    s = kv.alloc_slot()
    kv.ensure(s, 8)
    idx.insert(np.arange(8), kv.owned_pages(s))   # slot still holds refs
    # over cap, but both pages are pinned by the running sequence
    assert idx.cached_pages() == 2
    assert idx.lookup(np.arange(8))[0] == 8
    kv.release(s)                       # now index-only ...
    s2 = kv.alloc_slot()
    kv.ensure(s2, 4)
    idx.insert(np.asarray([50, 51, 52, 53]), kv.owned_pages(s2))
    kv.release(s2)
    assert idx.cached_pages() <= 1      # ... and the next insert enforces


def test_engine_default_cap_leaves_slot_headroom():
    cfg = get_config("tiny-lm").replace(dtype="float32", n_layers=2,
                                        d_model=64, d_ff=128, remat="none")
    p = init_params(cfg, KEY)
    eng = ServeEngine(cfg, p, batch_size=2, max_len=64, dtype="float32",
                      cache_kind="paged", page_size=8)
    assert eng._prefix.max_cached_pages == eng.kv.usable_pages - 2
    eng2 = ServeEngine(cfg, p, batch_size=2, max_len=64, dtype="float32",
                       cache_kind="paged", page_size=8, prefix_max_pages=3)
    assert eng2._prefix.max_cached_pages == 3
    reqs = [Request(prompt=(np.arange(20) + 13 * i).astype(np.int32)
                    % cfg.vocab_size, max_new_tokens=4) for i in range(5)]
    eng2.run(reqs)
    assert all(r.done for r in reqs)
    assert eng2._prefix.cached_pages() <= 3
