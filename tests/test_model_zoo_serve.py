"""Cross-architecture serving conformance matrix.

One paged serving stack covers the whole model zoo: plain attention
pages K/V per head, MLA pages the compressed latent cache (models/
mla.py: ckv_pages + kpe_pages), Mamba-mix models pool fixed-size state
slabs beside the attention pages (serve/state_slab.py), and MoE runs
batched-expert BCQ through the same dispatch layer. The invariant this
file pins down: for every architecture x weight precision, the paged
engine is greedy token-identical to the dense engine on the same
params — paging, slab admission, preemption and prefix attach are
memory-management choices, never numerics.

Matrix: {attention, MLA, Mamba-mix, MoE} x {fp, w3/w4 packed} x
{dense, paged}, plus MLA preemption-exactness and prefix-attach
(mirroring tests/test_paged_kv.py / test_prefix_sharing.py for the
latent cache).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import init_params
from repro.serve import Request, ServeEngine

KEY = jax.random.PRNGKey(0)

# arch label -> (registry name, packed bits for the quantized column)
ARCHES = {
    "attention": ("tiny-lm", 3),
    "mla": ("minicpm3-4b", 3),
    "mamba-mix": ("jamba-1.5-large-398b", 4),
    "moe": ("mixtral-8x7b", 4),
}

_state: dict = {}


def _arch_state(arch):
    """Per-arch cfg + fp and packed params, built once per session."""
    if arch in _state:
        return _state[arch]
    name, bits = ARCHES[arch]
    cfg = smoke_config(name).replace(dtype="float32", remat="none")
    if cfg.quant.bits != bits:
        cfg = cfg.replace(quant=dataclasses.replace(cfg.quant, bits=bits))
    p = init_params(cfg, KEY)

    from repro.core import quantize_model
    from repro.quant import QuantSpec
    calib = [jax.random.randint(jax.random.fold_in(KEY, i), (2, 32), 0,
                                cfg.vocab_size) for i in range(2)]
    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed")
    qp, _ = quantize_model(cfg, p, calib, spec=spec)
    _state[arch] = {"cfg": cfg, "fp": p, "quant": qp}
    return _state[arch]


def _reqs(cfg, n=3, max_new=5, seed=0):
    out = []
    for i in range(n):
        L = 4 + 3 * ((i + seed) % 3)            # mixed prompt lengths
        out.append(Request(prompt=(np.arange(L) * 7 + 11 * i + seed)
                           .astype(np.int32) % cfg.vocab_size,
                           max_new_tokens=max_new))
    return out


def _serve(cfg, params, reqs, **kw):
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      dtype="float32", **kw)
    eng.run(reqs)
    return [r.out for r in reqs], eng


# ---------------------------------------------------------------------------
# the matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant", ["fp", "quant"])
@pytest.mark.parametrize("arch", list(ARCHES))
def test_paged_matches_dense_greedy(arch, quant):
    st = _arch_state(arch)
    cfg, params = st["cfg"], st[quant]
    want, _ = _serve(cfg, params, _reqs(cfg))
    got, eng = _serve(cfg, params, _reqs(cfg), cache_kind="paged",
                      page_size=8)
    assert got == want
    # every generated token really flowed through the paged stack
    assert eng.stats["tokens"] > 0
    kv = eng.kv
    if hasattr(kv, "live_pages"):
        assert kv.live_pages + kv.free_page_count == kv.usable_pages
    if eng.slab is not None:
        # all slabs returned at completion; conservation holds
        assert eng.slab.live_slabs == 0
        assert eng.slab.free_slab_count == eng.slab.usable_slabs
        assert eng.slab.high_water > 0


def test_matrix_covers_every_cache_topology():
    """The four archs really exercise four distinct cache layouts: K/V
    pages, latent pages, state slabs beside pages, and expert stacks."""
    a = _arch_state("attention")["cfg"]
    assert a.mla is None and a.mamba is None and a.moe is None
    m = _arch_state("mla")["cfg"]
    assert m.mla is not None
    x = _arch_state("mamba-mix")["cfg"]
    assert x.mamba is not None and any(s.kind != "attn" for s in x.pattern)
    assert any(s.kind == "attn" for s in x.pattern)
    e = _arch_state("moe")["cfg"]
    assert e.moe is not None


# ---------------------------------------------------------------------------
# MLA: preemption exactness + prefix attach on the latent cache
# ---------------------------------------------------------------------------

def test_mla_preemption_by_eviction_resumes_exactly():
    """Latent pages evict and recompute like K/V pages: a pool too small
    for both sequences forces LIFO preemption mid-decode, and the
    resumed sequence regenerates token-identical output."""
    st = _arch_state("mla")
    cfg, p = st["cfg"], st["fp"]
    mk = lambda: [Request(prompt=(np.arange(6) * 3 + i).astype(np.int32)
                          % cfg.vocab_size, max_new_tokens=14)
                  for i in range(2)]
    want, _ = _serve(cfg, p, mk())
    got, eng = _serve(cfg, p, mk(), cache_kind="paged", page_size=8,
                      n_pages=5)
    assert eng.sched.preemptions > 0
    assert got == want


def test_mla_prefix_attach_skips_prefill_and_pages():
    """Radix prefix sharing works unchanged over latent pages: the
    second request attaches the shared prefix's pages by reference and
    prefills only its suffix."""
    st = _arch_state("mla")
    cfg, p = st["cfg"], st["fp"]
    page = 8
    prefix = (np.arange(4 * page, dtype=np.int32) * 3 + 5) % cfg.vocab_size
    tail = lambda i: (np.arange(100 + i * 7, 100 + i * 7 + page)
                      % cfg.vocab_size)
    mk = lambda: [Request(prompt=np.concatenate([prefix, tail(i)])
                          .astype(np.int32), max_new_tokens=5)
                  for i in range(2)]

    def serve(sharing):
        eng = ServeEngine(cfg, p, batch_size=2, max_len=64,
                          dtype="float32", cache_kind="paged",
                          page_size=page, prefix_sharing=sharing)
        rs = mk()
        eng.run(rs)
        return [r.out for r in rs], eng

    want, base = serve(False)
    got, eng = serve(True)
    assert got == want
    assert eng.stats["prefix_hits"] == 1
    assert eng.stats["prefix_tokens_saved"] == len(prefix)
    # aligned prefix: its latent pages were attached, not allocated
    assert (base.kv.pages_allocated - eng.kv.pages_allocated
            == len(prefix) // page)
    assert eng.stats["cow_forks"] == 0
