"""Roofline machinery units: wire-factor math, extrapolation, hlo profile."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # not installed: property tests below are gated out
    given = settings = st = None

from repro.launch import dryrun as dr
from repro.roofline.analysis import (CollectiveStats, parse_collectives,
                                     roofline_terms)
from repro.roofline.hlo_profile import profile_hlo


def test_extrapolate_linear_recovery():
    """If cost is exactly base + g*delta, the 2/3-probe recovers it."""
    base, delta, G = 7.0, 3.0, 40
    c2 = {"flops": base + 2 * delta, "bytes": 10 + 2 * 2.0,
          "wire_bytes": 1 + 2 * 0.5, "coll_count": 8}
    c3 = {"flops": base + 3 * delta, "bytes": 10 + 3 * 2.0,
          "wire_bytes": 1 + 3 * 0.5, "coll_count": 11}
    out = dr._extrapolate(c2, c3, G)
    assert abs(out["flops"] - (base + G * delta)) < 1e-9
    assert abs(out["bytes"] - (10 + G * 2.0)) < 1e-9
    assert abs(out["wire_bytes"] - (1 + G * 0.5)) < 1e-9
    assert out["coll_count_per_group"] == 3


if given is not None:
    @given(st.floats(0, 1e15), st.floats(0, 1e15), st.floats(0, 1e15))
    @settings(max_examples=30, deadline=None)
    def test_roofline_bound_is_max_term(f, b, w):
        st_ = CollectiveStats(total_wire_bytes=w)
        r = roofline_terms({"flops": f, "bytes accessed": b}, st_)
        assert r["t_bound_s"] >= r["t_compute_s"] - 1e-12
        assert r["t_bound_s"] >= r["t_memory_s"] - 1e-12
        assert r["t_bound_s"] >= r["t_collective_s"] - 1e-12
        assert 0.0 <= r["roofline_mfu"] <= 1.0 + 1e-9


def test_parse_collectives_async_pairs_counted_once():
    hlo = """
  %ag0 = bf16[64,64]{1,0} all-gather-start(%x), replica_groups=[4,2]<=[8]
  %ag1 = bf16[64,64]{1,0} all-gather-done(%ag0)
"""
    st_ = parse_collectives(hlo)
    # -start matches, -done does not
    assert st_.count == 1
    assert abs(st_.total_wire_bytes - 64 * 64 * 2 * 0.5) < 1e-6


def test_profile_hlo_groups_by_kind():
    hlo = """
  %d = f32[128,128]{1,0} dot(%a, %b), lhs_contracting_dims={1}
  %c = f32[128,128]{1,0} convert(%d)
  ROOT %t = (f32[128,128]{1,0}) tuple(%c)
"""
    p = profile_hlo(hlo)
    kinds = dict(p["by_kind"])
    assert kinds["dot"]["bytes"] == 128 * 128 * 4
    assert kinds["convert"]["count"] == 1


def test_wire_factors_ordering():
    """all-reduce must cost 2x all-gather for the same payload/group."""
    base = "replica_groups=[8,32]<=[256]"
    h1 = f"%a = f32[1024]{{0}} all-gather(%x), {base}"
    h2 = f"%a = f32[1024]{{0}} all-reduce(%x), {base}"
    ag = parse_collectives(h1).total_wire_bytes
    ar = parse_collectives(h2).total_wire_bytes
    assert abs(ar / ag - 2.0) < 1e-9
