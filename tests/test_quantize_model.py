"""Model-level quantization integration: calibration taps, all methods,
fake vs packed equivalence, Pallas dispatch, quantized serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core import quantize_model
from repro.kernels import ops
from repro.models import decode_step, forward, init_params, prefill
from repro.quant import QuantSpec

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("tiny-lm").replace(dtype="float32", n_layers=2)
    p = init_params(cfg, KEY)
    calib = [jax.random.randint(jax.random.fold_in(KEY, i), (2, 48), 0,
                                cfg.vocab_size) for i in range(2)]
    test = jax.random.randint(jax.random.fold_in(KEY, 99), (2, 48), 0,
                              cfg.vocab_size)
    base, _ = forward(cfg, p, test)
    return cfg, p, calib, test, base


ALL_METHODS = ["rtn", "gptq", "gptq_minmse", "gptq_bcq", "bcq", "gptqt"]


@pytest.mark.parametrize("method", ALL_METHODS)
def test_all_methods_produce_finite_models(tiny_setup, method):
    cfg, p, calib, test, base = tiny_setup
    qp, rep = quantize_model(cfg, p, calib,
                             spec=QuantSpec.from_config(cfg.quant,
                                                        method=method))
    logits, _ = forward(cfg, qp, test)
    assert jnp.isfinite(logits).all()
    assert len(rep) > 0
    for st in rep.values():
        assert np.isfinite(st["err"])


def test_fake_equals_packed(tiny_setup):
    cfg, p, calib, test, _ = tiny_setup
    spec = QuantSpec.from_config(cfg.quant, method="gptqt")
    qf, _ = quantize_model(cfg, p, calib, spec=spec.replace(mode="fake"))
    qp, _ = quantize_model(cfg, p, calib, spec=spec.replace(mode="packed"))
    lf, _ = forward(cfg, qf, test)
    lp, _ = forward(cfg, qp, test)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lp), atol=1e-5)


def test_packed_pallas_interpret_matches_ref(tiny_setup):
    cfg, p, calib, test, _ = tiny_setup
    qp, _ = quantize_model(
        cfg, p, calib,
        spec=QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed"))
    l_ref, _ = forward(cfg, qp, test)
    ops.FORCE_PALLAS = True
    try:
        l_pal, _ = forward(cfg, qp, test)
    finally:
        ops.FORCE_PALLAS = None
    np.testing.assert_allclose(np.asarray(l_pal), np.asarray(l_ref),
                               rtol=1e-4, atol=1e-4)


def test_quantized_decode_matches_quantized_forward(tiny_setup):
    cfg, p, calib, _, _ = tiny_setup
    qp, _ = quantize_model(
        cfg, p, calib,
        spec=QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed"))
    toks = jax.random.randint(KEY, (2, 24), 0, cfg.vocab_size)
    full, _ = forward(cfg, qp, toks)
    last, cache = prefill(cfg, qp, toks[:, :20], 32)
    errs = [float(jnp.abs(last - full[:, 19]).max())]
    for t in range(20, 24):
        last, cache = decode_step(cfg, qp, cache, toks[:, t:t + 1],
                                  jnp.full((2,), t, jnp.int32))
        errs.append(float(jnp.abs(last - full[:, t]).max()))
    assert max(errs) < 2e-4


def test_moe_expert_quantization():
    cfg = smoke_config("mixtral-8x7b").replace(dtype="float32")
    p = init_params(cfg, KEY)
    calib = [jax.random.randint(KEY, (2, 48), 0, cfg.vocab_size)]
    qp, rep = quantize_model(
        cfg, p, calib,
        spec=QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed"))
    logits, _ = forward(cfg, qp, calib[0])
    assert jnp.isfinite(logits).all()
    # expert leaves became QuantizedTensor stacks
    from repro.quant import QuantizedTensor
    moe_wg = qp["blocks"]["L0"]["moe"]["wg"]
    assert isinstance(moe_wg, QuantizedTensor)
    assert moe_wg.shape == p["blocks"]["L0"]["moe"]["wg"].shape


def test_mamba_arch_quantization():
    cfg = smoke_config("falcon-mamba-7b").replace(dtype="float32")
    p = init_params(cfg, KEY)
    calib = [jax.random.randint(KEY, (2, 48), 0, cfg.vocab_size)]
    qp, rep = quantize_model(
        cfg, p, calib, spec=QuantSpec.from_config(cfg.quant, method="gptqt"))
    logits, _ = forward(cfg, qp, calib[0])
    assert jnp.isfinite(logits).all()
    # excluded projections stayed dense (cfg.quant.exclude)
    assert isinstance(qp["blocks"]["L0"]["mamba"]["x_proj"], jax.Array)


def test_quantized_bytes_ratio():
    """Packed 3-bit weights must be ~5x smaller than f32 (or ~2.7x vs
    bf16) including alpha/beta overhead."""
    cfg = get_config("tiny-lm").replace(dtype="float32", n_layers=2)
    p = init_params(cfg, KEY)
    calib = [jax.random.randint(KEY, (2, 48), 0, cfg.vocab_size)]
    qp, _ = quantize_model(
        cfg, p, calib,
        spec=QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed"))
    from repro.quant import QuantizedTensor
    w = p["blocks"]["L0"]["attn"]["wq"]
    qw = qp["blocks"]["L0"]["attn"]["wq"]
    assert isinstance(qw, QuantizedTensor)
    dense_bytes = w.size * 4
    assert qw.packed_bytes() < dense_bytes * 0.30
