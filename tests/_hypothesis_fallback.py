"""Minimal stand-in for `hypothesis` when it isn't installed (bare local
environments): runs each property test on `max_examples` deterministic
pseudo-random draws from the strategy space, seeded by the test name so
failures reproduce. Only the tiny API surface the suite uses.

Environments that are SUPPOSED to have the real package (the CI images
install it) set REQUIRE_HYPOTHESIS=1: importing this shim then raises
immediately, so a broken/missing hypothesis install fails the run
loudly instead of being silently masked by the fallback's much weaker
example generation.
"""
from __future__ import annotations

import os
import random

if os.environ.get("REQUIRE_HYPOTHESIS"):
    raise ImportError(
        "REQUIRE_HYPOTHESIS is set but the real `hypothesis` package "
        "failed to import — refusing to substitute the fallback shim "
        "(install hypothesis in this image, or unset REQUIRE_HYPOTHESIS "
        "to accept the weaker deterministic fallback)")


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda r: bool(r.getrandbits(1)))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements))


def settings(**kw):
    def deco(fn):
        fn._fallback_settings = kw
        return fn
    return deco


def given(*strats):
    def deco(fn):
        # deliberately NOT functools.wraps: pytest must see a zero-arg
        # signature, not the strategy parameters (it would treat them
        # as fixtures)
        def run():
            # @settings may sit above OR below @given — check both
            cfg = (getattr(run, "_fallback_settings", None)
                   or getattr(fn, "_fallback_settings", {}))
            n = cfg.get("max_examples", 10)
            rng = random.Random(fn.__name__)
            for _ in range(n):
                fn(*[s.example(rng) for s in strats])
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        return run
    return deco
