"""Randomized scheduler fuzz: seeded random workloads (arrival order,
prompt lengths incl. shared prefixes, page-pool pressure forcing
preemption and index reclaim) must produce greedy outputs token-identical
to the dense-engine oracle, for every combination of page size, pool
size, chunked prefill, and prefix sharing the paged engine supports.

Engines are built once per pool shape and reused across examples (a
fresh ServeEngine means a fresh jit cache, far too slow per example),
but every example starts by clearing the radix index, so a falsifying
seed replays identically on its own — required for hypothesis shrinking
to be trustworthy. Cross-run index reuse (prefix hits on pages a
*previous* run parked, COW forks on stale tails, LRU reclaim) is still
covered deterministically: each example serves two seeded waves through
the same engine, and the second wave runs against the first wave's
accumulated index.
"""
import numpy as np
import jax
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # not installed: property tests below are gated out
    given = settings = st = None

from repro.configs import get_config
from repro.models import init_params
from repro.serve import Request, ServeEngine

BATCH, MAX_LEN = 3, 48
# (page_size, n_pages, prefill_chunk): small pools force preemption;
# chunked variants interleave prefill chunks with decode ticks
POOLS = [(8, 6, None), (8, 9, 5), (16, 6, 5), (16, 9, None)]
# (speculate_k, draft): the speculative axis reuses the pool-pressure
# workload — "perfect" drafts accept everything (bursts of k+1 tokens
# grow sequences fast), "adversarial" drafts accept ~nothing (every
# tick over-allocates k positions and truncates them back)
SPECS = [(2, "perfect"), (4, "perfect"), (2, "adversarial"),
         (4, "adversarial")]

_state = {}


def _setup():
    if _state:
        return _state
    cfg = get_config("tiny-lm").replace(dtype="float32", n_layers=2,
                                        d_model=64, d_ff=128, remat="none")
    params = init_params(cfg, jax.random.PRNGKey(0))
    _state["cfg"] = cfg
    _state["params"] = params
    _state["dense"] = ServeEngine(cfg, params, batch_size=BATCH,
                                  max_len=MAX_LEN, dtype="float32")
    _state["paged"] = {
        key: ServeEngine(cfg, params, batch_size=BATCH, max_len=MAX_LEN,
                         dtype="float32", cache_kind="paged",
                         page_size=key[0], n_pages=key[1],
                         prefill_chunk=key[2])
        for key in POOLS
    }
    drafts = {"perfect": params,
              "adversarial": init_params(cfg, jax.random.PRNGKey(1))}
    _state["spec"] = {
        (k, d): ServeEngine(cfg, params, batch_size=BATCH,
                            max_len=MAX_LEN, dtype="float32",
                            cache_kind="paged", page_size=8, n_pages=9,
                            speculate=k, draft_params=drafts[d])
        for k, d in SPECS
    }
    # two long base sequences; workload prompts share prefixes of them
    rng = np.random.default_rng(7)
    _state["bases"] = [rng.integers(1, cfg.vocab_size, 16).astype(np.int32)
                       for _ in range(2)]
    return _state


def _workload(rng, vocab, bases):
    reqs = []
    for _ in range(rng.integers(2, 5)):
        if rng.random() < 0.65:
            base = bases[int(rng.integers(0, len(bases)))]
            cut = int(rng.integers(2, len(base)))
            tail_n = int(rng.integers(1, 5))
            tail = rng.integers(1, vocab, tail_n).astype(np.int32)
            prompt = np.concatenate([base[:cut], tail])
        else:
            prompt = rng.integers(1, vocab,
                                  int(rng.integers(3, 13))).astype(np.int32)
        # occasional long generations outgrow the small pools mid-decode
        # and force preemption-by-eviction (+ exact recompute-on-resume)
        max_new = int(rng.integers(8, 15) if rng.random() < 0.3
                      else rng.integers(2, 6))
        reqs.append((prompt, max_new))
    return reqs


def _serve(eng, reqs):
    rs = [Request(prompt=p.copy(), max_new_tokens=n) for p, n in reqs]
    eng.run(rs)
    return [r.out for r in rs]


def _check_pool(kv):
    assert kv.live_pages + kv.free_page_count == kv.usable_pages
    for s in range(kv.max_seqs):
        assert not kv.owned_pages(s)


if given is not None:
    @settings(deadline=None)
    @given(st.integers(0, 10**6))
    def test_paged_sharing_matches_dense_oracle(seed):
        state = _setup()
        rng = np.random.default_rng(seed)
        key = POOLS[seed % len(POOLS)]
        eng = state["paged"][key]
        eng._prefix.clear()          # example state derives from seed alone
        for _wave in range(2):       # wave 2 hits wave 1's accumulated index
            reqs = _workload(rng, state["cfg"].vocab_size, state["bases"])
            want = _serve(state["dense"], reqs)
            got = _serve(eng, reqs)
            assert got == want, (seed, key, _wave)
            _check_pool(eng.kv)

    @settings(deadline=None, max_examples=25)
    @given(st.integers(0, 10**6))
    def test_speculative_matches_dense_oracle(seed):
        """Same oracle check with the speculative engines: greedy
        self-speculative decode is token-identical to the dense engine
        for ANY draft (the verify pass overwrites draft K/V), under the
        same prefix-sharing + pool-pressure workloads."""
        state = _setup()
        rng = np.random.default_rng(seed)
        key = SPECS[seed % len(SPECS)]
        eng = state["spec"][key]
        eng._prefix.clear()
        for _wave in range(2):
            reqs = _workload(rng, state["cfg"].vocab_size, state["bases"])
            want = _serve(state["dense"], reqs)
            got = _serve(eng, reqs)
            assert got == want, (seed, key, _wave)
            _check_pool(eng.kv)


def test_speculative_fuzz_deterministic_seeds():
    """hypothesis-free slice of the speculative axis: fixed seeds
    through every (k, draft) engine, two waves each so the second wave
    speculates on top of the first wave's accumulated prefix index."""
    state = _setup()
    for i, key in enumerate(SPECS):
        eng = state["spec"][key]
        eng._prefix.clear()
        rng = np.random.default_rng(1000 + i)
        for _wave in range(2):
            reqs = _workload(rng, state["cfg"].vocab_size, state["bases"])
            want = _serve(state["dense"], reqs)
            got = _serve(eng, reqs)
            assert got == want, (key, _wave)
            _check_pool(eng.kv)


# ---------------------------------------------------------------------------
# architecture axis: MLA latent pages + Mamba state slabs under pressure
# ---------------------------------------------------------------------------

# arch -> (registry name, paged-engine kwargs variants). MLA runs the
# page-pressure pools the attention engines use; the Mamba-mix variants
# bracket the slab axis: a slab-starved pool (state_slabs=2 -> one
# usable slab, admission serializes on slab capacity) and a roomy one
# where only the attention layer's pages can run dry.
ZOO = {
    "mla": ("minicpm3-4b",
            [dict(page_size=8, n_pages=6), dict(page_size=8, n_pages=9)]),
    "mamba-mix": ("jamba-1.5-large-398b",
                  [dict(page_size=8, n_pages=9, state_slabs=2),
                   dict(page_size=8, n_pages=9)]),
}

_zoo: dict = {}


def _zoo_setup():
    if _zoo:
        return _zoo
    from repro.configs import smoke_config
    for arch, (name, variants) in ZOO.items():
        cfg = smoke_config(name).replace(dtype="float32", remat="none")
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(7)
        bases = [rng.integers(1, cfg.vocab_size, 16).astype(np.int32)
                 for _ in range(2)]
        _zoo[arch] = {
            "cfg": cfg,
            "bases": bases,
            "dense": ServeEngine(cfg, params, batch_size=BATCH,
                                 max_len=MAX_LEN, dtype="float32"),
            "paged": [ServeEngine(cfg, params, batch_size=BATCH,
                                  max_len=MAX_LEN, dtype="float32",
                                  cache_kind="paged", **kw)
                      for kw in variants],
        }
    return _zoo


def _zoo_wave(arch, eng, rng, state):
    if eng._prefix is not None:
        eng._prefix.clear()
    for _wave in range(2):
        reqs = _workload(rng, state["cfg"].vocab_size, state["bases"])
        want = _serve(state["dense"], reqs)
        got = _serve(eng, reqs)
        assert got == want, (arch, _wave)
        _check_pool(eng.kv)
        if eng.slab is not None:
            # every slab came home; conservation survived the workload
            assert eng.slab.live_slabs == 0
            assert eng.slab.free_slab_count == eng.slab.usable_slabs


if given is not None:
    @settings(deadline=None, max_examples=20)
    @given(st.integers(0, 10**6))
    def test_model_zoo_matches_dense_oracle(seed):
        """The fuzz workloads through the MLA and Mamba-mix engines:
        latent-page eviction and slab-admission serialization must stay
        invisible in the token stream."""
        zoo = _zoo_setup()
        rng = np.random.default_rng(seed)
        arch = list(ZOO)[seed % len(ZOO)]
        state = zoo[arch]
        eng = state["paged"][seed // len(ZOO) % len(state["paged"])]
        _zoo_wave(arch, eng, rng, state)


def test_model_zoo_fuzz_deterministic_seeds():
    """hypothesis-free slice of the architecture axis: fixed seeds
    through every (arch, pool-variant) engine, two waves each."""
    zoo = _zoo_setup()
    for arch, state in zoo.items():
        for v, eng in enumerate(state["paged"]):
            _zoo_wave(arch, eng, np.random.default_rng(2000 + v), state)
    # the slab-starved Mamba variant really exercised slab admission
    starved = zoo["mamba-mix"]["paged"][0]
    assert starved.slab is not None and starved.slab.usable_slabs == 1
    assert starved.slab.high_water == 1


def test_fuzz_engines_accumulated_sharing():
    """After the fuzz (or standalone on a fresh pool): the shared-prefix
    machinery actually engaged — serve two same-prefix workloads through
    one pooled engine and require index hits plus exact outputs."""
    state = _setup()
    rng = np.random.default_rng(123)
    base = state["bases"][0]
    reqs = [(np.concatenate([base, np.asarray([5 + i], np.int32)]), 3)
            for i in range(3)]
    want = _serve(state["dense"], reqs)
    eng = state["paged"][POOLS[3]]
    hits0 = eng.stats.get("prefix_hits", 0)
    got = _serve(eng, reqs)
    assert got == want
    assert eng.stats["prefix_hits"] > hits0
    _check_pool(eng.kv)
