"""Property-based page-allocator test: random interleavings of
alloc / share / cow-write / free / preempt / index-ref operations must
preserve refcount conservation —

    free + sum(live pages, each counted once) == n_pages - 1

— keep every refcount equal to (# rows referencing the page) + (index
refs), and never leave a just-written page with refcount > 1 (the COW
invariant). Runs against the bare allocator (no device pool), so the
nightly profile can afford thousands of interleavings.
"""
import random

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # not installed: property tests below are gated out
    given = settings = st = None

from repro.serve import OutOfPages, PagedKVCache
from repro.serve.state_slab import StateSlabPool

OPS = ("alloc", "ensure", "share", "cow", "release", "preempt",
       "index_ref", "index_unref")


def _check(kv, index_refs):
    # conservation: each live page counted once, however many refs
    assert kv.live_pages + kv.free_page_count == kv.usable_pages
    # null page: never allocated, never free
    assert kv.refcount(0) == 0 and 0 not in kv._free
    # free list is duplicate-free and disjoint from live pages
    assert len(kv._free) == len(set(kv._free))
    for pid in kv._free:
        assert kv.refcount(pid) == 0
    # per-page refcount == row references + index references
    rows = {}
    for s in range(kv.max_seqs):
        mine = kv.owned_pages(s)
        assert len(mine) == len(set(mine))
        for pid in mine:
            rows[pid] = rows.get(pid, 0) + 1
        assert (kv.block_tables[s, :len(mine)] == mine).all()
        assert (kv.block_tables[s, len(mine):] == 0).all()
    for pid in range(1, kv.n_pages):
        assert kv.refcount(pid) == rows.get(pid, 0) + index_refs.get(pid, 0)


if given is not None:
    @settings(deadline=None)
    @given(st.integers(0, 10**9))
    def test_allocator_refcount_conservation_under_random_interleavings(seed):
        rng = random.Random(seed)
        page = rng.choice([2, 4, 8])
        n_pages = rng.randint(4, 20)
        seqs = rng.randint(1, 4)
        kv = PagedKVCache(None, n_pages=n_pages, page_size=page,
                          max_seqs=seqs, create_pool=False)
        index_refs: dict[int, int] = {}   # simulated radix-index references

        for _ in range(rng.randint(20, 80)):
            op = rng.choice(OPS)
            active = kv.active_slots()
            if op == "alloc":
                kv.alloc_slot()
            elif op == "ensure" and active:
                slot = rng.choice(active)
                want = rng.randint(1, kv.usable_pages * page + page)
                try:
                    kv.ensure(slot, want)
                except OutOfPages:
                    pass                          # must allocate nothing
            elif op == "share" and active:
                # attach a live chain to a fresh (page-less) slot, the way
                # admission attaches a matched prefix
                fresh = [s for s in active if not kv.owned_pages(s)]
                donors = [s for s in active if kv.owned_pages(s)]
                pool = ([kv.owned_pages(rng.choice(donors))] if donors else []) \
                    + ([sorted(index_refs)] if index_refs else [])
                if fresh and pool:
                    chain = rng.choice(pool)
                    k = rng.randint(1, min(len(chain), kv.max_pages_per_seq))
                    kv.share(rng.choice(fresh), chain[:k])
            elif op == "cow" and active:
                owners = [s for s in active if kv.owned_pages(s)]
                if owners:
                    slot = rng.choice(owners)
                    cap = len(kv.owned_pages(slot)) * page
                    start = rng.randint(0, cap - 1)
                    end = rng.randint(start + 1, cap)
                    try:
                        copies = kv.cow_for_write(slot, start, end)
                    except OutOfPages:
                        copies = None             # must fork nothing
                    if copies is not None:
                        # COW postcondition: nothing in the written range is
                        # shared, and every fork came off a shared page
                        owned = kv.owned_pages(slot)
                        for i in range(start // page, (end - 1) // page + 1):
                            assert kv.refcount(owned[i]) == 1
                        for src, dst in copies:
                            assert kv.refcount(src) >= 1 and dst in owned
            elif op in ("release", "preempt") and active:
                kv.release(rng.choice(active))    # preemption == release
            elif op == "index_ref":
                live = [pid for pid in range(1, kv.n_pages)
                        if kv.refcount(pid) > 0 and pid not in index_refs]
                if live:
                    pid = rng.choice(live)
                    kv.ref(pid)
                    index_refs[pid] = 1
            elif op == "index_unref" and index_refs:
                pid = rng.choice(sorted(index_refs))
                kv.unref(pid)
                del index_refs[pid]
            _check(kv, index_refs)

        # drain everything: all pages must come home
        for slot in kv.active_slots():
            kv.release(slot)
        for pid in list(index_refs):
            kv.unref(pid)
        assert kv.free_page_count == kv.usable_pages
        assert kv.live_pages == 0


if given is not None:
    @settings(deadline=None)
    @given(st.integers(0, 10**9))
    def test_sharded_allocator_invariants_under_random_interleavings(seed):
        """The same random-op soup over a 2-shard pool: conservation holds
        globally AND within each shard, every slot's pages stay in its
        shard, reserve pages never circulate, and cross-shard share()
        attempts are rejected without mutating anything."""
        rng = random.Random(seed)
        page = rng.choice([2, 4])
        pages_per_shard = rng.randint(3, 8)
        kv = PagedKVCache(None, n_pages=2 * pages_per_shard, page_size=page,
                          max_seqs=4, n_shards=2, create_pool=False)

        def check():
            assert kv.live_pages + kv.free_page_count == kv.usable_pages
            for sh in range(kv.n_shards):
                assert kv.live_in_shard(sh) + kv.free_in_shard(sh) \
                    == kv.usable_in_shard(sh)
                reserve = kv.null_page_of_shard(sh)
                assert kv.refcount(reserve) == 0 and reserve not in kv._free
            for s in range(kv.max_seqs):
                for pid in kv.owned_pages(s):
                    assert kv.shard_of_page(pid) == kv.shard_of_slot(s)

        for _ in range(rng.randint(20, 60)):
            op = rng.choice(OPS)
            active = kv.active_slots()
            if op == "alloc":
                kv.alloc_slot(shard=rng.choice([None, 0, 1]))
            elif op == "ensure" and active:
                try:
                    kv.ensure(rng.choice(active),
                              rng.randint(1, kv.usable_in_shard(0) * page
                                          + page))
                except OutOfPages:
                    pass
            elif op == "share" and active:
                fresh = [s for s in active if not kv.owned_pages(s)]
                donors = [s for s in active if kv.owned_pages(s)]
                if fresh and donors:
                    f, d = rng.choice(fresh), rng.choice(donors)
                    chain = kv.owned_pages(d)
                    k = rng.randint(1, min(len(chain), kv.max_pages_per_seq))
                    if kv.shard_of_slot(f) == kv.shard_of_slot(d):
                        kv.share(f, chain[:k])
                    else:
                        # cross-shard attach is rejected before any mutation
                        before = kv._refcount.copy()
                        with pytest.raises(AssertionError):
                            kv.share(f, chain[:k])
                        assert (kv._refcount == before).all()
                        assert not kv.owned_pages(f)
            elif op == "cow" and active:
                owners = [s for s in active if kv.owned_pages(s)]
                if owners:
                    slot = rng.choice(owners)
                    cap = len(kv.owned_pages(slot)) * page
                    start = rng.randint(0, cap - 1)
                    try:
                        kv.cow_for_write(slot, start, rng.randint(start + 1,
                                                                  cap))
                    except OutOfPages:
                        pass
            elif op in ("release", "preempt") and active:
                kv.release(rng.choice(active))
            check()

        for slot in kv.active_slots():
            kv.release(slot)
        assert kv.free_page_count == kv.usable_pages
        for sh in range(kv.n_shards):
            assert kv.free_in_shard(sh) == kv.usable_in_shard(sh)


# ---------------------------------------------------------------------------
# recurrent state slab pool: same conservation law, no-growth allocator
# ---------------------------------------------------------------------------

def _slab_soup(seed):
    """Random alloc / release / compact interleavings against
    StateSlabPool must keep the page pool's conservation law —
    live + free == usable (= n_slabs - n_shards) — globally and per
    shard, never hand out a reserve slab, and keep every refcount 0/1
    (recurrent state has no COW analogue)."""
    rng = random.Random(seed)
    n_shards = rng.choice([1, 2])
    slabs_per_shard = rng.randint(2, 6)
    seqs_per_shard = rng.randint(1, 3)
    pool = StateSlabPool(None, n_slabs=n_shards * slabs_per_shard,
                         max_seqs=n_shards * seqs_per_shard,
                         n_shards=n_shards)

    def check():
        assert pool.live_slabs + pool.free_slab_count == pool.usable_slabs
        for sh in range(n_shards):
            assert pool.live_in_shard(sh) + pool.free_in_shard(sh) \
                == pool.usable_in_shard(sh)
        for slot in range(pool.max_seqs):
            sid = pool.slab_of(slot)
            if sid is not None:
                assert not pool.is_reserve_slab(sid)
                assert pool.shard_of_slab(sid) == pool.shard_of_slot(slot)
                assert pool.refcount(sid) == 1

    held: set[int] = set()
    for _ in range(rng.randint(20, 80)):
        op = rng.choice(("alloc", "alloc", "release", "compact"))
        if op == "alloc":
            idle = [s for s in range(pool.max_seqs) if s not in held]
            if idle:
                slot = rng.choice(idle)
                before = pool.free_slab_count
                try:
                    pool.alloc(slot)
                    held.add(slot)
                except OutOfPages:
                    # failed alloc is atomic and really means a dry shard
                    assert pool.free_in_shard(pool.shard_of_slot(slot)) == 0
                    assert pool.free_slab_count == before
        elif op == "release":
            slot = rng.randrange(pool.max_seqs)
            pool.release(slot)          # idempotent for slab-less slots
            held.discard(slot)
        else:
            mapping = pool.compact()
            # live slabs land on the densest prefix of their shard,
            # never on a reserve id
            for new in mapping.values():
                assert not pool.is_reserve_slab(new)
        check()

    for slot in range(pool.max_seqs):
        pool.release(slot)
    assert pool.free_slab_count == pool.usable_slabs
    assert pool.live_slabs == 0


if given is not None:
    @settings(deadline=None)
    @given(st.integers(0, 10**9))
    def test_slab_pool_conservation_under_random_interleavings(seed):
        _slab_soup(seed)


def test_slab_pool_conservation_deterministic_seeds():
    """hypothesis-free slice of the slab property (the fuzz above only
    runs where hypothesis is installed)."""
    for seed in range(16):
        _slab_soup(seed)


def test_slab_pool_rejects_degenerate_geometry():
    with pytest.raises(AssertionError):
        StateSlabPool(None, n_slabs=1, max_seqs=1)          # no reserve
    with pytest.raises(AssertionError):
        StateSlabPool(None, n_slabs=5, max_seqs=4, n_shards=2)  # 2 !| 5
    with pytest.raises(AssertionError):
        StateSlabPool(None, n_slabs=2, max_seqs=2, n_shards=2)  # no usable


if given is not None:
    @settings(deadline=None)
    @given(st.integers(0, 10**9))
    def test_failed_allocations_are_atomic(seed):
        """ensure()/cow_for_write() that raise OutOfPages must leave the
        allocator exactly as it was (no partial allocation)."""
        rng = random.Random(seed)
        page = rng.choice([2, 4])
        kv = PagedKVCache(None, n_pages=rng.randint(4, 8), page_size=page,
                          max_seqs=2, create_pool=False)
        s0 = kv.alloc_slot()
        kv.ensure(s0, rng.randint(1, (kv.usable_pages - 1) * page))
        before = (list(kv._free), kv.owned_pages(s0),
                  kv.block_tables.copy(), kv._refcount.copy())
        s1 = kv.alloc_slot()
        with pytest.raises(OutOfPages):
            kv.ensure(s1, kv.usable_pages * page + page)
        after = (list(kv._free), kv.owned_pages(s0),
                 kv.block_tables.copy(), kv._refcount.copy())
        assert before[0] == after[0] and before[1] == after[1]
        assert (before[2] == after[2]).all() and (before[3] == after[3]).all()
        assert not kv.owned_pages(s1)
