"""Self-speculative decoding scenario: one packed artifact serving as
its own draft (leading code planes + re-fit scales, quant/draft.py)
against vanilla single-token decode on the same quantized weights.

The scenario serves the same natural-text request batch twice — a
vanilla paged engine and a speculative engine (draft proposes K tokens
per tick in one fused dispatch, one batched target pass verifies K+1
positions, rejected tokens roll back via kv.truncate) — and gates three
different kinds of claim:

  - exactness: greedy speculative output is token-identical to vanilla
    decode for ANY draft (the verify pass overwrites draft K/V), so
    `greedy_matched` counts sequences and gates exactly at the request
    count, and `acceptance_rate` is deterministic (noise 0.0): greedy
    argmax chains contain no sampling.
  - cost: the draft shares the target's packed sign words byte-for-byte;
    `draft_extra_bytes` (unique buffers in the draft tree that are NOT
    aliases of target buffers) must equal `draft_scale_bytes` (the
    re-fit alpha/beta leaves) — the draft adds ZERO resident HBM beyond
    its scales.
  - speed: `decode_speedup` (speculative vs vanilla decode tokens/s) and
    `verify_batch_efficiency` — how many single-token decode dispatches
    one (K+1)-position verify pass replaces, measured on the live
    engine's jitted callables. Both are noisy on shared CPU runners
    (noise 0.5); the deterministic token counters above are the
    regression gate, the speed metrics are the trajectory.

The model is the steps-300 tiny LM (sharper greedy margins than the
40-step serve-smoke model: a w3 draft of a w4 gptqt target accepts
~0.8-0.9 of its proposals instead of coin-flipping), quantized in-
scenario with gptqt w4 packed.

  PYTHONPATH=src python -m benchmarks.serve_speculative    # standalone
  PYTHONPATH=src python -m benchmarks.run --only serve_speculative
"""
from __future__ import annotations

import time

import numpy as np

from repro.bench import Metric, counter, info, register_scenario, throughput

MAX_LEN = 160
PAGE = 32
MAX_NEW = 64
PROMPT_LEN = 16
BATCH = 4
SPECULATE_K = 4
DRAFT_BITS = 3
TARGET_BITS = 4

_MODEL = None


def _model():
    """(cfg, quantized target params). Trained once (disk-cached under
    artifacts/models/), gptqt-quantized to packed w4 per process."""
    global _MODEL
    if _MODEL is None:
        from benchmarks.common import calib_batches_for
        from repro.core import quantize_model
        from repro.data.pretrained import get_trained_lm
        from repro.quant import QuantSpec

        cfg, params = get_trained_lm("tiny-lm", steps=300)
        spec = QuantSpec.from_config(cfg.quant, method="gptqt",
                                     mode="packed", bits=TARGET_BITS)
        qp, _ = quantize_model(cfg, params, calib_batches_for("wiki"),
                               spec=spec)
        _MODEL = (cfg, qp)
    return _MODEL


def _requests(wave: int):
    """Natural wiki-corpus prompts (deterministic slices): greedy
    continuations of real text are where a lower-bit self-draft agrees
    with its target; random-token prompts flatten the logits and halve
    acceptance."""
    from repro.data.corpus import token_stream
    from repro.serve import Request

    toks = token_stream("wiki", 40_000)
    out = []
    for i in range(BATCH):
        off = 1000 * wave + 700 * i
        prompt = np.asarray(toks[off:off + PROMPT_LEN], np.int32)
        out.append(Request(prompt=prompt, max_new_tokens=MAX_NEW))
    return out


def _serve(eng):
    """Warmup wave (jit compiles), stat reset, then the measured wave."""
    eng.run(_requests(0))
    for k in ("tokens", "draft_tokens", "accepted_tokens", "ticks"):
        eng.stats[k] = 0
    eng.stats["decode_s"] = 0.0
    reqs = eng.run(_requests(1))
    return [list(r.out) for r in reqs], eng.stats_snapshot()


def _verify_efficiency(eng, k: int) -> float:
    """Dispatches saved per verify pass: (k+1) * t(single-token decode)
    / t((k+1)-position verify), timed on the engine's own jitted
    callables against its live cache. ~k+1 when the per-call cost is
    dominated by weight expansion (batching is free), ~1 when cost is
    linear in positions (batching buys nothing)."""
    import jax
    import jax.numpy as jnp

    B = eng.B
    cache = eng.cache
    cur = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.full((B,), PROMPT_LEN, jnp.int32)
    live = jnp.ones((B,), jnp.int32)
    nv = jnp.full((B,), k + 1, jnp.int32)
    vt = jnp.zeros((B, k + 1), jnp.int32)

    def t(fn, n=10):
        nonlocal cache
        out = fn(cache)
        cache = out[-1]
        jax.block_until_ready(out[0])      # compile + warm
        t0 = time.time()
        for _ in range(n):
            out = fn(cache)
            cache = out[-1]
            jax.block_until_ready(out[0])
        return (time.time() - t0) / n

    t_decode = t(lambda c: eng._decode(eng.params, c, cur, pos,
                                       eng._bt_dev, live, eng._null_row))
    t_verify = t(lambda c: eng._verify(eng.params, c, vt, pos,
                                       eng._bt_dev, nv, live,
                                       eng._null_row))
    eng.cache = cache
    return (k + 1) * t_decode / t_verify


@register_scenario("serve_speculative", quick=True, tags=("serving",))
def serve_speculative_scenario(ctx) -> dict:
    """Self-speculative decode vs vanilla on one packed w4 artifact."""
    from repro.quant import draft_extra_bytes, make_draft_params
    from repro.serve import ServeEngine

    cfg, qp = _model()
    metrics: dict = {}

    base = ServeEngine(cfg, qp, batch_size=BATCH, max_len=MAX_LEN,
                       dtype="float32", cache_kind="paged", page_size=PAGE)
    out_base, s_base = _serve(base)

    dp = make_draft_params(qp, DRAFT_BITS)
    eng = ServeEngine(cfg, qp, batch_size=BATCH, max_len=MAX_LEN,
                      dtype="float32", cache_kind="paged", page_size=PAGE,
                      speculate=SPECULATE_K, draft_bits=DRAFT_BITS,
                      draft_params=dp)
    out_spec, s = _serve(eng)

    # exactness: greedy speculative decode == vanilla, per sequence
    matched = sum(a == b for a, b in zip(out_base, out_spec))
    metrics["greedy_requests"] = counter(len(out_base), unit="seqs")
    metrics["greedy_matched"] = counter(matched, unit="seqs",
                                        higher_is_better=True)

    # acceptance is a deterministic token count under greedy decode
    metrics["acceptance_rate"] = Metric(round(s.acceptance_rate, 6),
                                        higher_is_better=True, noise=0.0)
    metrics["draft_tokens"] = counter(s.draft_tokens, unit="tok")
    metrics["accepted_tokens"] = counter(s.accepted_tokens, unit="tok",
                                         higher_is_better=True)

    # zero-HBM draft: every byte the draft tree adds over the target is
    # a re-fit scale leaf; the packed sign words are shared objects
    extra = draft_extra_bytes(qp, dp)
    scale_bytes = sum(
        l.alphas.size * l.alphas.dtype.itemsize
        + l.betas.size * l.betas.dtype.itemsize
        for l in _quant_leaves(dp))
    metrics["draft_extra_bytes"] = counter(extra, unit="B")
    metrics["draft_scale_bytes"] = counter(scale_bytes, unit="B")
    metrics["draft_nonscale_bytes"] = counter(extra - scale_bytes,
                                              unit="B")

    # speed trajectory (noisy on shared runners)
    metrics["tokens_per_s"] = throughput(s.decode_tok_s)
    metrics["tokens_per_s_base"] = throughput(s_base.decode_tok_s)
    metrics["decode_speedup"] = Metric(
        s.decode_tok_s / max(s_base.decode_tok_s, 1e-9), unit="x",
        higher_is_better=True, noise=0.5)
    metrics["verify_batch_efficiency"] = Metric(
        _verify_efficiency(eng, SPECULATE_K), unit="x",
        higher_is_better=True, noise=0.5)

    metrics["speculate_k"] = info(s.speculate_k)
    metrics["draft_bits"] = info(s.draft_bits, unit="bits")
    metrics["ticks"] = counter(eng.stats["ticks"], unit="ticks")
    return metrics


def _quant_leaves(tree):
    import jax
    is_qt = lambda l: hasattr(l, "codes")
    return [l for l in jax.tree_util.tree_leaves(
                tree, is_leaf=is_qt) if is_qt(l)]


def main() -> None:
    from repro.bench import BenchContext
    for name, m in serve_speculative_scenario(BenchContext(quick=True)).items():
        print(f"serve_speculative/{name},{m.value:.6g},{m.unit}")


if __name__ == "__main__":
    main()
