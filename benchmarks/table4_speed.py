"""Tab. IV analogue: per-token generation cost.

No TPU in this container, so this benchmark reports BOTH:
  (a) measured CPU wall-time per decode-shaped matmul for the three
      representations (dense bf16-equivalent, GPTQ-style int+dequant,
      GPTQT fused binary coding) at several model widths — the relative
      ordering is the paper's Tab. IV structure;
  (b) the structural projection that determines real decode latency on
      the bandwidth-bound target: weight bytes per token / HBM bw
      (v5e 819 GB/s), where GPTQT-3bit moves ~18.75% of bf16 bytes plus
      alpha/beta overhead. The projected speedup column is `derived`.

The `GROUP_SIZES` axis re-times the fused path with per-K-group scales
(G = K/group_size copies of alpha/beta): the measured CPU delta is the
dequant overhead of the extra scale expansion, and the projection adds
the G-times-larger scale bytes — the perf trajectory captures what
finer grouping costs on the serving path.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.bench import Metric, info, latency, register_scenario
from repro.bench.metrics.timers import measure
from repro.kernels import ref
from repro.quant.packing import pack_signs

HBM_BW = 819e9
WIDTHS = [(1024, 4096), (2048, 8192), (4096, 16384)]
QUICK_WIDTHS = [(1024, 4096)]
BITS = 3
GROUP_SIZES = (0, 128, 64)      # 0 = per-channel (G=1)


def collect(widths=None, iters=5):
    """Measure every (width, representation) cell. Returns rows keyed
    (K, N); each cell keeps both the historical mean-us fields and the
    raw per-call second samples (`*_samples_s`) the registered scenario
    turns into percentiles."""
    rows = {}
    rng = np.random.default_rng(0)
    for K, N in (widths or WIDTHS):
        x = jnp.asarray(rng.standard_normal((1, K)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32))
        # GPTQ-style: int codes + per-row scale, dequant then matmul
        q = jnp.asarray(rng.integers(0, 8, (K, N)).astype(np.int8))
        s = jnp.asarray(rng.random((1, N), dtype=np.float32))
        # GPTQT: packed bitplanes
        signs = jnp.asarray(rng.integers(0, 2, (BITS, K, N)).astype(bool))
        codes = pack_signs(signs)

        dense = jax.jit(lambda x, w: x @ w)
        gptq_path = jax.jit(
            lambda x, q, s: x @ (q.astype(jnp.float32) * s))
        gptqt_path = jax.jit(
            lambda x, c, a, b: ref.bcq_matmul_ref(x, c, a, b, K))

        s_d = measure(dense, x, w, warmup=1, iters=iters)
        s_g = measure(gptq_path, x, q, s, warmup=1, iters=iters)
        t_d = float(np.mean(s_d))
        t_g = float(np.mean(s_g))

        bytes_dense = K * N * 2                        # bf16 target bytes
        rows[(K, N)] = {"dense_us": t_d * 1e6, "gptq_us": t_g * 1e6,
                        "dense_samples_s": s_d, "gptq_samples_s": s_g,
                        "proj_us_dense_v5e": bytes_dense / HBM_BW * 1e6}

        # fused path across scale granularities: G = K/gs alpha/beta
        # copies — measures the dequant-expand overhead of finer groups
        for gs in GROUP_SIZES:
            G = K // gs if gs else 1
            tag = f"gptqt_fused_g{gs}" if gs else "gptqt_fused"
            alphas = jnp.asarray(rng.random((G, N, BITS), dtype=np.float32))
            betas = jnp.zeros((G, N), jnp.float32)
            s_t = measure(gptqt_path, x, codes, alphas, betas,
                          warmup=1, iters=iters)
            t_t = float(np.mean(s_t))
            bytes_packed = (BITS * (K // 32) * N * 4
                            + G * N * BITS * 4 + G * N * 4)
            proj_speedup = bytes_dense / bytes_packed  # bandwidth-bound
            rows[(K, N)][f"{tag}_us"] = t_t * 1e6
            rows[(K, N)][f"{tag}_samples_s"] = s_t
            rows[(K, N)][f"{tag}_proj_speedup_v5e"] = proj_speedup
            rows[(K, N)][f"{tag}_proj_us_v5e"] = bytes_packed / HBM_BW * 1e6
        rows[(K, N)]["gptqt_us"] = rows[(K, N)]["gptqt_fused_us"]
        rows[(K, N)]["proj_speedup_v5e"] = \
            rows[(K, N)]["gptqt_fused_proj_speedup_v5e"]
        rows[(K, N)]["proj_us_gptqt_v5e"] = \
            rows[(K, N)]["gptqt_fused_proj_us_v5e"]
    return rows


def main(widths=None):
    """Standalone CSV path (historical shape: name,us_per_call,derived)."""
    rows = collect(widths)
    for (K, N), r in rows.items():
        emit(f"table4/K{K}N{N}/dense", r["dense_us"], "1.00x")
        emit(f"table4/K{K}N{N}/gptq_dequant", r["gptq_us"],
             f"{r['dense_us'] / r['gptq_us']:.2f}x_cpu")
        for gs in GROUP_SIZES:
            tag = f"gptqt_fused_g{gs}" if gs else "gptqt_fused"
            emit(f"table4/K{K}N{N}/{tag}", r[f"{tag}_us"],
                 f"proj_{r[f'{tag}_proj_speedup_v5e']:.2f}x_v5e")
    return rows


@register_scenario("table4_speed", quick=True, tags=("quant", "kernels"))
def table4_speed_scenario(ctx) -> dict:
    """Tab. IV decode-matmul timings as gated metrics: per-call latency
    percentiles for each representation (CPU wall time, wide noise) and
    the exact bytes-ratio projections (analytic, noise 0)."""
    rows = collect(QUICK_WIDTHS if ctx.quick else WIDTHS,
                   iters=8 if ctx.quick else 16)
    metrics: dict = {}
    for (K, N), r in rows.items():
        pre = f"K{K}N{N}"
        metrics[f"{pre}/dense_s"] = latency(r["dense_samples_s"])
        metrics[f"{pre}/gptq_dequant_s"] = latency(r["gptq_samples_s"])
        for gs in GROUP_SIZES:
            tag = f"gptqt_fused_g{gs}" if gs else "gptqt_fused"
            metrics[f"{pre}/{tag}_s"] = latency(r[f"{tag}_samples_s"])
            # analytic bandwidth-bound projection: exact, gates at 0
            metrics[f"{pre}/{tag}_proj_speedup_v5e"] = Metric(
                r[f"{tag}_proj_speedup_v5e"], unit="x",
                higher_is_better=True, noise=0.0)
        metrics[f"{pre}/proj_us_dense_v5e"] = info(
            r["proj_us_dense_v5e"], unit="us")
    return metrics


if __name__ == "__main__":
    main()
