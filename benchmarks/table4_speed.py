"""Tab. IV analogue: per-token generation cost.

No TPU in this container, so this benchmark reports BOTH:
  (a) measured CPU wall-time per decode-shaped matmul for the three
      representations (dense bf16-equivalent, GPTQ-style int+dequant,
      GPTQT fused binary coding) at several model widths — the relative
      ordering is the paper's Tab. IV structure;
  (b) the structural projection that determines real decode latency on
      the bandwidth-bound target: weight bytes per token / HBM bw
      (v5e 819 GB/s), where GPTQT-3bit moves ~18.75% of bf16 bytes plus
      alpha/beta overhead. The projected speedup column is `derived`.

The `GROUP_SIZES` axis re-times the fused path with per-K-group scales
(G = K/group_size copies of alpha/beta): the measured CPU delta is the
dequant overhead of the extra scale expansion, and the projection adds
the G-times-larger scale bytes — the perf trajectory captures what
finer grouping costs on the serving path.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ref
from repro.quant.packing import pack_signs

HBM_BW = 819e9
WIDTHS = [(1024, 4096), (2048, 8192), (4096, 16384)]
BITS = 3
GROUP_SIZES = (0, 128, 64)      # 0 = per-channel (G=1)


def _bench(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / iters


def main():
    rows = {}
    rng = np.random.default_rng(0)
    for K, N in WIDTHS:
        x = jnp.asarray(rng.standard_normal((1, K)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32))
        # GPTQ-style: int codes + per-row scale, dequant then matmul
        q = jnp.asarray(rng.integers(0, 8, (K, N)).astype(np.int8))
        s = jnp.asarray(rng.random((1, N), dtype=np.float32))
        # GPTQT: packed bitplanes
        signs = jnp.asarray(rng.integers(0, 2, (BITS, K, N)).astype(bool))
        codes = pack_signs(signs)

        dense = jax.jit(lambda x, w: x @ w)
        gptq_path = jax.jit(
            lambda x, q, s: x @ (q.astype(jnp.float32) * s))
        gptqt_path = jax.jit(
            lambda x, c, a, b: ref.bcq_matmul_ref(x, c, a, b, K))

        t_d = _bench(dense, x, w)
        t_g = _bench(gptq_path, x, q, s)

        bytes_dense = K * N * 2                        # bf16 target bytes
        emit(f"table4/K{K}N{N}/dense", t_d * 1e6, "1.00x")
        emit(f"table4/K{K}N{N}/gptq_dequant", t_g * 1e6,
             f"{t_d / t_g:.2f}x_cpu")
        rows[(K, N)] = {"dense_us": t_d * 1e6, "gptq_us": t_g * 1e6,
                        "proj_us_dense_v5e": bytes_dense / HBM_BW * 1e6}

        # fused path across scale granularities: G = K/gs alpha/beta
        # copies — measures the dequant-expand overhead of finer groups
        for gs in GROUP_SIZES:
            G = K // gs if gs else 1
            tag = f"gptqt_fused_g{gs}" if gs else "gptqt_fused"
            alphas = jnp.asarray(rng.random((G, N, BITS), dtype=np.float32))
            betas = jnp.zeros((G, N), jnp.float32)
            t_t = _bench(gptqt_path, x, codes, alphas, betas)
            bytes_packed = (BITS * (K // 32) * N * 4
                            + G * N * BITS * 4 + G * N * 4)
            proj_speedup = bytes_dense / bytes_packed  # bandwidth-bound
            emit(f"table4/K{K}N{N}/{tag}", t_t * 1e6,
                 f"proj_{proj_speedup:.2f}x_v5e")
            rows[(K, N)][f"{tag}_us"] = t_t * 1e6
            rows[(K, N)][f"{tag}_proj_speedup_v5e"] = proj_speedup
            rows[(K, N)][f"{tag}_proj_us_v5e"] = bytes_packed / HBM_BW * 1e6
        rows[(K, N)]["gptqt_us"] = rows[(K, N)]["gptqt_fused_us"]
        rows[(K, N)]["proj_speedup_v5e"] = \
            rows[(K, N)]["gptqt_fused_proj_speedup_v5e"]
        rows[(K, N)]["proj_us_gptqt_v5e"] = \
            rows[(K, N)]["gptqt_fused_proj_us_v5e"]
    return rows


if __name__ == "__main__":
    main()
