"""Tab. III analogue: same method grid on the second corpus distribution
("ptb" grammar) — shows the orderings are not corpus-specific."""
from __future__ import annotations

from benchmarks.common import emit, eval_ppl, quantized_ppl
from repro.data.pretrained import get_trained_lm

METHODS = ["rtn", "bcq", "gptq", "gptqt"]


def main():
    rows = {}
    cfg, params = get_trained_lm("tiny-lm", corpus="ptb")
    base = eval_ppl(cfg, params, "ptb")
    emit("table3/tiny-lm/full16", 0.0, f"{base:.3f}")
    rows[("full", 16)] = base
    for m in METHODS:
        ppl, dt = quantized_ppl(cfg, params, "ptb", m, 3)
        emit(f"table3/tiny-lm/{m}-w3", dt * 1e6, f"{ppl:.3f}")
        rows[(m, 3)] = ppl
    return rows


if __name__ == "__main__":
    main()
