"""Tab. V analogue (quantization overfitting): GPTQ(linear) vs
GPTQ(min MSE) vs GPTQ+BCQ vs GPTQT, 3-bit, on a trained tiny LM.
The paper's point: grids fitted to minimize plain weight-MSE (min-MSE,
BCQ) do WORSE inside GPTQ than the plain linear grid, while GPTQT's
two-step grid does better."""
from __future__ import annotations

from benchmarks.common import emit, eval_ppl, quantized_ppl
from repro.data.pretrained import get_trained_lm

METHODS = ["gptq", "gptq_minmse", "gptq_bcq", "gptqt"]

# 2-bit: at tiny-LM scale 3-bit is saturated (all compensated methods sit
# at fp16 ppl); the overfitting effect the paper shows at 3-bit on OPT
# appears here in the 2-bit stress regime (documented deviation).
BITS = 2


def main():
    rows = {}
    cfg, params = get_trained_lm("tiny-lm", corpus="wiki")
    base = eval_ppl(cfg, params, "wiki")
    emit("table5/full16", 0.0, f"{base:.3f}")
    for m in METHODS:
        ppl, dt = quantized_ppl(cfg, params, "wiki", m, BITS)
        emit(f"table5/{m}-w{BITS}", dt * 1e6, f"{ppl:.3f}")
        rows[m] = ppl
    return rows


if __name__ == "__main__":
    main()
