"""Serving throughput scenarios: dense-slot vs paged engine on the tiny
config, plus the shared-system-prompt scenario for the radix prefix
cache. Registered with the perf-trajectory harness as
`serve_throughput` and `serve_shared_prefix` (both in the --quick CPU
subset; see docs/BENCHMARKS.md).

`serve_throughput` sweeps request concurrency and reports decode
throughput (tokens/s), TTFT/TPOT percentiles over per-request samples,
and the paged pool's page high-water — the number that explains WHY
paged sustains load: with c concurrent requests the dense engine pins
c * max_len KV slots while the paged pool's footprint tracks live
tokens.

`serve_shared_prefix` mirrors multi-user traffic behind one system
prompt: every request is `system prompt (SHARED_PREFIX tokens) + short
user turn`. With prefix sharing the engine prefills the system prompt
once and serves every later request from the radix index, so TTFT and
prefill token counts drop against the no-sharing paged baseline — the
prefill-token/hit/COW counters are deterministic and gate exactly.

  PYTHONPATH=src python -m benchmarks.serve_throughput     # standalone
  PYTHONPATH=src python -m benchmarks.run --quick          # via runner
"""
from __future__ import annotations

import time

import numpy as np

from repro.bench import (Metric, counter, info, latency, register_scenario,
                         throughput)

MAX_LEN = 128
PAGE = 32
MAX_NEW = 24
PROMPT_LEN = 16

SHARED_PREFIX = 64      # system-prompt tokens shared by every request
SHARED_TAIL = 8         # per-user suffix tokens
SHARED_MAX_NEW = 12

_MODEL = None


def _model():
    """Tiny trained-free LM shared by every serving scenario in this
    process (init only — scenario numbers measure serving, not
    training)."""
    global _MODEL
    if _MODEL is None:
        import jax

        from repro.configs import get_config
        from repro.models import init_params
        cfg = get_config("tiny-lm").replace(dtype="float32", n_layers=2,
                                            d_model=128, d_ff=256,
                                            remat="none")
        _MODEL = (cfg, init_params(cfg, jax.random.PRNGKey(0)))
    return _MODEL


def _requests(vocab, n):
    from repro.serve import Request
    return [Request(prompt=(np.arange(PROMPT_LEN) * 11 + 7 * i)
                    .astype(np.int32) % vocab, max_new_tokens=MAX_NEW)
            for i in range(n)]


def _serve(cfg, params, kind, concurrency):
    from repro.serve import ServeEngine
    kw = {}
    if kind == "paged":
        kw = dict(cache_kind="paged", page_size=PAGE)
    eng = ServeEngine(cfg, params, batch_size=concurrency, max_len=MAX_LEN,
                      dtype="float32", **kw)
    reqs = _requests(cfg.vocab_size, concurrency)
    t0 = time.time()
    eng.run(reqs)
    wall = time.time() - t0
    return wall, eng.stats_snapshot()


def _shared_prefix_requests(vocab, n, wave=0):
    from repro.serve import Request
    prefix = (np.arange(SHARED_PREFIX) * 13 + 3).astype(np.int32) % vocab
    out = []
    for i in range(n):
        uid = 100 * wave + i
        tail = (np.arange(SHARED_TAIL) * 7 + 11 * uid + 1).astype(np.int32) % vocab
        out.append(Request(prompt=np.concatenate([prefix, tail]),
                           max_new_tokens=SHARED_MAX_NEW))
    return out


def _serve_shared(cfg, params, sharing, concurrency):
    """Shared-system-prompt workload on the paged engine, with the radix
    prefix cache on or off. One long-lived engine serves a first wave of
    users (jit warmup + index population), then the measured wave — new
    user suffixes behind the same system prompt, the steady state the
    radix cache targets."""
    from repro.serve import ServeEngine

    eng = ServeEngine(cfg, params, batch_size=concurrency,
                      max_len=MAX_LEN, dtype="float32",
                      cache_kind="paged", page_size=PAGE,
                      prefix_sharing=sharing)
    eng.run(_shared_prefix_requests(cfg.vocab_size, concurrency, wave=0))
    for k in ("prefill_tokens", "tokens"):
        eng.stats[k] = 0
    warm = eng.stats_snapshot()
    eng.stats["decode_s"] = 0.0
    reqs = _shared_prefix_requests(cfg.vocab_size, concurrency, wave=1)
    t0 = time.time()
    eng.run(reqs)
    wall = time.time() - t0
    snap = eng.stats_snapshot()
    return {
        "wall_s": wall,
        "tok_s": snap.decode_tok_s,
        "ttft_s": snap.ttft_avg_s,
        "ttft_samples_s": snap.ttft_samples_s,
        "prefill_tokens": snap.prefill_tokens,
        "saved_tokens": snap.prefix_tokens_saved - warm.prefix_tokens_saved,
        "prefix_hits": snap.prefix_hits - warm.prefix_hits,
        "prefix_hit_rate": snap.prefix_hit_rate,
        "cow_forks": snap.cow_forks - warm.cow_forks,
        "pages_hw": snap.kv_high_water_pages,
        "us_per_tok": snap.us_per_token,
    }


@register_scenario("serve_throughput", quick=True, tags=("serving",))
def serve_throughput_scenario(ctx) -> dict:
    """Dense vs paged engine across a concurrency sweep."""
    cfg, params = _model()
    metrics: dict = {}
    sweep = (2, 4) if ctx.quick else (2, 4, 8)
    for c in sweep:
        for kind in ("dense", "paged"):
            wall, s = _serve(cfg, params, kind, c)
            tag = f"{kind}_c{c}"
            metrics[f"{tag}/tok_s"] = throughput(s.decode_tok_s)
            if s.ttft_samples_s:
                metrics[f"{tag}/ttft_s"] = latency(s.ttft_samples_s)
            if s.tpot_samples_s:
                metrics[f"{tag}/tpot_s"] = latency(s.tpot_samples_s)
            metrics[f"{tag}/pages_high_water"] = counter(
                s.kv_high_water_pages, unit="pages")
            metrics[f"{tag}/prefill_tokens"] = counter(
                s.prefill_tokens, unit="tok")
            metrics[f"{tag}/tokens"] = info(s.tokens, unit="tok")
    return metrics


@register_scenario("serve_shared_prefix", quick=True, tags=("serving",))
def serve_shared_prefix_scenario(ctx) -> dict:
    """Radix prefix sharing vs no-sharing under one system prompt."""
    cfg, params = _model()
    metrics: dict = {}
    sweep = (4,) if ctx.quick else (4, 8)
    for c in sweep:
        base = _serve_shared(cfg, params, False, c)
        shared = _serve_shared(cfg, params, True, c)
        tag = f"c{c}"
        speedup = base["ttft_s"] / max(shared["ttft_s"], 1e-9)
        metrics[f"{tag}/ttft_speedup"] = Metric(
            speedup, unit="x", higher_is_better=True, noise=0.5)
        if shared["ttft_samples_s"]:
            metrics[f"{tag}/ttft_s"] = latency(shared["ttft_samples_s"])
        metrics[f"{tag}/tok_s"] = throughput(shared["tok_s"])
        # deterministic counters: the sharing win in exact tokens/pages
        metrics[f"{tag}/prefill_tokens"] = counter(
            shared["prefill_tokens"], unit="tok")
        metrics[f"{tag}/prefill_tokens_base"] = counter(
            base["prefill_tokens"], unit="tok")
        metrics[f"{tag}/tokens_saved"] = counter(
            shared["saved_tokens"], unit="tok", higher_is_better=True)
        metrics[f"{tag}/prefix_hits"] = counter(
            shared["prefix_hits"], higher_is_better=True)
        metrics[f"{tag}/prefix_hit_rate"] = counter(
            shared["prefix_hit_rate"], higher_is_better=True)
        metrics[f"{tag}/cow_forks"] = counter(shared["cow_forks"])
        metrics[f"{tag}/pages_high_water"] = counter(
            shared["pages_hw"], unit="pages")
    return metrics


@register_scenario("serve_sharded", tags=("serving", "sharded"))
def serve_sharded_scenario(ctx) -> dict:
    """Paged engine over a (data=2, model=1) mesh — only meaningful when
    the host exposes >= 2 devices (XLA_FLAGS=
    --xla_force_host_platform_device_count=2 to exercise on CPU)."""
    import jax

    if len(jax.devices()) < 2:
        raise RuntimeError(
            "serve_sharded needs >= 2 devices (set XLA_FLAGS="
            "--xla_force_host_platform_device_count=2)")
    from repro.launch.mesh import make_serve_mesh
    from repro.serve import ServeEngine
    cfg, params = _model()
    mesh = make_serve_mesh(data=2, model=1)
    metrics: dict = {}
    for c in ((4,) if ctx.quick else (4, 8)):
        eng = ServeEngine(cfg, params, batch_size=c, max_len=MAX_LEN,
                          dtype="float32", cache_kind="paged",
                          page_size=PAGE, mesh=mesh)
        eng.run(_requests(cfg.vocab_size, c))
        s = eng.stats_snapshot()
        tag = f"d2_c{c}"
        metrics[f"{tag}/tok_s"] = throughput(s.decode_tok_s)
        metrics[f"{tag}/us_per_tok"] = Metric(s.us_per_token, unit="us")
        metrics[f"{tag}/pages_per_shard"] = info(eng.kv.pages_per_shard,
                                                 unit="pages")
        metrics[f"{tag}/compile_cache_entries"] = counter(
            s.compile_cache_entries, unit="entries")
    return metrics


def main() -> None:
    """Standalone CLI: run both quick scenarios and print their metrics
    as CSV-ish lines (the registered path writes BENCH_*.json)."""
    from repro.bench import BenchContext
    ctx = BenchContext(quick=False)
    for fn in (serve_throughput_scenario, serve_shared_prefix_scenario):
        for name, m in fn(ctx).items():
            print(f"{fn.__name__}/{name},{m.value:.6g},{m.unit}")


if __name__ == "__main__":
    main()
