"""Serving throughput: dense-slot vs paged engine on the tiny config.

Sweeps request concurrency and reports decode throughput (tokens/s),
time-to-first-token and time-per-output-token for both cache backends,
plus the paged pool's page high-water — the number that explains WHY
paged sustains load: with c concurrent requests the dense engine pins
c * max_len KV slots while the paged pool's footprint tracks live
tokens.

  PYTHONPATH=src python -m benchmarks.serve_throughput
"""
from __future__ import annotations

import time

import numpy as np


MAX_LEN = 128
PAGE = 32
MAX_NEW = 24
PROMPT_LEN = 16


def _requests(vocab, n):
    from repro.serve import Request
    return [Request(prompt=(np.arange(PROMPT_LEN) * 11 + 7 * i)
                    .astype(np.int32) % vocab, max_new_tokens=MAX_NEW)
            for i in range(n)]


def _serve(cfg, params, kind, concurrency):
    from repro.serve import ServeEngine
    kw = {}
    if kind == "paged":
        kw = dict(cache_kind="paged", page_size=PAGE)
    eng = ServeEngine(cfg, params, batch_size=concurrency, max_len=MAX_LEN,
                      dtype="float32", **kw)
    reqs = _requests(cfg.vocab_size, concurrency)
    t0 = time.time()
    eng.run(reqs)
    wall = time.time() - t0
    s = eng.stats
    tok_s = s["tokens"] / max(s["decode_s"], 1e-9)
    return {
        "wall_s": wall, "tok_s": tok_s,
        "ttft_s": s["ttft_avg_s"], "tpot_s": s["tpot_avg_s"],
        "pages_hw": s["kv_high_water_pages"],
        "pages_total": s["kv_usable_pages"],
        "us_per_tok": 1e6 * s["decode_s"] / max(s["tokens"], 1),
    }


def main() -> None:
    from benchmarks.common import emit
    from repro.configs import get_config
    from repro.models import init_params
    import jax

    cfg = get_config("tiny-lm").replace(dtype="float32", n_layers=2,
                                        d_model=128, d_ff=256, remat="none")
    params = init_params(cfg, jax.random.PRNGKey(0))

    for c in (2, 4, 8):
        for kind in ("dense", "paged"):
            r = _serve(cfg, params, kind, c)
            emit(f"serve_tput_{kind}_c{c}", r["us_per_tok"],
                 f"tok_s={r['tok_s']:.1f};ttft_s={r['ttft_s']:.3f};"
                 f"tpot_s={r['tpot_s']:.4f};pages={r['pages_hw']}/"
                 f"{r['pages_total']}")


if __name__ == "__main__":
    main()
