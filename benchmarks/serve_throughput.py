"""Serving throughput: dense-slot vs paged engine on the tiny config,
plus the shared-system-prompt scenario for the radix prefix cache.

Sweeps request concurrency and reports decode throughput (tokens/s),
time-to-first-token and time-per-output-token for both cache backends,
plus the paged pool's page high-water — the number that explains WHY
paged sustains load: with c concurrent requests the dense engine pins
c * max_len KV slots while the paged pool's footprint tracks live
tokens.

The shared-prefix scenario mirrors multi-user traffic behind one system
prompt: every request is `system prompt (SHARED_PREFIX tokens) + short
user turn`. With prefix sharing the engine prefills the system prompt
once and serves every later request from the radix index, so TTFT and
prefill token counts drop against the no-sharing paged baseline.

  PYTHONPATH=src python -m benchmarks.serve_throughput
"""
from __future__ import annotations

import time

import numpy as np


MAX_LEN = 128
PAGE = 32
MAX_NEW = 24
PROMPT_LEN = 16

SHARED_PREFIX = 64      # system-prompt tokens shared by every request
SHARED_TAIL = 8         # per-user suffix tokens
SHARED_MAX_NEW = 12


def _requests(vocab, n):
    from repro.serve import Request
    return [Request(prompt=(np.arange(PROMPT_LEN) * 11 + 7 * i)
                    .astype(np.int32) % vocab, max_new_tokens=MAX_NEW)
            for i in range(n)]


def _serve(cfg, params, kind, concurrency):
    from repro.serve import ServeEngine
    kw = {}
    if kind == "paged":
        kw = dict(cache_kind="paged", page_size=PAGE)
    eng = ServeEngine(cfg, params, batch_size=concurrency, max_len=MAX_LEN,
                      dtype="float32", **kw)
    reqs = _requests(cfg.vocab_size, concurrency)
    t0 = time.time()
    eng.run(reqs)
    wall = time.time() - t0
    s = eng.stats
    tok_s = s["tokens"] / max(s["decode_s"], 1e-9)
    return {
        "wall_s": wall, "tok_s": tok_s,
        "ttft_s": s["ttft_avg_s"], "tpot_s": s["tpot_avg_s"],
        "pages_hw": s["kv_high_water_pages"],
        "pages_total": s["kv_usable_pages"],
        "us_per_tok": 1e6 * s["decode_s"] / max(s["tokens"], 1),
    }


def _shared_prefix_requests(vocab, n, wave=0):
    from repro.serve import Request
    prefix = (np.arange(SHARED_PREFIX) * 13 + 3).astype(np.int32) % vocab
    out = []
    for i in range(n):
        uid = 100 * wave + i
        tail = (np.arange(SHARED_TAIL) * 7 + 11 * uid + 1).astype(np.int32) % vocab
        out.append(Request(prompt=np.concatenate([prefix, tail]),
                           max_new_tokens=SHARED_MAX_NEW))
    return out


def _serve_shared(cfg, params, sharing, concurrency):
    """Shared-system-prompt workload on the paged engine, with the radix
    prefix cache on or off. One long-lived engine serves a first wave of
    users (jit warmup + index population), then the measured wave — new
    user suffixes behind the same system prompt, the steady state the
    radix cache targets."""
    from repro.serve import ServeEngine

    eng = ServeEngine(cfg, params, batch_size=concurrency,
                      max_len=MAX_LEN, dtype="float32",
                      cache_kind="paged", page_size=PAGE,
                      prefix_sharing=sharing)
    eng.run(_shared_prefix_requests(cfg.vocab_size, concurrency, wave=0))
    for k in ("prefill_tokens", "tokens"):
        eng.stats[k] = 0
    base = {k: eng.stats.get(k, 0)
            for k in ("prefix_hits", "cow_forks", "prefix_tokens_saved")}
    eng.stats["decode_s"] = 0.0
    reqs = _shared_prefix_requests(cfg.vocab_size, concurrency, wave=1)
    t0 = time.time()
    eng.run(reqs)
    wall = time.time() - t0
    s = dict(eng.stats)
    for k, v in base.items():
        s[k] = s.get(k, 0) - v
    return {
        "wall_s": wall,
        "tok_s": s["tokens"] / max(s["decode_s"], 1e-9),
        "ttft_s": s["ttft_avg_s"],
        "prefill_tokens": s["prefill_tokens"],
        "saved_tokens": s["prefix_tokens_saved"],
        "prefix_hits": s["prefix_hits"],
        "cow_forks": s["cow_forks"],
        "pages_hw": s["kv_high_water_pages"],
        "us_per_tok": 1e6 * s["decode_s"] / max(s["tokens"], 1),
    }


def main() -> None:
    from benchmarks.common import emit
    from repro.configs import get_config
    from repro.models import init_params
    import jax

    cfg = get_config("tiny-lm").replace(dtype="float32", n_layers=2,
                                        d_model=128, d_ff=256, remat="none")
    params = init_params(cfg, jax.random.PRNGKey(0))

    for c in (2, 4, 8):
        for kind in ("dense", "paged"):
            r = _serve(cfg, params, kind, c)
            emit(f"serve_tput_{kind}_c{c}", r["us_per_tok"],
                 f"tok_s={r['tok_s']:.1f};ttft_s={r['ttft_s']:.3f};"
                 f"tpot_s={r['tpot_s']:.4f};pages={r['pages_hw']}/"
                 f"{r['pages_total']}")

    # shared-system-prompt scenario: prefix sharing vs no-sharing
    for c in (4, 8):
        base = _serve_shared(cfg, params, False, c)
        shared = _serve_shared(cfg, params, True, c)
        speedup = base["ttft_s"] / max(shared["ttft_s"], 1e-9)
        emit(f"serve_shared_prefix_c{c}", shared["us_per_tok"],
             f"ttft_s={shared['ttft_s']:.3f};ttft_base_s="
             f"{base['ttft_s']:.3f};ttft_speedup={speedup:.2f}x;"
             f"tok_s={shared['tok_s']:.1f};tok_s_base={base['tok_s']:.1f};"
             f"prefill_toks={shared['prefill_tokens']}/"
             f"{base['prefill_tokens']};hits={shared['prefix_hits']};"
             f"cow={shared['cow_forks']};pages_hw={shared['pages_hw']}/"
             f"{base['pages_hw']}")

    # sharded serving: paged engine over a (data, 1) mesh when the host
    # exposes >1 device (launch with XLA_FLAGS=
    # --xla_force_host_platform_device_count=2 to exercise on CPU) —
    # measures the mesh-partitioned pool + shared compile cache path
    n_dev = len(jax.devices())
    if n_dev >= 2:
        from repro.launch.mesh import make_serve_mesh
        from repro.serve import ServeEngine
        mesh = make_serve_mesh(data=2, model=1)
        for c in (4, 8):
            eng = ServeEngine(cfg, params, batch_size=c, max_len=MAX_LEN,
                              dtype="float32", cache_kind="paged",
                              page_size=PAGE, mesh=mesh)
            reqs = _requests(cfg.vocab_size, c)
            t0 = time.time()
            eng.run(reqs)
            s = eng.stats
            emit(f"serve_sharded_d2_c{c}",
                 1e6 * s["decode_s"] / max(s["tokens"], 1),
                 f"tok_s={s['tokens'] / max(s['decode_s'], 1e-9):.1f};"
                 f"wall_s={time.time() - t0:.2f};"
                 f"shards={eng.kv.n_shards};"
                 f"pages_per_shard={eng.kv.pages_per_shard}")
    else:
        print("# sharded scenario skipped: 1 device (set XLA_FLAGS="
              "--xla_force_host_platform_device_count=2)")


if __name__ == "__main__":
    main()
