"""Radix prefix-cache microbenchmark: host-only allocator + index ops,
no model and no device pool (`PagedKVCache(create_pool=False)`), so the
numbers isolate the bookkeeping the serving engine pays per admission —
lookup, share, COW fork, insert, cap-enforced eviction.

The workload is the traffic shape the radix cache exists for: a small
set of hot system prompts (reused across many requests, Zipf-ish pick)
each followed by a unique user tail. The cache cap is set well below
the working set so the cold-first eviction policy is exercised on every
wave: hot-prefix chains must survive (their nodes keep earning lookup
hits) while one-shot tails churn through the cap. All counters are
deterministic for a fixed seed — they gate exactly (noise 0) in
`tools/bench_diff.py` — and the hit rate dropping means the eviction
policy broke.

  PYTHONPATH=src python -m benchmarks.run --only prefix_cache_ops
"""
from __future__ import annotations

import numpy as np

from repro.bench import counter, latency, register_scenario
from repro.bench.metrics.timers import Stopwatch

PAGE = 16
N_PAGES = 129               # 1 null + 128 usable
MAX_SEQS = 4
CACHE_CAP = 48              # pages the index may retain (forces eviction)
N_HOT = 4                   # distinct system prompts
HOT_PAGES = 4               # 64-token system prompts
TAIL_TOKENS = 24            # unique per-request user suffix


def _hot_prefixes(rng):
    return [rng.integers(0, 32000, HOT_PAGES * PAGE).astype(np.int32)
            for _ in range(N_HOT)]


def run_workload(n_requests: int, seed: int = 0):
    """Serve `n_requests` synthetic admissions through a host-only
    allocator + radix index, mirroring the scheduler's admission /
    finish bookkeeping (lookup -> share -> COW -> insert -> release).
    Returns (prefix, kv, per-request second samples)."""
    from repro.serve import PagedKVCache, RadixPrefixCache

    kv = PagedKVCache(None, n_pages=N_PAGES, page_size=PAGE,
                      max_seqs=MAX_SEQS, create_pool=False)
    prefix = RadixPrefixCache(kv, max_cached_pages=CACHE_CAP)
    rng = np.random.default_rng(seed)
    hot = _hot_prefixes(rng)
    sw = Stopwatch()
    for i in range(n_requests):
        # skewed reuse: prompt 0 is ~2x hotter than the rest
        j = int(rng.integers(0, N_HOT + 1)) % N_HOT
        tail = rng.integers(0, 32000, TAIL_TOKENS).astype(np.int32)
        toks = np.concatenate([hot[j], tail])
        with sw.lap():
            matched, pages = prefix.lookup(toks,
                                           max_tokens=len(toks) - 1)
            slot = kv.alloc_slot()
            assert slot is not None   # serial requests, MAX_SEQS slots
            if matched:
                kv.share(slot, pages)
                prefix.hits += 1                 # scheduler contract:
                prefix.tokens_saved += matched   # one hit per admission
            kv.ensure(slot, len(toks))
            kv.cow_for_write(slot, matched, len(toks))
            prefix.insert(toks,
                          kv.owned_pages(slot)[:kv.pages_for(len(toks))])
            kv.release(slot)
    return prefix, kv, sw.samples


@register_scenario("prefix_cache_ops", quick=True, tags=("serving",))
def prefix_cache_ops_scenario(ctx) -> dict:
    """Admission-bookkeeping latency + exact cache-policy counters."""
    n = 200 if ctx.quick else 1000
    prefix, kv, samples = run_workload(n, seed=ctx.seed)
    return {
        "admission_s": latency(samples),
        "hit_rate": counter(prefix.hit_rate, higher_is_better=True),
        "hits": counter(prefix.hits, higher_is_better=True),
        "tokens_saved": counter(prefix.tokens_saved, unit="tok",
                                higher_is_better=True),
        "evictions": counter(prefix.evictions),
        "cached_pages": counter(prefix.cached_pages(), unit="pages"),
        "cow_forks": counter(kv.cow_forks),
        "pages_allocated": counter(kv.pages_allocated, unit="pages"),
        "high_water_pages": counter(kv.high_water, unit="pages"),
    }


def main() -> None:
    from repro.bench import BenchContext
    for name, m in prefix_cache_ops_scenario(BenchContext()).items():
        print(f"prefix_cache_ops/{name},{m.value:.6g},{m.unit}")


if __name__ == "__main__":
    main()
