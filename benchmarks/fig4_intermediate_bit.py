"""Fig. 4 analogue: impact of the step-1 intermediate bit-width (3..6)
with the second step finalizing at 3 bits. Paper: 4-5 intermediate bits
is the sweet spot (3 == plain re-encode loses; 6 explodes search time
for little gain)."""
from __future__ import annotations

from benchmarks.common import emit, eval_ppl, quantized_ppl
from repro.data.pretrained import get_trained_lm


def main():
    rows = {}
    cfg, params = get_trained_lm("tiny-lm", corpus="wiki")
    # final 2-bit (stress regime; see table5 note), intermediate 3..6
    for ib in (3, 4, 5, 6):
        ppl, dt = quantized_ppl(cfg, params, "wiki", "gptqt", 2,
                                intermediate_bits=ib, reexplore_range=1,
                                reexplore_points=17)
        emit(f"fig4/intermediate{ib}", dt * 1e6, f"{ppl:.3f}")
        rows[ib] = ppl
    return rows


if __name__ == "__main__":
    main()
