"""KV-cache capacity scenario: binary-coded page pool vs raw fp pages.

The quantized pool (serve/kv_cache.py `kv_bits`, layout quant/kv.py)
stores each page as packed sign bitplanes + per-(token, head, K-group)
alpha/beta scales instead of raw fp K/V. At the tier-1 toy geometry
(head_dim 64, 4 bits, one scale group per head vector) a page costs
52 B per (token, KV head) vector against 256 B fp32 — 4.9x more pages,
hence 4.9x more concurrent sequences, under the same HBM byte budget.

The scenario gates two things, both deterministic:
  - the capacity arithmetic: bytes/page from `PagedKVCache.bytes_per_page`
    (no device pool needed) and the max concurrent sequences a fixed
    byte budget admits for the raw vs the binary-coded pool — the
    headline `capacity_gain` counter must stay >= 4x;
  - greedy-output equality: the same request batch served by the fp32
    pool and the 4-bit pool must produce token-identical greedy
    generations on the lightly-trained tier-1 toy model (the model the
    CI serve smokes train, steps=40) — `greedy_matched` counts
    sequences, gated exactly at the request count.

Decode throughput is reported as a noisy info metric only; this
scenario's subject is bytes, not speed (on CPU the fused-dequant kernel
runs in interpret mode through the jnp oracle path).

  PYTHONPATH=src python -m benchmarks.kv_capacity          # standalone
  PYTHONPATH=src python -m benchmarks.run --only serve_kv_capacity
"""
from __future__ import annotations

import numpy as np

from repro.bench import counter, info, register_scenario, throughput

MAX_LEN = 160
PAGE = 16
MAX_NEW = 12
KV_BITS = 4
BATCH = 3
HBM_BUDGET = 64 << 20            # fixed byte budget for the capacity math

SEEDS = ["the ancient city", "a famous museum", "this railway",
         "the council", "another region", "the early dynasty"]

_MODEL = None


def _model():
    """The tier-1 toy model, trained the same 40 steps the CI serve
    smokes use: enough that greedy margins dominate the 4-bit coding
    error (the equality gate needs real token preferences, not the
    coin-flip argmax of random-init logits). Cached on disk after the
    first call (artifacts/models/)."""
    global _MODEL
    if _MODEL is None:
        from repro.data.pretrained import get_trained_lm
        _MODEL = get_trained_lm("tiny-lm", steps=40)
    return _MODEL


def _capacity(cfg, kv_bits: int):
    """(bytes_per_page, max concurrent sequences) a HBM_BUDGET-byte pool
    admits: usable pages after the null page, divided by the pages one
    max_len sequence needs. Host-side arithmetic only."""
    from repro.serve.kv_cache import PagedKVCache
    kv = PagedKVCache(cfg, n_pages=2, page_size=PAGE, max_seqs=1,
                      dtype="float32", create_pool=False, kv_bits=kv_bits)
    bpp = kv.bytes_per_page()
    pages_per_seq = -(-MAX_LEN // PAGE)
    usable = HBM_BUDGET // bpp - 1
    return bpp, max(usable // pages_per_seq, 0)


def _serve(cfg, params, kv_bits: int):
    """Serve the seed batch on a paged engine; returns (outputs, stats).
    Prefix sharing is off: the equality leg compares pure pool reads,
    not index-dependent admission order."""
    from repro.data import ByteTokenizer
    from repro.serve import Request, ServeEngine

    tok = ByteTokenizer()
    eng = ServeEngine(cfg, params, batch_size=BATCH, max_len=MAX_LEN,
                      dtype="float32", cache_kind="paged", page_size=PAGE,
                      kv_bits=kv_bits, prefix_sharing=False)
    reqs = [Request(prompt=tok.encode(s), max_new_tokens=MAX_NEW)
            for s in SEEDS]
    eng.run(reqs)
    return [list(r.out) for r in reqs], eng.stats_snapshot()


@register_scenario("serve_kv_capacity", quick=True, tags=("serving",))
def serve_kv_capacity_scenario(ctx) -> dict:
    """4-bit binary-coded KV pool: capacity win + greedy equality."""
    cfg, params = _model()
    metrics: dict = {}

    bpp_fp, seqs_fp = _capacity(cfg, 0)
    bpp_q, seqs_q = _capacity(cfg, KV_BITS)
    metrics["bytes_per_page_fp32"] = counter(bpp_fp, unit="B")
    metrics[f"bytes_per_page_w{KV_BITS}"] = counter(bpp_q, unit="B")
    metrics["seqs_at_budget_fp32"] = counter(seqs_fp, unit="seqs")
    metrics[f"seqs_at_budget_w{KV_BITS}"] = counter(
        seqs_q, unit="seqs", higher_is_better=True)
    metrics["capacity_gain"] = counter(
        round(seqs_q / max(seqs_fp, 1), 4), unit="x",
        higher_is_better=True)

    out_fp, s_fp = _serve(cfg, params, 0)
    out_q, s_q = _serve(cfg, params, KV_BITS)
    matched = sum(a == b for a, b in zip(out_fp, out_q))
    metrics["greedy_requests"] = counter(len(out_fp), unit="seqs")
    metrics["greedy_matched"] = counter(matched, unit="seqs",
                                        higher_is_better=True)
    metrics["kv_bits"] = info(s_q.kv_bits, unit="bits")
    metrics["kv_pool_bytes"] = counter(s_q.kv_pool_bytes, unit="B")
    metrics["kv_pool_bytes_fp32"] = counter(s_fp.kv_pool_bytes, unit="B")
    metrics["tok_s"] = throughput(s_q.decode_tok_s)
    return metrics


def main() -> None:
    from repro.bench import BenchContext
    for name, m in serve_kv_capacity_scenario(BenchContext(quick=True)).items():
        print(f"serve_kv_capacity/{name},{m.value:.6g},{m.unit}")


if __name__ == "__main__":
    main()
