"""Tab. VI analogue: effect of the re-exploration range (0 / 1 / 2 bits,
Eq. 7) on GPTQT perplexity, 3-bit final + 5-bit intermediate."""
from __future__ import annotations

from benchmarks.common import emit, eval_ppl, quantized_ppl
from repro.data.pretrained import get_trained_lm


def main():
    rows = {}
    cfg, params = get_trained_lm("tiny-lm", corpus="wiki")
    # 2-bit final / 4-bit intermediate: the stress regime where the scale
    # re-exploration has visible effect at tiny-LM scale
    for rng in (0, 1, 2):
        ppl, dt = quantized_ppl(cfg, params, "wiki", "gptqt", 2,
                                intermediate_bits=4, reexplore_range=rng,
                                reexplore_points=17)
        emit(f"table6/range{rng}", dt * 1e6, f"{ppl:.3f}")
        rows[rng] = ppl
    return rows


if __name__ == "__main__":
    main()
