"""Benchmark entry point over the perf-trajectory runner.

Importing this module registers every built-in scenario (the serving
and kernel suites register themselves in their own modules; the ppl
table/figure suites are wrapped below), then the CLI dispatches through
repro.bench.runner: one schema'd BENCH_<name>.json per scenario, a
summary table, and a nonzero exit when any scenario failed — per-
scenario pass/fail is recorded in the JSON documents, not buried in a
stderr traceback behind a clean CSV header.

  python -m benchmarks.run --quick            # fast CPU subset (CI gate)
  python -m benchmarks.run                    # everything registered
  python -m benchmarks.run --only table4_speed serve_throughput
  python -m benchmarks.run --list
  tools/bench_diff.py --run artifacts/bench   # gate vs committed baselines
"""
from __future__ import annotations

import argparse
import sys

# importing the suite modules populates the scenario registry
from benchmarks import (kv_capacity, prefix_cache_ops,  # noqa: F401
                        serve_model_zoo, serve_speculative,
                        serve_throughput, table4_speed)
from repro.bench import (Metric, available_scenarios, exit_code,
                         register_scenario, run_scenarios)

# Perplexity is deterministic for fixed seeds on one machine, but cross-
# machine float/runtime drift is real; a 5% band flags a genuine quality
# regression (method ordering flips are >> 5%) without tripping on BLAS.
PPL_NOISE = 0.05


def _register_ppl_suite(scn_name, main_fn, fmt_key):
    """Wrap a legacy table/figure `main() -> {key: ppl}` suite as a
    registered (non-quick: each trains/quantizes tiny LMs) scenario."""
    @register_scenario(scn_name, quick=False, tags=("ppl",))
    def _scenario(ctx, _main=main_fn, _fmt=fmt_key):
        return {f"{_fmt(k)}/ppl": Metric(float(v), unit="ppl",
                                         noise=PPL_NOISE)
                for k, v in _main().items()}
    return _scenario


def _register_ppl_suites():
    from benchmarks import (fig4_intermediate_bit, table1_ppl,
                            table3_ppl_shifted, table5_overfit,
                            table6_reexplore)
    _register_ppl_suite(
        "table1_ppl", table1_ppl.main,
        lambda k: f"{k[0]}/{k[1]}-w{k[2]}" + (f"-g{k[3]}" if k[3] else ""))
    _register_ppl_suite(
        "table3_ppl_shifted", table3_ppl_shifted.main,
        lambda k: f"{k[0]}-w{k[1]}")
    _register_ppl_suite("table5_overfit", table5_overfit.main,
                        lambda k: f"{k}-w2")
    _register_ppl_suite("table6_reexplore", table6_reexplore.main,
                        lambda k: f"range{k}")
    _register_ppl_suite("fig4_intermediate_bit", fig4_intermediate_bit.main,
                        lambda k: f"intermediate{k}")


_register_ppl_suites()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run",
        description="Run registered benchmark scenarios and emit "
                    "BENCH_<name>.json perf-trajectory documents.")
    ap.add_argument("--quick", action="store_true",
                    help="fast CPU subset only (the CI regression gate)")
    ap.add_argument("--only", nargs="+", metavar="SCENARIO",
                    help="run exactly these scenarios")
    ap.add_argument("--out", default="artifacts/bench",
                    help="output directory for BENCH_*.json "
                         "(default: %(default)s)")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload seed handed to every scenario")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    args = ap.parse_args(argv)

    if args.list:
        quick = set(available_scenarios(quick_only=True))
        for name in available_scenarios():
            mark = "quick" if name in quick else "full"
            print(f"{name:24s} [{mark}]")
        return 0

    results = run_scenarios(args.only, quick=args.quick,
                            out_dir=args.out, seed=args.seed)
    return exit_code(results)


if __name__ == "__main__":
    sys.exit(main())
