"""Benchmark aggregator — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (fig4_intermediate_bit, serve_throughput,
                            table1_ppl, table3_ppl_shifted, table4_speed,
                            table5_overfit, table6_reexplore)
    print("name,us_per_call,derived")
    suites = [
        ("table4_speed", table4_speed.main),
        ("table1_ppl", table1_ppl.main),
        ("table3_ppl_shifted", table3_ppl_shifted.main),
        ("table5_overfit", table5_overfit.main),
        ("table6_reexplore", table6_reexplore.main),
        ("fig4_intermediate_bit", fig4_intermediate_bit.main),
        ("serve_throughput", serve_throughput.main),
    ]
    failures = []
    for name, fn in suites:
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"FAILED suites: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
