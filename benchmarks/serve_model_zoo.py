"""Model-zoo serving scenario: the paged stack across architectures.

Registered as `serve_model_zoo` (quick; see docs/BENCHMARKS.md). For
each non-plain-attention architecture — MLA (paged latent cache),
Mamba-mix (state slabs beside attention pages), MoE (batched-expert
BCQ dispatch) — serve the same greedy workload through the dense and
paged engines and report:

  - tokens/s on the paged engine (timing metric, wide noise band);
  - `greedy_matched`: 1 iff paged output is token-identical to dense —
    the deterministic conformance gate (noise 0: any paging-visible
    numeric drift fails CI);
  - the capacity counters each architecture adds: latent bytes/page
    for MLA, slab high-water + bytes/slab for Mamba.

Plain attention is covered by `serve_throughput`; this scenario owns
the zoo.

  PYTHONPATH=src:. python -m benchmarks.serve_model_zoo    # standalone
  PYTHONPATH=src:. python -m benchmarks.run --quick        # via runner
"""
from __future__ import annotations

import numpy as np

from repro.bench import counter, info, register_scenario, throughput

MAX_LEN = 64
PAGE = 8
MAX_NEW = 8
N_REQS = 3

# arch tag -> registry name
ZOO = {
    "mla": "minicpm3-4b",
    "mamba_mix": "jamba-1.5-large-398b",
    "moe": "mixtral-8x7b",
}

_MODELS: dict = {}


def _model(arch):
    """Smoke-sized model per arch, shared across scenario calls in one
    process (init only — the numbers measure serving)."""
    if arch not in _MODELS:
        import jax

        from repro.configs import smoke_config
        from repro.models import init_params
        cfg = smoke_config(ZOO[arch]).replace(dtype="float32", remat="none")
        _MODELS[arch] = (cfg, init_params(cfg, jax.random.PRNGKey(0)))
    return _MODELS[arch]


def _requests(vocab, seed=0):
    from repro.serve import Request
    out = []
    for i in range(N_REQS):
        L = 4 + 3 * (i % 3)
        out.append(Request(prompt=(np.arange(L) * 7 + 11 * i + seed)
                           .astype(np.int32) % vocab,
                           max_new_tokens=MAX_NEW))
    return out


def _serve(cfg, params, paged):
    from repro.serve import ServeEngine
    kw = dict(cache_kind="paged", page_size=PAGE) if paged else {}
    eng = ServeEngine(cfg, params, batch_size=2, max_len=MAX_LEN,
                      dtype="float32", **kw)
    reqs = _requests(cfg.vocab_size)
    eng.run(reqs)
    return [r.out for r in reqs], eng.stats_snapshot()


@register_scenario("serve_model_zoo", quick=True, tags=("serving", "zoo"))
def serve_model_zoo_scenario(ctx) -> dict:
    """Dense-vs-paged conformance + throughput for MLA/Mamba/MoE."""
    metrics: dict = {}
    for arch in ZOO:
        cfg, params = _model(arch)
        want, _ = _serve(cfg, params, paged=False)
        got, s = _serve(cfg, params, paged=True)
        metrics[f"{arch}/greedy_matched"] = counter(
            int(got == want), higher_is_better=True)
        metrics[f"{arch}/tok_s"] = throughput(s.decode_tok_s)
        metrics[f"{arch}/tokens"] = info(s.tokens, unit="tok")
        metrics[f"{arch}/pages_high_water"] = counter(
            s.kv_high_water_pages, unit="pages")
        if arch == "mla":
            # compressed latent pages: (kv_lora_rank + rope dim) per
            # token, not 2 * Hkv * hd — the capacity win paging buys
            metrics[f"{arch}/latent_bytes_per_page"] = info(
                s.kv_bytes_per_page, unit="B")
        if arch == "mamba_mix":
            metrics[f"{arch}/slab_high_water"] = counter(
                s.slab_high_water, unit="slabs")
            metrics[f"{arch}/slabs_allocated"] = counter(
                s.slabs_allocated, unit="slabs")
            metrics[f"{arch}/slab_bytes_per_slab"] = info(
                s.slab_bytes_per_slab, unit="B")
    return metrics


def main() -> None:
    """Standalone CLI: print the scenario's metrics as CSV-ish lines."""
    from repro.bench import BenchContext
    for name, m in serve_model_zoo_scenario(BenchContext(quick=True)).items():
        print(f"serve_model_zoo/{name},{m.value:.6g},{m.unit}")


if __name__ == "__main__":
    main()
