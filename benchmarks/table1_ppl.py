"""Tab. I/II analogue: perplexity of full/RTN/BCQ/GPTQ/GPTQT at 3-bit and
2-bit on trained tiny LMs (wiki-analogue corpus). The paper's claim under
test: GPTQT <= GPTQ < BCQ << RTN at 3-bit; at 2-bit RTN/BCQ collapse
while GPTQT stays reasonable."""
from __future__ import annotations

from benchmarks.common import emit, eval_ppl, quantized_ppl
from repro.data.pretrained import get_trained_lm

MODELS = ["tiny-lm", "tiny-lm-wide"]
METHODS = ["rtn", "bcq", "gptq", "gptqt"]


def main(models=None):
    rows = {}
    for name in models or MODELS:
        cfg, params = get_trained_lm(name, corpus="wiki")
        base = eval_ppl(cfg, params, "wiki")
        emit(f"table1/{name}/full16", 0.0, f"{base:.3f}")
        rows[(name, "full", 16)] = base
        for bits in (3, 2):
            for m in METHODS:
                ppl, dt = quantized_ppl(cfg, params, "wiki", m, bits)
                emit(f"table1/{name}/{m}-w{bits}", dt * 1e6, f"{ppl:.3f}")
                rows[(name, m, bits)] = ppl
    return rows


if __name__ == "__main__":
    main()
