"""Tab. I/II analogue: perplexity of full/RTN/BCQ/GPTQ/GPTQT at 3-bit and
2-bit on trained tiny LMs (wiki-analogue corpus). The paper's claim under
test: GPTQT <= GPTQ < BCQ << RTN at 3-bit; at 2-bit RTN/BCQ collapse
while GPTQT stays reasonable.

`--group-size` adds a FineQuant-style axis: the same method x bits grid
re-run with per-K-group scales (group_size entries per scale group),
reported as e.g. `gptqt-w2-g64`. Finer groups should close most of the
2-bit gap at a small memory cost (see docs/QUANT.md for the formula).
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit, eval_ppl, quantized_ppl
from repro.data.pretrained import get_trained_lm

MODELS = ["tiny-lm", "tiny-lm-wide"]
METHODS = ["rtn", "bcq", "gptq", "gptqt"]


def main(models=None, group_sizes=(0,)):
    rows = {}
    for name in models or MODELS:
        cfg, params = get_trained_lm(name, corpus="wiki")
        base = eval_ppl(cfg, params, "wiki")
        emit(f"table1/{name}/full16", 0.0, f"{base:.3f}")
        rows[(name, "full", 16, 0)] = base
        for gs in group_sizes:
            tag = f"-g{gs}" if gs else ""
            for bits in (3, 2):
                for m in METHODS:
                    ppl, dt = quantized_ppl(cfg, params, "wiki", m, bits,
                                            group_size=gs)
                    emit(f"table1/{name}/{m}-w{bits}{tag}", dt * 1e6,
                         f"{ppl:.3f}")
                    rows[(name, m, bits, gs)] = ppl
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", nargs="*", default=None)
    ap.add_argument("--group-size", type=int, nargs="*", default=[0],
                    help="group_size values to sweep (0 = per-channel)")
    args = ap.parse_args()
    main(models=args.models, group_sizes=tuple(args.group_size))
