"""Render the roofline table from artifacts/dryrun/*.json (EXPERIMENTS.md
§Roofline source). One row per (arch x shape x mesh [x quant])."""
from __future__ import annotations

import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_cells(mesh=None, pattern="*.json"):
    cells = []
    for p in sorted(ARTIFACTS.glob(pattern)):
        d = json.loads(p.read_text())
        if mesh and d.get("mesh") != mesh:
            continue
        d["_file"] = p.name
        cells.append(d)
    return cells


HBM_BW = 819e9


def analytic_stream_s(d):
    """Lower-bound memory term: weight bytes (+cache read/write for
    inference cells, +optimizer state for train) per device / HBM bw.
    Unlike XLA 'bytes accessed' (which counts fusion-internal buffers and
    dtype converts — an upper bound), this is the irreducible stream."""
    n = d.get("n_devices", 256)
    w = d.get("params_bytes_packed") or d.get("params_bytes_bf16", 0)
    b = w
    if d["shape"].startswith(("decode", "long")):
        b += 2 * d.get("cache_bytes", 0)
    elif d["shape"].startswith("prefill"):
        b += d.get("cache_bytes", 0)
    else:
        b += d.get("state_bytes", 0)
    return b / n / HBM_BW


def fmt_row(d):
    r = d.get("roofline", {})
    q = f"w{d['quant_bits']}" if d.get("quant_bits") else "bf16"
    if not d.get("ok"):
        return (f"| {d['arch']} | {d['shape']} | {d['mesh']} | {q} "
                f"| FAILED | | | | | | |")
    return ("| {arch} | {shape} | {mesh} | {q} | {tc:.3e} | {tm:.3e} "
            "| {ts:.3e} | {tx:.3e} | {bound} | {mfu:.3f} | {useful:.2f} |"
            ).format(
        arch=d["arch"], shape=d["shape"], mesh=d["mesh"], q=q,
        tc=r["t_compute_s"], tm=r["t_memory_s"], ts=analytic_stream_s(d),
        tx=r["t_collective_s"], bound=r["bound"], mfu=r["roofline_mfu"],
        useful=r.get("useful_flops_ratio", 0.0))


HEADER = ("| arch | shape | mesh | repr | t_compute (s) | t_mem HLO (s) "
          "| t_mem stream (s) | t_collective (s) | bound "
          "| roofline MFU ceil | useful/HLO flops |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def main(pattern=None):
    import sys
    pattern = pattern or (sys.argv[1] if len(sys.argv) > 1 else "*.json")
    cells = load_cells(pattern=pattern)
    print(HEADER)
    for d in cells:
        print(fmt_row(d))
    ok = sum(1 for d in cells if d.get("ok"))
    print(f"\n{ok}/{len(cells)} cells OK")
    return cells


if __name__ == "__main__":
    main()
