"""Shared benchmark utilities: trained tiny LMs, quantization sweep
drivers, CSV emission (name,us_per_call,derived)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import quantize_model
from repro.data.corpus import calibration_slices, eval_batches
from repro.data.evaluate import perplexity
from repro.data.pretrained import corpus_tokens, get_trained_lm
from repro.quant import QuantSpec

# scaled-down analog of the paper's 128 slices x 2048 tokens
N_CALIB, CALIB_LEN = 24, 192
EVAL_SEQ, EVAL_BATCH = 192, 8
MAX_EVAL_BATCHES = 6


def calib_batches_for(corpus: str):
    toks = corpus_tokens(corpus, split="train")
    sl = calibration_slices(toks, N_CALIB, CALIB_LEN, seed=1)
    # group slices into batches of 4 for the capture pass
    return [sl[i:i + 4] for i in range(0, len(sl), 4)]


def eval_ppl(cfg, params, corpus: str) -> float:
    toks = corpus_tokens(corpus, split="eval")
    return perplexity(cfg, params, eval_batches(toks, EVAL_BATCH, EVAL_SEQ),
                      max_batches=MAX_EVAL_BATCHES)


def quantized_ppl(cfg, params, corpus, method, bits, **kw) -> tuple:
    """Returns (ppl, seconds). kw feeds the QuantSpec (the method x bits
    sweep axis: intermediate_bits=, reexplore_range=, overrides=, ...)."""
    spec = QuantSpec.from_config(cfg.quant, method=method, bits=bits, **kw)
    t0 = time.time()
    qp, _ = quantize_model(cfg, params, calib_batches_for(corpus),
                           spec=spec)
    dt = time.time() - t0
    return eval_ppl(cfg, qp, corpus), dt


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
