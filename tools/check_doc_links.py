#!/usr/bin/env python
"""Docs link checker: every relative markdown link and every
slash-containing backticked file reference in docs/*.md (and the root
*.md) must resolve to a real file, so the docs can't silently rot as
the tree is refactored.

Resolution: a markdown link resolves relative to its document; a
backticked path like `serve/engine.py` resolves against the repo root,
src/, src/repro/ and docs/ (first hit wins). References without a "/"
(e.g. `manifest.json`, artifact members) are not checked.

  python tools/check_doc_links.py          # exits 1 on dangling refs
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

# [text](relative/target.md#anchor) — external schemes are skipped
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `path/with/slash.ext` possibly followed by ":symbol" or " --flags"
CODE_REF = re.compile(r"`([A-Za-z0-9_.\-]+(?:/[A-Za-z0-9_.\-]+)+"
                      r"\.(?:py|md|yml|yaml|json|txt))[:\s`]")
SEARCH_ROOTS = ("", "src", "src/repro", "docs")


def _doc_files():
    return sorted(list((ROOT / "docs").glob("*.md"))
                  + list(ROOT.glob("*.md")))


def _resolve_code_ref(ref: str) -> bool:
    return any((ROOT / base / ref).exists() for base in SEARCH_ROOTS)


def check() -> list[str]:
    problems = []
    for doc in _doc_files():
        text = doc.read_text()
        rel = doc.relative_to(ROOT)
        for m in MD_LINK.finditer(text):
            target = m.group(1)
            if re.match(r"^[a-z][a-z0-9+.-]*:", target) \
                    or target.startswith("#"):
                continue                      # external / in-page
            path = (doc.parent / target.split("#", 1)[0]).resolve()
            if not path.exists():
                problems.append(f"{rel}: dangling link ({target})")
        for m in CODE_REF.finditer(text):
            ref = m.group(1)
            if not _resolve_code_ref(ref):
                problems.append(f"{rel}: stale file reference `{ref}`")
    return problems


def main() -> int:
    problems = check()
    for p in problems:
        print(p)
    if problems:
        print(f"{len(problems)} dangling doc reference(s)")
        return 1
    print(f"doc links OK ({len(_doc_files())} files checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
