#!/usr/bin/env python
"""Thin compatibility shim: the docs link check is now repro-lint rule
R007 (src/repro/analysis/rules/docs.py, catalog in docs/ANALYSIS.md).
This entry point just runs that one rule so old habits and scripts keep
working; CI runs the full linter via tools/repro_lint.py.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

from repro_lint import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(["--rule", "R007"]))
