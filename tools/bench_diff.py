#!/usr/bin/env python
"""CI regression gate: diff a benchmark run against committed baselines.

Compares every BENCH_<name>.json under --baseline (the committed
trajectory, artifacts/bench_baselines/) against the same scenario's
document under --run, metric by metric, using each baseline metric's
own noise band scaled by --noise-scale (CI uses a wide scale on shared
CPU runners; deterministic counters carry a 0 band and stay exact at
any scale). Exits nonzero on any regression past its band, on a
scenario/metric that disappeared from the run, or on schema-invalid
documents. The verdict logic lives in src/repro/bench/diff.py and is
pure, so the same inputs always produce the same exit code.

  python tools/bench_diff.py --run artifacts/bench \\
      --baseline artifacts/bench_baselines [--noise-scale 4]

  # adopt the current run as the new committed baseline (re-baselining
  # after an intentional perf change; commit the result)
  python tools/bench_diff.py --run artifacts/bench --update
"""
from __future__ import annotations

import argparse
import shutil
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.bench import diff as bdiff  # noqa: E402
from repro.bench import schema  # noqa: E402

DEFAULT_BASELINE = ROOT / "artifacts" / "bench_baselines"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff BENCH_*.json runs against committed baselines")
    ap.add_argument("--run", required=True, metavar="DIR",
                    help="directory holding the fresh BENCH_*.json run")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    metavar="DIR", help="committed baseline directory "
                    "(default artifacts/bench_baselines)")
    ap.add_argument("--noise-scale", type=float, default=1.0,
                    help="multiply every baseline noise band (use > 1 on "
                    "noisy shared-CPU runners; 0-band counters stay exact)")
    ap.add_argument("--update", action="store_true",
                    help="copy the run's documents over the baselines "
                    "instead of gating (intentional re-baseline)")
    args = ap.parse_args(argv)

    try:
        runs = schema.load_dir(args.run)
    except schema.BenchSchemaError as e:
        print(f"invalid run document: {e}", file=sys.stderr)
        return 1
    if not runs:
        print(f"no {schema.PREFIX}*.json under {args.run}", file=sys.stderr)
        return 1

    if args.update:
        dest = Path(args.baseline)
        dest.mkdir(parents=True, exist_ok=True)
        for name in sorted(runs):
            src = schema.bench_path(args.run, name)
            shutil.copy2(src, dest / src.name)
            print(f"baselined {name} -> {dest / src.name}")
        print(f"{len(runs)} baseline(s) updated; review + commit "
              f"{dest} to adopt them")
        return 0

    try:
        baselines = schema.load_dir(args.baseline)
    except schema.BenchSchemaError as e:
        print(f"invalid baseline document: {e}", file=sys.stderr)
        return 1
    if not baselines:
        print(f"no baselines under {args.baseline}; run with --update "
              f"to create them", file=sys.stderr)
        return 1

    for w in bdiff.fingerprint_mismatches(baselines, runs):
        print(f"WARNING: {w}")

    verdicts = bdiff.diff_all(baselines, runs,
                              noise_scale=args.noise_scale)
    print(bdiff.format_report(verdicts))
    failed = [v for v in verdicts if v.failed]
    gated = sum(1 for v in verdicts if v.status in ("ok", "regressed"))
    if failed:
        print(f"\n{len(failed)} regression(s) past the noise band "
              f"(noise_scale={args.noise_scale:g}):")
        for v in failed:
            where = f"{v.scenario}/{v.metric}" if v.metric else v.scenario
            if v.status == "missing":
                print(f"  {where}: missing from the run")
            else:
                print(f"  {where}: {v.base_value:.6g} -> "
                      f"{v.run_value:.6g} (worse by {v.worse_by:+.1%}, "
                      f"band {v.band:.1%})")
        return 1
    print(f"\nno regressions ({gated} gated metric(s) across "
          f"{len(baselines)} scenario(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
