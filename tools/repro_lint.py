#!/usr/bin/env python
"""repro-lint: run the repo's invariant rules (src/repro/analysis/)
over the tree and gate on the committed suppression baseline.

  PYTHONPATH=src python tools/repro_lint.py                 # full run
  PYTHONPATH=src python tools/repro_lint.py --rule R004     # one rule
  PYTHONPATH=src python tools/repro_lint.py --list-rules
  PYTHONPATH=src python tools/repro_lint.py --update-baseline

Exit status: 0 when every finding is baselined and no baseline entry is
stale; 1 otherwise. `--update-baseline` rewrites the baseline to
exactly the current findings (deterministic order, justifications of
surviving entries carried forward) and exits 0 — commit the diff.

Rule catalog and the suppression workflow: docs/ANALYSIS.md.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis.baseline import (load_baseline, partition,  # noqa: E402
                                     render_baseline)
from repro.analysis.context import AnalysisContext  # noqa: E402
from repro.analysis.registry import (available_rules, get_rule,  # noqa: E402
                                     run_rules)

DEFAULT_BASELINE = ROOT / "tools" / "repro_lint_baseline.txt"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro_lint",
        description="repo invariant lint (docs/ANALYSIS.md)")
    ap.add_argument("--root", default=str(ROOT),
                    help="tree to analyze (default: the repo)")
    ap.add_argument("--rule", action="append", dest="rules", metavar="RNNN",
                    help="run only this rule (repeatable); baseline "
                    "gating still applies to the selected rules")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="suppression baseline file")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline (report everything, gate "
                    "on any finding)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to the current findings "
                    "and exit 0")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in available_rules():
            rule = get_rule(rid)
            print(f"{rid}  {rule.title}")
            if rule.rationale:
                print(f"      {rule.rationale}")
        return 0

    ctx = AnalysisContext(args.root)
    findings = run_rules(ctx, args.rules)
    findings = ctx.parse_failures() + findings

    if args.update_baseline:
        old = load_baseline(args.baseline)
        Path(args.baseline).write_text(render_baseline(findings, old))
        print(f"wrote {args.baseline} ({len(findings)} entr"
              f"{'y' if len(findings) == 1 else 'ies'})")
        return 0

    baseline = {} if args.no_baseline else load_baseline(args.baseline)
    new, suppressed, stale = partition(findings, baseline)

    for f in new:
        print(f.render())
    if stale and not args.rules:
        # a partial run can't tell a stale entry from an unrun rule
        for key in stale:
            print(f"stale baseline entry (no longer fires): "
                  f"{key.replace(chr(9), ' | ')}")
    else:
        stale = []

    n_rules = len(args.rules) if args.rules else len(available_rules())
    print(f"repro-lint: {n_rules} rule(s), {len(new)} finding(s), "
          f"{len(suppressed)} suppressed, {len(stale)} stale")
    if new or stale:
        print("fix the findings, or run --update-baseline and commit "
              "the diff with a justification per entry")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
