"""Perf-trajectory benchmark subsystem: scenario registry, warmup-aware
metrics with percentile statistics, versioned BENCH_<name>.json
documents and the baseline-diff regression gate.

Layers (see docs/BENCHMARKS.md):
  registry.py  — `@register_scenario` / `get_scenario` (benchmarks/
                 modules are the built-ins, registered at import)
  metrics/     — timers (warmup + block_until_ready), percentile stats
                 and the `Metric` record (unit, direction, noise band)
  schema.py    — the BENCH document format: machine fingerprint, git
                 SHA, quant config, per-metric noise bands; versioned,
                 future versions refused
  runner.py    — the executor: runs scenarios, captures pass/fail,
                 writes documents, prints the summary table
  diff.py      — deterministic baseline-vs-run verdicts; the CLI lives
                 in tools/bench_diff.py
"""
from __future__ import annotations

from repro.bench.metrics import (Metric, Stopwatch, counter, info, latency,
                                 measure, percentile, summarize, throughput)
from repro.bench.registry import (Scenario, available_scenarios,
                                  get_scenario, register_scenario)
from repro.bench.runner import (BenchContext, ScenarioResult, exit_code,
                                run_one, run_scenarios)
from repro.bench.schema import (SCHEMA_VERSION, BenchSchemaError, bench_path,
                                load_dir, load_doc, make_doc, validate,
                                write_doc)

__all__ = [
    "Metric", "Stopwatch", "counter", "info", "latency", "measure",
    "percentile", "summarize", "throughput",
    "Scenario", "register_scenario", "get_scenario", "available_scenarios",
    "BenchContext", "ScenarioResult", "run_one", "run_scenarios",
    "exit_code",
    "SCHEMA_VERSION", "BenchSchemaError", "bench_path", "load_dir",
    "load_doc", "make_doc", "validate", "write_doc",
]
