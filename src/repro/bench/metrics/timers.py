"""Warmup-aware wall-clock timing for benchmark scenarios.

jax makes naive timing lie twice: the first call pays tracing + XLA
compilation, and every call returns before the device work finishes.
`measure` runs `warmup` untimed calls first (compilation lands there),
then `iters` timed calls, blocking on the result pytree each time, and
returns the raw per-call samples so the metrics layer can report
percentiles instead of a single mean that hides the tail.
"""
from __future__ import annotations

import time
from typing import Callable, List


def block(x) -> None:
    """Wait for every jax array in a result pytree; host values pass
    through untouched (scenarios also time pure-python paths)."""
    if x is None:
        return
    if hasattr(x, "block_until_ready"):
        x.block_until_ready()
        return
    if isinstance(x, (list, tuple)):
        for item in x:
            block(item)
        return
    if isinstance(x, dict):
        for item in x.values():
            block(item)


def measure(fn: Callable, *args, warmup: int = 1,
            iters: int = 5) -> List[float]:
    """Per-call wall seconds of ``fn(*args)`` over `iters` timed calls
    after `warmup` untimed ones. Each timed call blocks on its own
    result, so the samples include device time, not dispatch time."""
    assert iters >= 1, iters
    for _ in range(max(warmup, 0)):
        block(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        block(fn(*args))
        samples.append(time.perf_counter() - t0)
    return samples


class Stopwatch:
    """Accumulates per-event wall-clock samples (e.g. one per request):

        sw = Stopwatch()
        with sw.lap():
            serve_one()
        sw.samples  # [seconds, ...]
    """

    def __init__(self):
        self.samples: List[float] = []

    class _Lap:
        def __init__(self, sw):
            self._sw = sw

        def __enter__(self):
            self._t0 = time.perf_counter()
            return self

        def __exit__(self, *exc):
            self._sw.samples.append(time.perf_counter() - self._t0)
            return False

    def lap(self) -> "Stopwatch._Lap":
        return Stopwatch._Lap(self)
