"""Percentile statistics for benchmark samples.

The perf-trajectory harness gates CI on these numbers, so the math is
deliberately boring and deterministic: sort once, linear interpolation
between order statistics (the same convention as numpy's default
``np.percentile(..., method="linear")``), no randomness, no dependence
on sample order. `tests/test_bench.py` pins the implementation against
numpy on seeded samples.
"""
from __future__ import annotations

from typing import Dict, Iterable, List

PERCENTILES = (50, 90, 99)


def percentile(samples: Iterable[float], q: float) -> float:
    """q-th percentile (0..100) with linear interpolation between the
    two nearest order statistics — identical to numpy's default method,
    implemented here so the gate does not drift with numpy versions."""
    xs: List[float] = sorted(float(x) for x in samples)
    if not xs:
        raise ValueError("percentile() of empty sample set")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q={q} outside [0, 100]")
    if len(xs) == 1:
        return xs[0]
    pos = q / 100.0 * (len(xs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def summarize(samples: Iterable[float],
              percentiles: Iterable[int] = PERCENTILES) -> Dict[str, float]:
    """Order-independent summary of a sample set: n/mean/min/max plus
    the requested percentiles (keys ``p50``, ``p90``, ...)."""
    xs = [float(x) for x in samples]
    if not xs:
        raise ValueError("summarize() of empty sample set")
    out = {
        "n": float(len(xs)),
        "mean": sum(xs) / len(xs),
        "min": min(xs),
        "max": max(xs),
    }
    for q in percentiles:
        out[f"p{q}"] = percentile(xs, q)
    return out
