"""Metrics layer of the perf-trajectory harness.

A scenario returns ``{metric_name: Metric}``. Each Metric carries the
fields the baseline-diff gate needs to judge it without scenario-
specific knowledge: direction (`higher_is_better`), a relative noise
band (`noise`, None = informational / never gated), and optional
percentile detail for latency-style metrics.

Conventions for the noise band (a *relative* half-width; bench_diff may
scale it with --noise-scale for noisy CPU runners):
  - deterministic counters (token counts, page high-waters, COW forks,
    prefix hits): noise 0.0 — any worsening is a real behavior change;
  - wall-clock timings / throughputs: noise ~0.5 — CPU CI shares cores;
  - analytic projections (bytes ratios): noise 0.0 — pure arithmetic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional

from repro.bench.metrics.stats import PERCENTILES, percentile, summarize
from repro.bench.metrics.timers import Stopwatch, block, measure

TIMING_NOISE = 0.5       # default relative band for wall-clock metrics


@dataclass
class Metric:
    """One gated (or informational) benchmark number."""
    value: float
    unit: str = ""
    higher_is_better: bool = False
    noise: Optional[float] = TIMING_NOISE   # None = never gated
    percentiles: Optional[Dict[str, float]] = None

    def __post_init__(self):
        self.value = float(self.value)
        if self.noise is not None and self.noise < 0:
            raise ValueError(f"negative noise band: {self.noise}")


def latency(samples_s: Iterable[float], *, unit: str = "s",
            noise: float = TIMING_NOISE) -> Metric:
    """Latency metric from raw per-event samples: gate on p50 (robust
    to a single straggler), keep the full percentile summary."""
    summary = summarize(samples_s)
    return Metric(value=summary["p50"], unit=unit, higher_is_better=False,
                  noise=noise, percentiles=summary)


def throughput(value: float, *, unit: str = "tok/s",
               noise: float = TIMING_NOISE) -> Metric:
    return Metric(value=value, unit=unit, higher_is_better=True,
                  noise=noise)


def counter(value: float, *, unit: str = "", higher_is_better: bool = False,
            noise: float = 0.0) -> Metric:
    """Deterministic count (pages, tokens, forks): exact by default."""
    return Metric(value=value, unit=unit,
                  higher_is_better=higher_is_better, noise=noise)


def info(value: float, *, unit: str = "") -> Metric:
    """Recorded for the trajectory, never gated (e.g. totals fixed by
    the workload definition)."""
    return Metric(value=value, unit=unit, noise=None)


__all__ = [
    "Metric", "latency", "throughput", "counter", "info",
    "percentile", "summarize", "PERCENTILES",
    "measure", "block", "Stopwatch", "TIMING_NOISE",
]
