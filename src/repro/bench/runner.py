"""Benchmark executor: runs registered scenarios, emits BENCH_*.json.

The executor/runner split (mirroring the scheduler/engine split in
serve/): scenarios measure, the runner owns the lifecycle — per-
scenario wall timing, exception capture, schema'd emission, the final
summary table and the exit code. A scenario that raises is recorded as
``status: "fail"`` with its traceback *in the JSON document* and the
run exits nonzero with a summary table; it can no longer vanish into a
stderr line behind a clean CSV header (the old benchmarks/run.py
failure mode).
"""
from __future__ import annotations

import time
import traceback
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from repro.bench import schema
from repro.bench.metrics import Metric
from repro.bench.registry import Scenario, available_scenarios, get_scenario

DEFAULT_OUT_DIR = "artifacts/bench"


@dataclass
class BenchContext:
    """What the executor hands each scenario: the run mode and a seed.
    Scenarios must derive ALL randomness from `seed` so a re-run is an
    identical workload (the diff gate's counters assume it)."""
    quick: bool = False
    seed: int = 0
    out_dir: Path = Path(DEFAULT_OUT_DIR)


@dataclass
class ScenarioResult:
    name: str
    status: str                      # "pass" | "fail"
    wall_s: float
    metrics: Dict[str, Metric] = field(default_factory=dict)
    error: Optional[str] = None
    path: Optional[Path] = None

    @property
    def ok(self) -> bool:
        return self.status == "pass"


def run_one(scn: Scenario, ctx: BenchContext) -> ScenarioResult:
    """Execute one scenario, capturing failure instead of propagating:
    the trajectory must record that a scenario broke, not skip it."""
    t0 = time.perf_counter()
    try:
        metrics = scn(ctx)
        if not isinstance(metrics, dict) or not all(
                isinstance(m, Metric) for m in metrics.values()):
            raise TypeError(
                f"scenario {scn.name!r} must return dict[str, Metric], "
                f"got {type(metrics).__name__}")
        return ScenarioResult(name=scn.name, status="pass",
                              wall_s=time.perf_counter() - t0,
                              metrics=metrics)
    except Exception:  # noqa: BLE001 — recorded, reported, exit nonzero
        return ScenarioResult(name=scn.name, status="fail",
                              wall_s=time.perf_counter() - t0,
                              error=traceback.format_exc())


def _emit(result: ScenarioResult, scn: Scenario, ctx: BenchContext) -> Path:
    doc = schema.make_doc(result.name, result.metrics,
                          status=result.status, error=result.error,
                          wall_s=result.wall_s, quick=ctx.quick,
                          quant=scn.quant)
    return schema.write_doc(schema.bench_path(ctx.out_dir, result.name),
                            doc)


def _summary_table(results: Sequence[ScenarioResult]) -> str:
    rows = [("scenario", "status", "wall_s", "metrics", "output")]
    for r in results:
        rows.append((r.name, r.status.upper(), f"{r.wall_s:.2f}",
                     str(len(r.metrics)), str(r.path or "-")))
    widths = [max(len(row[i]) for row in rows) for i in range(len(rows[0]))]
    lines = ["  ".join(c.ljust(w) for c, w in zip(row, widths))
             for row in rows]
    lines.insert(1, "-" * len(lines[0]))
    return "\n".join(lines)


def run_scenarios(names: Optional[Sequence[str]] = None, *,
                  quick: bool = False, out_dir=DEFAULT_OUT_DIR,
                  seed: int = 0) -> List[ScenarioResult]:
    """Run `names` (default: the quick subset with quick=True, else
    every registered scenario), write one BENCH_<name>.json each, print
    the summary table. Callers turn the results into an exit code via
    `exit_code(results)`."""
    if names is None:
        names = available_scenarios(quick_only=quick)
    ctx = BenchContext(quick=quick, seed=seed, out_dir=Path(out_dir))
    results: List[ScenarioResult] = []
    for name in names:
        scn = get_scenario(name)
        print(f"[bench] {name} ...", flush=True)
        r = run_one(scn, ctx)
        r.path = _emit(r, scn, ctx)
        if not r.ok:
            print(f"[bench] {name} FAILED\n{r.error}", flush=True)
        results.append(r)
    print(f"\n{_summary_table(results)}")
    n_fail = sum(not r.ok for r in results)
    if n_fail:
        print(f"\n{n_fail}/{len(results)} scenario(s) FAILED")
    return results


def exit_code(results: Sequence[ScenarioResult]) -> int:
    if not results:
        return 1                 # an empty run gates nothing: loud, not green
    return 1 if any(not r.ok for r in results) else 0
