"""Scenario registry: string names -> benchmark scenario callables.

The same open-registration pattern as quant/registry.py's quantizer
registry: every benchmark scenario registers itself under a name with
`@register_scenario("name", ...)`, and the runner dispatches through
`get_scenario` — there is no suite list hard-coded anywhere. The
`benchmarks/` modules are the built-ins; importing them (which
`benchmarks/run.py` does) is what populates the registry, so this
module stays import-light and repro.bench never depends on benchmarks/
at import time.

A scenario is a callable ``fn(ctx) -> dict[str, Metric]`` where ctx is
a runner.BenchContext (quick flag, seed, output dir). The executor
(runner.py) owns everything around the call: timing, pass/fail capture,
schema'd emission, the summary table and the process exit code — a
scenario only measures and returns numbers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

_REGISTRY: Dict[str, "Scenario"] = {}


@dataclass(frozen=True)
class Scenario:
    """One registered benchmark scenario.

    quick: part of the fast CPU subset (`benchmarks/run.py --quick`,
        the CI regression gate). Quick scenarios must run in interpret-
        mode Pallas on a few CPU cores in well under a minute each.
    tags: free-form grouping ("serving", "kernels", "ppl", ...).
    quant: static description of the quantization config the scenario
        exercises (recorded in its BENCH document), None for dense.
    """
    name: str
    fn: Callable
    quick: bool = False
    tags: Tuple[str, ...] = ()
    quant: Optional[dict] = None

    def __call__(self, ctx):
        return self.fn(ctx)


def register_scenario(name: str, *, quick: bool = False,
                      tags: Tuple[str, ...] = (),
                      quant: Optional[dict] = None):
    """Function decorator: `@register_scenario("table4_speed", ...)`.
    Later registrations override (same contract as the quantizer
    registry — downstream code may re-register a scenario with a
    different implementation)."""
    def deco(fn):
        _REGISTRY[name] = Scenario(name=name, fn=fn, quick=quick,
                                   tags=tuple(tags), quant=quant)
        return fn
    return deco


def get_scenario(name: str) -> Scenario:
    if name not in _REGISTRY:
        known = ", ".join(sorted(_REGISTRY)) or "<none registered>"
        raise KeyError(f"unknown scenario {name!r}; known: {known}")
    return _REGISTRY[name]


def available_scenarios(*, quick_only: bool = False) -> Tuple[str, ...]:
    names = sorted(_REGISTRY)
    if quick_only:
        names = [n for n in names if _REGISTRY[n].quick]
    return tuple(names)
