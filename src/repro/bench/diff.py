"""Baseline-diff regression gate over BENCH_*.json documents.

Pure functions from (baseline doc, run doc) to verdicts, so the gate is
deterministic — the same pair of documents always yields the same
verdict (pinned by tests/test_bench.py) — and importable by both
tools/bench_diff.py (the CI entry point) and tests.

Judgment rules, per metric present in the BASELINE (the baseline is
the contract; metrics only in the run are informational):
  - metrics with ``noise: null`` are informational, never gated;
  - the *relative worsening* is computed direction-aware from
    `higher_is_better`; improvements never fail;
  - the allowed band is ``noise * noise_scale`` (CI passes a large
    --noise-scale on shared CPU runners; counters with noise 0 stay
    exact at any scale) plus a tiny epsilon for float round-trips;
  - a baseline of exactly 0 gates on any nonzero worsening (counters
    like cow_forks=0 must not silently start forking);
  - a scenario or metric missing from the run REGRESSES: coverage must
    not rot silently. A baseline whose scenario failed (`status:
    "fail"`) gates nothing but is reported.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

EPS = 1e-9


@dataclass(frozen=True)
class Verdict:
    scenario: str
    metric: str                      # "" for scenario-level problems
    status: str                      # "ok" | "regressed" | "missing" | "info"
    base_value: Optional[float] = None
    run_value: Optional[float] = None
    worse_by: Optional[float] = None   # relative worsening (+ = worse)
    band: Optional[float] = None       # allowed relative worsening

    @property
    def failed(self) -> bool:
        return self.status in ("regressed", "missing")


def relative_worsening(base: float, run: float,
                       higher_is_better: bool) -> float:
    """Signed relative change in the *bad* direction: positive means
    the run is worse than the baseline. A zero baseline degenerates to
    +/-inf on any change (counters that were exactly 0 must stay 0)."""
    delta = (base - run) if higher_is_better else (run - base)
    if abs(base) < EPS:
        return 0.0 if abs(delta) < EPS else float("inf") * (1 if delta > 0
                                                            else -1)
    return delta / abs(base)


def diff_metric(scenario: str, name: str, base_m: dict, run_m: Optional[dict],
                *, noise_scale: float = 1.0) -> Verdict:
    base_v = float(base_m["value"])
    noise = base_m.get("noise")
    if run_m is None:
        return Verdict(scenario, name, "missing", base_value=base_v)
    run_v = float(run_m["value"])
    if noise is None:
        return Verdict(scenario, name, "info", base_v, run_v)
    worse = relative_worsening(base_v, run_v,
                               bool(base_m.get("higher_is_better", False)))
    band = float(noise) * float(noise_scale)
    status = "regressed" if worse > band + EPS else "ok"
    return Verdict(scenario, name, status, base_v, run_v, worse, band)


def diff_docs(base_doc: dict, run_doc: Optional[dict], *,
              noise_scale: float = 1.0) -> List[Verdict]:
    name = base_doc["name"]
    if run_doc is None:
        return [Verdict(name, "", "missing")]
    if base_doc.get("status") != "pass":
        # a failed baseline holds no numbers worth gating on; surface it
        return [Verdict(name, "", "info")]
    if run_doc.get("status") != "pass":
        return [Verdict(name, "", "missing")]
    out = []
    run_metrics = run_doc.get("metrics", {})
    for mname, base_m in sorted(base_doc.get("metrics", {}).items()):
        out.append(diff_metric(name, mname, base_m,
                               run_metrics.get(mname),
                               noise_scale=noise_scale))
    return out


def diff_all(baselines: Dict[str, dict], runs: Dict[str, dict], *,
             noise_scale: float = 1.0) -> List[Verdict]:
    out: List[Verdict] = []
    for name in sorted(baselines):
        out.extend(diff_docs(baselines[name], runs.get(name),
                             noise_scale=noise_scale))
    return out


def fingerprint_mismatches(baselines: Dict[str, dict],
                           runs: Dict[str, dict]) -> List[str]:
    """Human-readable warnings when run and baseline machines differ —
    the trajectory is still gated (that is what noise_scale is for),
    but the reader should know the hardware moved under the numbers."""
    warns = []
    for name in sorted(set(baselines) & set(runs)):
        b = baselines[name].get("machine", {})
        r = runs[name].get("machine", {})
        keys = ("platform", "device_platform", "device_kind", "n_devices")
        delta = [f"{k}: {b.get(k)!r} -> {r.get(k)!r}"
                 for k in keys if b.get(k) != r.get(k)]
        if delta:
            warns.append(f"{name}: machine fingerprint differs "
                         f"({'; '.join(delta)})")
    return warns


def format_report(verdicts: Sequence[Verdict]) -> str:
    rows = [("scenario", "metric", "baseline", "run", "worse_by",
             "band", "verdict")]

    def fmt(v):
        return "-" if v is None else f"{v:.6g}"

    for v in verdicts:
        rows.append((v.scenario, v.metric or "<scenario>",
                     fmt(v.base_value), fmt(v.run_value),
                     "-" if v.worse_by is None else f"{v.worse_by:+.1%}",
                     "-" if v.band is None else f"{v.band:.1%}",
                     v.status.upper()))
    widths = [max(len(row[i]) for row in rows) for i in range(len(rows[0]))]
    lines = ["  ".join(c.ljust(w) for c, w in zip(row, widths))
             for row in rows]
    lines.insert(1, "-" * len(lines[0]))
    return "\n".join(lines)
