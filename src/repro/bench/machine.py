"""Machine fingerprint + git identity for BENCH_*.json provenance.

Every benchmark document records *where* its numbers came from, because
a perf trajectory spliced across machines is noise, not signal: the
diff gate prints a loud warning when the run and baseline fingerprints
disagree (CI runners vs the workstation that committed the baseline),
and readers of a BENCH file can always tell a v5e number from a laptop
number.
"""
from __future__ import annotations

import os
import platform
import subprocess
import sys


def fingerprint() -> dict:
    """Hashable-ish identity of the benchmarking host: platform, python,
    jax version and the accelerator jax actually sees. jax import is
    lazy-by-construction here only in the sense that callers invoke this
    at emit time, when the scenario has long since imported jax."""
    out = {
        "platform": platform.platform(),
        "python": platform.python_version(),
        "cpu_count": os.cpu_count() or 0,
    }
    try:
        import jax
        devs = jax.devices()
        out["jax"] = jax.__version__
        out["device_platform"] = devs[0].platform if devs else "none"
        out["device_kind"] = getattr(devs[0], "device_kind", "unknown") \
            if devs else "none"
        out["n_devices"] = len(devs)
    except Exception:  # noqa: BLE001 — fingerprinting must never fail a run
        out["jax"] = "unavailable"
        out["device_platform"] = "unknown"
        out["device_kind"] = "unknown"
        out["n_devices"] = 0
    return out


def git_sha(cwd: str | None = None) -> str:
    """HEAD commit of the benchmarked tree ("unknown" outside a repo);
    "-dirty" is appended when the worktree has uncommitted changes, so
    a baseline can never silently claim to be a committed state."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=cwd, text=True,
            capture_output=True, timeout=10, check=True).stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], cwd=cwd, text=True,
            capture_output=True, timeout=10, check=True).stdout.strip()
        return sha + ("-dirty" if dirty else "")
    except Exception:  # noqa: BLE001
        return "unknown"


def main() -> int:
    import json
    print(json.dumps({"machine": fingerprint(), "git_sha": git_sha()},
                     indent=2, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main())
