"""Versioned schema of the BENCH_<name>.json documents.

One document per scenario run. The schema is deliberately flat and
self-describing: a BENCH file carries everything the diff gate and a
human reader need — where it ran (machine fingerprint, git SHA), what
it measured (metrics with units, direction and noise bands), whether
the scenario even completed (status/error), and which schema version
wrote it.

Versioning contract:
  - `bench_schema_version` is required and integral.
  - documents written by an OLDER version load if their fields still
    validate (additive evolution is the plan, as with ckpt/packed.py's
    manifest FORMAT_VERSION).
  - documents written by a NEWER version are REFUSED with a clear
    error: silently misreading future fields could pass a regression
    gate on garbage. `tests/test_bench.py` pins this refusal path.

  python -m repro.bench.schema DIR   # validate every BENCH_*.json in DIR
"""
from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import Dict

from repro.bench.metrics import Metric

SCHEMA_VERSION = 1
PREFIX = "BENCH_"
STATUSES = ("pass", "fail")


class BenchSchemaError(ValueError):
    """A document does not satisfy the BENCH schema."""


def bench_path(out_dir, name: str) -> Path:
    return Path(out_dir) / f"{PREFIX}{name}.json"


# ---------------- metric (de)serialization ----------------

def metric_to_json(m: Metric) -> dict:
    d = {"value": m.value, "unit": m.unit,
         "higher_is_better": bool(m.higher_is_better), "noise": m.noise}
    if m.percentiles is not None:
        d["percentiles"] = {k: float(v) for k, v in m.percentiles.items()}
    return d


def metric_from_json(d: dict) -> Metric:
    return Metric(value=d["value"], unit=d.get("unit", ""),
                  higher_is_better=bool(d.get("higher_is_better", False)),
                  noise=d.get("noise"),
                  percentiles=d.get("percentiles"))


# ---------------- document construction ----------------

def make_doc(name: str, metrics: Dict[str, Metric], *, status: str = "pass",
             error: str | None = None, wall_s: float = 0.0,
             quick: bool = False, quant: dict | None = None,
             created_unix: float | None = None) -> dict:
    """Assemble a schema-valid document for one scenario run. `quant`
    is the quantization config the scenario exercised (a QuantSpec's
    dict form), None for dense/serving-only scenarios."""
    import time

    from repro.bench import machine
    doc = {
        "bench_schema_version": SCHEMA_VERSION,
        "name": str(name),
        "status": status,
        "error": error,
        "wall_s": float(wall_s),
        "quick": bool(quick),
        "created_unix": float(time.time() if created_unix is None
                              else created_unix),
        "git_sha": machine.git_sha(),
        "machine": machine.fingerprint(),
        "quant": quant,
        "metrics": {str(k): metric_to_json(v) for k, v in metrics.items()},
    }
    validate(doc)
    return doc


# ---------------- validation ----------------

def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise BenchSchemaError(msg)


def validate(doc: dict) -> None:
    """Raise BenchSchemaError unless `doc` is a valid BENCH document of
    this or an older schema version."""
    _require(isinstance(doc, dict), f"document is {type(doc).__name__}, "
             "not an object")
    v = doc.get("bench_schema_version")
    _require(isinstance(v, int) and not isinstance(v, bool),
             "bench_schema_version missing or not an integer")
    _require(v >= 1, f"bench_schema_version {v} < 1")
    _require(v <= SCHEMA_VERSION,
             f"document has bench_schema_version {v} but this tool only "
             f"understands <= {SCHEMA_VERSION}; refusing to interpret a "
             f"future format (upgrade the repo instead)")
    for field, types in (("name", str), ("status", str), ("wall_s", float),
                         ("quick", bool), ("git_sha", str),
                         ("machine", dict), ("metrics", dict)):
        _require(field in doc, f"missing required field '{field}'")
        val = doc[field]
        if types is float:
            _require(isinstance(val, (int, float))
                     and not isinstance(val, bool),
                     f"'{field}' must be a number, got {val!r}")
        else:
            _require(isinstance(val, types),
                     f"'{field}' must be {types.__name__}, got {val!r}")
    _require(doc["status"] in STATUSES,
             f"status {doc['status']!r} not in {STATUSES}")
    _require(doc.get("error") is None or isinstance(doc["error"], str),
             "'error' must be null or a string")
    _require(doc.get("quant") is None or isinstance(doc["quant"], dict),
             "'quant' must be null or an object")
    for mname, m in doc["metrics"].items():
        ctx = f"metric {mname!r}"
        _require(isinstance(m, dict), f"{ctx}: not an object")
        _require("value" in m, f"{ctx}: missing 'value'")
        _require(isinstance(m["value"], (int, float))
                 and not isinstance(m["value"], bool),
                 f"{ctx}: 'value' must be a number")
        noise = m.get("noise")
        _require(noise is None or (isinstance(noise, (int, float))
                                   and not isinstance(noise, bool)
                                   and noise >= 0),
                 f"{ctx}: 'noise' must be null or a number >= 0")
        _require(isinstance(m.get("higher_is_better", False), bool),
                 f"{ctx}: 'higher_is_better' must be a boolean")
        pct = m.get("percentiles")
        if pct is not None:
            _require(isinstance(pct, dict)
                     and all(isinstance(x, (int, float)) for x in
                             pct.values()),
                     f"{ctx}: 'percentiles' must map names to numbers")


# ---------------- file I/O ----------------

def write_doc(path, doc: dict) -> Path:
    validate(doc)
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    tmp.replace(path)
    return path


def load_doc(path) -> dict:
    path = Path(path)
    try:
        doc = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        raise BenchSchemaError(f"{path}: not valid JSON ({e})") from e
    try:
        validate(doc)
    except BenchSchemaError as e:
        raise BenchSchemaError(f"{path}: {e}") from e
    return doc


def load_dir(out_dir) -> Dict[str, dict]:
    """Every BENCH_*.json under `out_dir`, keyed by scenario name."""
    out = {}
    for p in sorted(Path(out_dir).glob(f"{PREFIX}*.json")):
        doc = load_doc(p)
        out[doc["name"]] = doc
    return out


def main(argv=None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    if len(args) != 1:
        print("usage: python -m repro.bench.schema DIR", file=sys.stderr)
        return 2
    paths = sorted(Path(args[0]).glob(f"{PREFIX}*.json"))
    if not paths:
        print(f"no {PREFIX}*.json under {args[0]}", file=sys.stderr)
        return 1
    bad = 0
    for p in paths:
        try:
            doc = load_doc(p)
            print(f"ok   {p} ({doc['name']}: {doc['status']}, "
                  f"{len(doc['metrics'])} metrics)")
        except BenchSchemaError as e:
            print(f"FAIL {e}")
            bad += 1
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
