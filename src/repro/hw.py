"""TPU layout constants — the single home for the tile/pack numbers the
kernels and the packed representations are built around.

Every block/tile/group size in `kernels/` and `quant/` must trace back
to these (repro-lint rule R004 enforces it): a tile height that is not a
SUBLANE multiple or a lane width that is not a LANE multiple silently
falls off the fast path on real hardware, and a group size that is not a
WORD multiple breaks the 32-signs-per-uint32 packing invariant. Defining
them once — instead of a `WORD = 32` per module — is what lets the lint
check the *values* as well as the names.

  SUBLANE  second-minor (sublane) tile height for fp32 operands; block
           heights (BM and friends) must be multiples of this.
  LANE     minor-dim lane width and MXU systolic dimension; block widths
           (BN) must be multiples of this.
  WORD     sign bits packed per uint32 word along K; K-blocks and scale
           group sizes must be multiples of this so groups never split a
           pack word.
"""
from __future__ import annotations

SUBLANE = 8
LANE = 128
WORD = 32
