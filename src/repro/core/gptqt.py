"""GPTQT: quantize twice (paper §II-B/C/D).

Per weight matrix (worked in GPTQ orientation Wt = W^T, rows = output
channels):

  step 1   per-row linear grid at `intermediate_bits` n (centered form,
           see core/rtn.py).
  step 2   pick a BCchoice — a binary-coding-expressible subset of the
           2^n integer levels — per row, minimizing the diag(H)-weighted
           weight error (second-order proxy of the paper's output-error
           criterion, DESIGN.md §6.3).
  re-expl  grid-search the scale multiplier over the Eq. 7 range
           [ (2^n-1)/(2^{n+r}-1), (2^n-1)/(2^{n-r}-1) ] with the chosen
           BCchoice fixed ("stretch the axis like a spring").
  solve    run the GPTQ solver against the final per-row float levels.
  fuse     collapse both steps into pure binary coding (Eq. 11):
           alpha_i = S'*e_i/2, beta = S'*(m - off) + center; pack sign
           bitplanes -> QuantizedTensor.

Group-wise scaling (`group_size > 0`, FineQuant-style): every contiguous
K-group of a row gets its OWN grid, BCchoice, and re-explored scale.
Groups fold into rows up front (core/rtn.group_rows), so steps 1-3 run
batched over all (row, group) pairs at once — the same vectorized code,
N*G rows of length K/G — and only the GPTQ solve sees the full rows,
switching grids at group boundaries via its `col_group` map. The fused
QuantizedTensor then carries true G = K/group_size scale leaves
(alphas (G, N, k), betas (G, N)).

Scoring uses per-row histograms of the int-domain weights (sufficient
statistics s0/s1/s2 per bin), which turns candidate search into two
(N, bins) @ (bins, n_candidates) matmuls; `exact=True` scores elementwise
instead (tests / tiny layers).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.binary_coding import (choice_levels_int,
                                      enumerate_bc_choices, sign_combos)
from repro.core.gptq import gptq_solve
from repro.core.rtn import group_rows, row_grid
from repro.quant.packing import pack_signs
from repro.quant.qlinear import QuantizedTensor

HIST_BINS_PER_LEVEL = 8


@dataclass
class GPTQTResult:
    qt: QuantizedTensor          # packed representation (layer layout K,N)
    wq_t: jnp.ndarray            # dequantized (N, K) fp32 (GPTQ orientation)
    levels: jnp.ndarray          # (N[, G], 2^k) final float levels
    choice_e: jnp.ndarray        # (N[, G], k) chosen e_i
    choice_j: jnp.ndarray        # (N[, G]) chosen offset j
    scale: jnp.ndarray           # (N[, G]) re-explored scale S'
    center: jnp.ndarray          # (N[, G]) row/group centers
    mult: jnp.ndarray            # (N[, G]) selected scale multiplier
    group_size: int = 0          # K-group length (0 = per-channel)


def _row_hist_stats(Wn, hd, n_levels, bins):
    """Wn (N, K) int-domain weights; hd (K,) or (N, K) diag-H weights.
    -> s0, s1, s2 (N, bins), bin centers (bins,)."""
    N, K = Wn.shape
    lo, hi = -0.5, n_levels - 0.5
    width = (hi - lo) / bins
    idx = jnp.clip(((Wn - lo) / width).astype(jnp.int32), 0, bins - 1)
    flat = (jnp.arange(N)[:, None] * bins + idx).reshape(-1)
    w = jnp.broadcast_to(hd, (N, K)).reshape(-1)
    x = Wn.reshape(-1)
    s0 = jax.ops.segment_sum(w, flat, N * bins).reshape(N, bins)
    s1 = jax.ops.segment_sum(w * x, flat, N * bins).reshape(N, bins)
    s2 = jax.ops.segment_sum(w * x * x, flat, N * bins).reshape(N, bins)
    centers = lo + (jnp.arange(bins) + 0.5) * width
    return s0, s1, s2, centers


def _score_candidates_hist(s0, s1, s2, centers, cand_levels):
    """cand_levels (C, L) int-domain (any order). Returns scores (N, C)."""
    sorted_lv = jnp.sort(cand_levels, axis=1)            # (C, L)
    mids = (sorted_lv[:, 1:] + sorted_lv[:, :-1]) / 2.0  # (C, L-1)
    # nearest level value per (candidate, bin)
    idx = jnp.sum(centers[None, :, None] > mids[:, None, :], axis=-1)  # (C,B)
    V = jnp.take_along_axis(sorted_lv, idx, axis=1)      # (C, B)
    # err(N,C) = sum_b s2 - 2 V s1 + V^2 s0
    const = jnp.sum(s2, axis=1, keepdims=True)           # (N, 1)
    return const - 2.0 * (s1 @ V.T) + (s0 @ (V * V).T)


def _score_candidates_exact(Wn, hd, cand_levels):
    """Elementwise scoring. Wn (N,K); hd (K,) or (N,K);
    cand_levels (C,L) -> (N, C)."""
    hd2 = jnp.broadcast_to(hd, Wn.shape)

    def one(lv):
        d = jnp.min(jnp.abs(Wn[..., None] - lv[None, None, :]), axis=-1)
        return jnp.sum(d * d * hd2, axis=1)
    return jax.lax.map(one, cand_levels).T               # (N, C)


def _mult_grid(reexplore_range: int, n: int, points: int):
    if reexplore_range <= 0:
        return jnp.ones((1,), jnp.float32)
    top = 2.0 ** n - 1.0
    lo = top / (2.0 ** (n + reexplore_range) - 1.0)
    hi = top / (2.0 ** (n - reexplore_range) - 1.0)
    return jnp.exp(jnp.linspace(jnp.log(lo), jnp.log(hi), points)).astype(jnp.float32)


def gptqt_quantize(Wt, H, *, bits=3, intermediate_bits=5,
                   reexplore_range=1, reexplore_points=33,
                   max_candidates=4096, exact=False, percdamp=0.01,
                   actorder=True, group_size=0,
                   orig_dtype="bfloat16") -> GPTQTResult:
    """Wt (N_out, K_in) fp32; H (K, K). Full GPTQT pipeline.

    `group_size > 0` fits an independent (grid, BCchoice, re-explored
    scale) per contiguous K-group; it must divide K.
    """
    Wt = Wt.astype(jnp.float32)
    N, K = Wt.shape
    n, k = intermediate_bits, bits
    n_levels = 2 ** n
    hd = jnp.clip(jnp.diag(H.astype(jnp.float32)), 1e-12, None)

    # fold groups into rows: all per-row steps below run on (R, Kg) with
    # R = N*G rows (one per (row, group) pair) — batch, don't loop
    Wr, G = group_rows(Wt, group_size)                   # (R, Kg)
    R, Kg = Wr.shape
    # per-(row,group) diag-H weights: group g sees hd columns [g*Kg, ...)
    hdr = jnp.tile(hd.reshape(G, Kg), (N, 1)) if G > 1 else hd

    # ---- step 1: linear grid ----
    S0, center = row_grid(Wr, n)

    # ---- step 2: BCchoice search at S0 ----
    E, J = enumerate_bc_choices(n, k, max_candidates=max_candidates)
    cand_levels = choice_levels_int(E, J, k)             # (C, 2^k)
    Wn = (Wr - center[:, None]) / S0[:, None] + (n_levels - 1) / 2.0
    if exact:
        scores = _score_candidates_exact(Wn, hdr, cand_levels)
    else:
        bins = HIST_BINS_PER_LEVEL * n_levels
        s0, s1, s2, centers = _row_hist_stats(Wn, hdr, n_levels, bins)
        scores = _score_candidates_hist(s0, s1, s2, centers, cand_levels)
    best = jnp.argmin(scores, axis=1)                    # (R,)
    ce, cj = E[best], J[best]                            # (R,k), (R,)

    # ---- re-explore scale (Eq. 7), choice fixed, per (row, group) ----
    mults = _mult_grid(reexplore_range, n, reexplore_points)
    combos = jnp.asarray(sign_combos(k))                 # (L, k)
    off = (n_levels - 1) / 2.0
    # int-domain levels per (row, group): (R, L)
    row_levels_int = cj[:, None] + (jnp.sum(ce, 1)[:, None] + ce @ combos.T) / 2.0
    sorted_rl = jnp.sort(row_levels_int, axis=1)
    mids = (sorted_rl[:, 1:] + sorted_rl[:, :-1]) / 2.0
    hdr2 = jnp.broadcast_to(hdr, Wr.shape)

    def mult_err(m):
        Wm = (Wr - center[:, None]) / (S0 * m)[:, None] + off
        idx = jnp.sum(Wm[:, :, None] > mids[:, None, :], axis=-1)
        q = jnp.take_along_axis(sorted_rl, idx.reshape(R, -1), axis=1).reshape(R, Kg)
        d = (Wm - q) * (S0 * m)[:, None]                 # back to float domain
        return jnp.sum(d * d * hdr2, axis=1)             # (R,)

    errs = jax.lax.map(mult_err, mults)                  # (M, R)
    mi = jnp.argmin(errs, axis=0)                        # (R,)
    mult = mults[mi]
    S = S0 * mult                                        # (R,)

    # ---- final float levels, computed EXACTLY as fused dequant does ----
    alphas = (ce / 2.0) * S[:, None]                     # (R, k)
    beta = (cj + jnp.sum(ce, 1) / 2.0 - off) * S + center  # (R,)
    levels = beta[:, None] + alphas @ combos.T           # (R, 2^k), combo order

    # ---- GPTQ solve against the fused grid(s) ----
    wq_t, idx = gptq_solve(Wt, H, levels.reshape(N, G, -1),
                           percdamp=percdamp, actorder=actorder)

    # ---- pack: combo index IS the sign pattern ----
    signs = ((idx[:, :, None] >> jnp.arange(k)[None, None, :]) & 1) > 0  # (N,K,k)
    signs = jnp.transpose(signs, (2, 1, 0))              # (k, K, N)
    codes = pack_signs(signs)
    qt = QuantizedTensor(
        codes=codes,                                       # (k, ceil(K/32), N)
        alphas=jnp.swapaxes(alphas.reshape(N, G, k), 0, 1),  # (G, N, k)
        betas=beta.reshape(N, G).T,                        # (G, N)
        k_in=K, orig_dtype=orig_dtype)

    def shaped(x):
        """(R, ...) -> (N, ...) for G=1, (N, G, ...) for grouped runs."""
        return x.reshape(N, G, *x.shape[1:]) if G > 1 else x
    return GPTQTResult(qt=qt, wq_t=wq_t, levels=shaped(levels),
                       choice_e=shaped(ce), choice_j=shaped(cj),
                       scale=shaped(S), center=shaped(center),
                       mult=shaped(mult), group_size=int(group_size))
