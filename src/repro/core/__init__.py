"""The paper's primary contribution: GPTQT two-step quantization,
its baselines, and the calibration/quantize-model machinery."""
from repro.core.api import (collect_hessians, eligible_paths,
                            quantize_matrix, quantize_model)
from repro.core.binary_coding import (bcq_alternating, bcq_greedy,
                                      bcq_levels, enumerate_bc_choices)
from repro.core.gptq import gptq_solve, gptq_solve_refresh, output_error
from repro.core.gptqt import gptqt_quantize
from repro.core.hessian import (HessianAccumulator, damp,
                                hessian_from_inputs)
from repro.core.rtn import (group_rows, linear_levels, minmse_grid,
                            n_k_groups, quantize_rtn, row_grid)

__all__ = [
    "quantize_model", "quantize_matrix", "collect_hessians",
    "eligible_paths", "gptqt_quantize", "gptq_solve",
    "gptq_solve_refresh", "output_error",
    "bcq_greedy", "bcq_alternating", "bcq_levels", "enumerate_bc_choices",
    "HessianAccumulator", "hessian_from_inputs", "damp", "quantize_rtn",
    "row_grid", "linear_levels", "minmse_grid", "group_rows", "n_k_groups",
]
