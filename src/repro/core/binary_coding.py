"""Binary-coding quantization (BCQ, paper Eq. 3-4) and the BCchoice
candidate enumeration used by GPTQT's second step.

A k-bit binary coding of a row w is w ~ sum_i alpha_i b_i with
b_i in {-1,+1}: 2^k representable values m +/- d_1 +/- ... +/- d_k.

`enumerate_bc_choices(n, k)` enumerates every subset of the step-1
integer axis {0..2^n-1} that is expressible as such a tree ("select
specific nodes and cotyledons from the linear quantization tree", Fig. 3):
with e_i = 2*d_i (positive integers, e_1 >= ... >= e_k) and
m = (sum e_i)/2 + j, all 2^k leaves are integers in range. The paper's
example [0,1,6,7] is (e=(5,1), j=0).
"""
from __future__ import annotations

import itertools

import numpy as np
import jax.numpy as jnp


def sign_combos(bits: int) -> np.ndarray:
    """(2^k, k) array of {-1,+1}: combo c uses sign of bit i of c."""
    c = np.arange(2 ** bits)[:, None]
    return (2 * ((c >> np.arange(bits)[None, :]) & 1) - 1).astype(np.float32)


def enumerate_bc_choices(intermediate_bits: int, bits: int,
                         max_candidates: int | None = None):
    """Returns (E (C, k) float32 of e_i values, J (C,) float32 offsets).
    Candidate level sets in int domain: j + (t + combos @ e) / 2."""
    top = 2 ** intermediate_bits - 1
    es, js = [], []
    # e_1 >= e_2 >= ... >= e_k >= 1, sum <= top
    for e in itertools.combinations_with_replacement(range(1, top + 1), bits):
        e = tuple(sorted(e, reverse=True))
        t = sum(e)
        if t > top:
            continue
        for j in range(top - t + 1):
            es.append(e)
            js.append(j)
    E = np.asarray(es, np.float32)
    J = np.asarray(js, np.float32)
    # dedupe identical level sets (degenerate e's can coincide)
    combos = sign_combos(bits)
    levels = J[:, None] + (E.sum(1)[:, None] + E @ combos.T) / 2.0
    key = np.unique(np.sort(levels, axis=1), axis=0, return_index=True)[1]
    E, J = E[np.sort(key)], J[np.sort(key)]
    if max_candidates is not None and len(E) > max_candidates:
        # keep a spread: sort by (span, offset) and stride-sample
        idx = np.linspace(0, len(E) - 1, max_candidates).astype(int)
        E, J = E[idx], J[idx]
    return jnp.asarray(E), jnp.asarray(J)


def choice_levels_int(E, J, bits: int):
    """(C, k), (C,) -> (C, 2^k) int-domain level values (combo order)."""
    combos = jnp.asarray(sign_combos(bits))              # (2^k, k)
    return J[:, None] + (jnp.sum(E, axis=1)[:, None] + E @ combos.T) / 2.0


# --------------------------------------------------------------------------
# BCQ baseline (Kwon et al.): greedy + alternating least squares
#
# Group-wise scaling: `group_size > 0` fits an independent binary coding
# per contiguous K-group. Groups are folded into rows (repro.core.rtn.
# group_rows) so the per-row solvers below batch over (row, group) pairs
# in one shot; alphas come back with an explicit (N, G, bits) group axis.
# --------------------------------------------------------------------------

def bcq_greedy(Wt, bits: int):
    """Eq. 3: residual sign coding. Wt (N, K) -> alphas (N, bits),
    signs (bits, N, K)."""
    r = Wt.astype(jnp.float32)
    alphas, signs = [], []
    for _ in range(bits):
        b = jnp.where(r >= 0, 1.0, -1.0)
        a = jnp.mean(jnp.abs(r), axis=1)                 # = r.b / K
        signs.append(b)
        alphas.append(a)
        r = r - a[:, None] * b
    return jnp.stack(alphas, 1), jnp.stack(signs, 0)


def bcq_alternating(Wt, bits: int, iters: int = 15, group_size: int = 0):
    """Eq. 4: alternately refit alphas by least squares and reassign signs
    by nearest representable level. Returns (Wq, alphas, signs) with
    Wq (N, K), signs (bits, N, K) and alphas (N, bits) — or, with
    `group_size > 0`, one coding per contiguous K-group and alphas
    carrying the group axis (N, G, bits)."""
    if group_size:
        from repro.core.rtn import group_rows
        Wg, G = group_rows(Wt, group_size)
        wq, alphas, signs = bcq_alternating(Wg, bits, iters)
        N, K = Wt.shape
        return (wq.reshape(N, K), alphas.reshape(N, G, bits),
                signs.reshape(bits, N, K))
    N, K = Wt.shape
    alphas, signs = bcq_greedy(Wt, bits)
    combos = jnp.asarray(sign_combos(bits))              # (L, k)
    for _ in range(iters):
        # refit alphas: per-row LS  (B^T B) a = B^T w
        B = jnp.stack(list(signs), 0)                    # (k, N, K)
        G = jnp.einsum("ink,jnk->nij", B, B)             # (N, k, k)
        rhs = jnp.einsum("ink,nk->ni", B, Wt)            # (N, k)
        G = G + 1e-6 * jnp.eye(bits)
        alphas = jnp.linalg.solve(G, rhs[..., None])[..., 0]
        alphas = jnp.abs(alphas)                         # canonical sign
        # reassign: nearest of the 2^k levels
        levels = combos @ alphas.T                       # (L, N)
        idx = jnp.argmin(
            jnp.abs(Wt[None] - levels[:, :, None]), axis=0)    # (N, K)
        signs = jnp.stack(
            [combos[idx, i] for i in range(bits)], 0)    # (k, N, K)
    wq = jnp.einsum("ink,ni->nk", signs, alphas)
    return wq, alphas, signs


def bcq_levels(Wt, bits: int, iters: int = 15, group_size: int = 0):
    """Level values of the BCQ-fit grid (for GPTQ+BCQ, Tab. V):
    (N, 2^k), or (N, G, 2^k) with `group_size > 0`."""
    _, alphas, _ = bcq_alternating(Wt, bits, iters, group_size=group_size)
    combos = jnp.asarray(sign_combos(bits))
    return alphas @ combos.T                             # (N[, G], 2^k)
