"""quantize_model(): walk a param tree, calibrate per-layer Hessians by
tapping linear() inputs on an unrolled forward, and quantize every
eligible weight with the requested method.

Methods (paper Tab. I/V grid):
  rtn          round-to-nearest linear grid
  gptq         GPTQ with linear grid
  gptq_minmse  GPTQ with per-row MSE-optimal clipped grid   (Tab. V)
  gptq_bcq     GPTQ with BCQ-fit binary-coding grid         (Tab. V)
  bcq          plain BCQ (no error compensation)
  gptqt        the paper's method (two-step + re-explore + fuse)

`mode="fake"` replaces weights with dequantized fp arrays (perplexity
evals, exactly what the paper measures); `mode="packed"` installs
QuantizedTensor leaves (fused binary coding; serving/kernels path).
Packed mode is available for gptqt/bcq — the binary-coding methods.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binary_coding as bc
from repro.core import rtn as rtn_mod
from repro.core.gptq import gptq_solve, output_error
from repro.core.gptqt import gptqt_quantize
from repro.core.hessian import hessian_from_inputs
from repro.models import layers as L
from repro.models.model import (_apply_layer, embed_inputs, unembed)
from repro.quant.packing import pack_signs
from repro.quant.qlinear import QuantizedTensor

# param-leaf names eligible for quantization (2D GEMM weights + 3D expert
# stacks); everything else (norms, convs, A_log, embeddings) is left alone.
QUANTIZABLE = {
    "wq", "wk", "wv", "wo", "wg", "wu", "wd", "in_proj", "out_proj",
    "x_proj", "dt_w", "wq_a", "wq_b", "wkv_a", "wkv_b", "lm_head",
}


def _leaf_name(path):
    last = path[-1]
    return getattr(last, "key", getattr(last, "name", str(last)))


def eligible_paths(cfg, params, include_head=False):
    """-> list of (path tuple, leaf) for quantizable weights."""
    out = []
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        name = _leaf_name(path)
        if name not in QUANTIZABLE:
            continue
        if name == "lm_head" and not include_head:
            continue
        if any(sub in name for sub in cfg.quant.exclude):
            continue
        out.append((path, leaf))
    return out


# --------------------------------------------------------------------------
# calibration: unrolled forward with activation taps
# --------------------------------------------------------------------------

def forward_unrolled(cfg, group_trees, top, inputs):
    """Python-loop forward over pre-sliced per-group param trees (so leaf
    object ids are stable for the tap)."""
    x = embed_inputs(cfg, top, inputs)
    positions = jnp.arange(x.shape[1])
    aux = jnp.zeros((), jnp.float32)
    for gp in group_trees:
        for i, spec in enumerate(cfg.pattern):
            x, aux, _ = _apply_layer(cfg, spec, gp[f"L{i}"], x, positions, aux)
    x = L.rmsnorm(x, top["final_ln"], cfg.norm_eps)
    return unembed(cfg, top, x), aux


def collect_hessians(cfg, params, calib_batches, include_head=False):
    """Run calibration batches, return {path_str: (leaf, H or [H_e], n)}.

    calib_batches: iterable of token (B, S) arrays (or frames).
    """
    blocks = params["blocks"]
    n_groups = cfg.n_groups
    group_trees = [jax.tree.map(lambda a: a[g], blocks) for g in range(n_groups)]
    top = {k: v for k, v in params.items() if k != "blocks"}

    # id -> path map over the sliced trees
    id2path = {}
    for g, gp in enumerate(group_trees):
        for path, leaf in jax.tree_util.tree_leaves_with_path(gp):
            name = _leaf_name(path)
            if name in QUANTIZABLE:
                id2path[id(leaf)] = (g, path, leaf)
    if include_head and "lm_head" in top:
        id2path[id(top["lm_head"])] = (-1, (jax.tree_util.DictKey("lm_head"),),
                                       top["lm_head"])

    acc: dict = {}
    with L.tap_activations() as rec:
        for batch in calib_batches:
            forward_unrolled(cfg, group_trees, top, batch)
            for wid, xs in rec.items():
                if wid not in id2path:
                    continue
                g, path, leaf = id2path[wid]
                key = (g, jax.tree_util.keystr(path))
                ent = acc.setdefault(key, {"leaf": leaf, "g": g, "path": path,
                                           "xs": []})
                ent["xs"].extend(xs)
            rec.clear()

    if not acc:
        raise RuntimeError(
            "calibration captured no activations for any quantizable "
            "weight — are the param leaves jax Arrays?")
    out = {}
    for key, ent in acc.items():
        leaf = ent["leaf"]
        if leaf.ndim == 3:      # expert stack (E, K, N): per-expert H
            E = leaf.shape[0]
            hs = []
            for e in range(E):
                xe = [x[e] for x in ent["xs"]]
                hs.append(hessian_from_inputs(xe)[0])
            out[key] = (ent["path"], ent["g"], leaf, hs)
        else:
            H, _ = hessian_from_inputs(ent["xs"])
            out[key] = (ent["path"], ent["g"], leaf, H)
    return out


# --------------------------------------------------------------------------
# per-matrix dispatch
# --------------------------------------------------------------------------

def quantize_matrix(W, H, method, qcfg, mode="fake", exact_search=False):
    """W: layer layout (K, N); H: (K, K). Returns (new leaf, stats)."""
    Wt = W.astype(jnp.float32).T                         # (N, K)
    bits = qcfg.bits
    if method == "rtn":
        wq, _ = rtn_mod.quantize_rtn(Wt, bits)
    elif method == "bcq":
        wq, alphas, signs = bc.bcq_alternating(Wt, bits)
        if mode == "packed":
            codes = pack_signs(jnp.transpose(signs, (0, 2, 1)))  # (k,K,N)
            qt = QuantizedTensor(codes, alphas[None],            # (1,N,k)
                                 jnp.zeros((1, Wt.shape[0]), jnp.float32),
                                 k_in=Wt.shape[1], orig_dtype=str(W.dtype))
            return qt, {"err": output_error(Wt, wq, H)}
    elif method in ("gptq", "gptq_minmse", "gptq_bcq"):
        if method == "gptq":
            S, center = rtn_mod.row_grid(Wt, bits)
            levels = rtn_mod.linear_levels(S, center, bits)
        elif method == "gptq_minmse":
            S, center = rtn_mod.minmse_grid(Wt, bits)
            levels = rtn_mod.linear_levels(S, center, bits)
        else:
            levels = bc.bcq_levels(Wt, bits)
        wq, _ = gptq_solve(Wt, H, levels)
    elif method == "gptqt":
        res = gptqt_quantize(
            Wt, H, bits=bits, intermediate_bits=qcfg.intermediate_bits,
            reexplore_range=qcfg.reexplore_range,
            reexplore_points=qcfg.reexplore_points,
            exact=exact_search, orig_dtype=str(W.dtype))
        if mode == "packed":
            return res.qt, {"err": output_error(Wt, res.wq_t, H)}
        wq = res.wq_t
    else:
        raise ValueError(f"unknown method {method!r}")
    return wq.T.astype(W.dtype), {"err": output_error(Wt, wq, H)}


def _set_leaf(params, path, value):
    """Functional leaf replacement by tree path."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    leaves = []
    for p, leaf in flat:
        leaves.append(value if p == path else leaf)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def quantize_model(cfg, params, calib_batches, *, method="gptqt", qcfg=None,
                   mode="fake", include_head=False, exact_search=False,
                   verbose=False):
    """Returns (new params, report dict). See module docstring."""
    qcfg = qcfg or cfg.quant
    hs = collect_hessians(cfg, params, calib_batches, include_head)
    blocks = params["blocks"]
    report = {}

    # regroup: stacked block leaves quantized per group then restacked
    by_path: dict = {}
    for key, (path, g, leaf, H) in hs.items():
        by_path.setdefault(jax.tree_util.keystr(path), []).append(
            (g, path, leaf, H))

    new_params = params
    for pstr, entries in sorted(by_path.items()):
        entries.sort(key=lambda e: e[0])
        g0, path0, leaf0, _ = entries[0]
        if g0 == -1:    # top-level (lm_head)
            new_leaf, st = quantize_matrix(leaf0, entries[0][3], method, qcfg,
                                           mode, exact_search)
            new_params = {**new_params, "lm_head": new_leaf}
            report[pstr] = st
            continue
        stacked_src = _get_by_path(blocks, path0)        # (G, ...) original
        news, errs = [], []
        for g, path, leaf, H in entries:
            src = stacked_src[g]
            if src.ndim == 3:                            # expert stack
                per_e = [quantize_matrix(src[e], H[e], method, qcfg, mode,
                                         exact_search) for e in range(src.shape[0])]
                new_e = _stack_leaves([p for p, _ in per_e])
                errs.extend(s["err"] for _, s in per_e)
                news.append(new_e)
            else:
                nl, st = quantize_matrix(src, H, method, qcfg, mode,
                                         exact_search)
                errs.append(st["err"])
                news.append(nl)
        stacked_new = _stack_leaves(news)
        new_blocks = _set_by_path(new_params["blocks"], path0, stacked_new)
        new_params = {**new_params, "blocks": new_blocks}
        report[pstr] = {"err": float(np.mean(errs))}
        if verbose:
            print(f"  quantized {pstr}: mean tr-err {report[pstr]['err']:.4g}")
    return new_params, report


def _stack_leaves(items):
    if isinstance(items[0], QuantizedTensor):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *items,
                            is_leaf=lambda x: isinstance(x, jax.Array))
    return jnp.stack(items)


def _get_by_path(tree, path):
    node = tree
    for k in path:
        node = node[getattr(k, "key", getattr(k, "idx", None))]
    return node


def _set_by_path(tree, path, value):
    k = path[0]
    key = getattr(k, "key", getattr(k, "idx", None))
    if len(path) == 1:
        new = dict(tree)
        new[key] = value
        return new
    new = dict(tree)
    new[key] = _set_by_path(tree[key], path[1:], value)
    return new
