"""quantize_model(): walk a param tree, calibrate per-layer Hessians by
tapping linear() inputs on an unrolled forward, and quantize every
eligible weight through the quantizer registry.

The surface is declarative: a `repro.quant.QuantSpec` names the method
(resolved through the `@register_quantizer` registry — `rtn`, `bcq`,
`gptq`, `gptq_minmse`, `gptq_bcq`, `gptqt`, or anything downstream
registers), the bit-widths, the mode, and ordered per-leaf override
rules for mixed precision (e.g. `lm_head`/`wv` at higher bits):

    spec = QuantSpec.from_config(cfg.quant, method="gptqt", mode="packed",
                                 overrides=(OverrideRule("wv", bits=4),))
    qparams, report = quantize_model(cfg, params, calib_batches, spec=spec)

`mode="fake"` replaces weights with dequantized fp arrays (perplexity
evals, exactly what the paper measures); `mode="packed"` installs
QuantizedTensor leaves (fused binary coding; serving/kernels path) and
is available for methods whose quantizer sets `supports_packed`
(gptqt/bcq). Packed trees persist via repro.ckpt.packed (save_packed /
load_packed) so serving can boot without re-quantizing.

Calibration streams: every captured activation batch is folded into a
per-weight `HessianAccumulator` immediately, so peak host/device memory
is O(K^2) per tracked weight — not O(#batches x activations).

The pre-spec keyword signature (method=, qcfg=, mode=, include_head=,
exact_search=) still works as a thin deprecation shim.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gptq import output_error
from repro.core.hessian import HessianAccumulator
from repro.models import layers as L
from repro.models.model import (_apply_layer, embed_inputs, unembed)
from repro.quant.qlinear import QuantizedTensor
from repro.quant.registry import get_quantizer
from repro.quant.spec import (LeafPlan, QuantSpec, dotted_path,
                              is_quantizable, leaf_name, QUANTIZABLE)

# leaf_name was private here before the spec module unified eligibility;
# keep the old underscore alias for back-compat imports.
_leaf_name = leaf_name


def eligible_paths(cfg, params, include_head=False):
    """-> list of (path tuple, leaf) for quantizable weights."""
    out = []
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        name = leaf_name(path)
        if is_quantizable(name, include_head=include_head,
                          exclude=cfg.quant.exclude,
                          ndim=getattr(leaf, "ndim", 0)):
            out.append((path, leaf))
    return out


# --------------------------------------------------------------------------
# calibration: unrolled forward with activation taps
# --------------------------------------------------------------------------

def forward_unrolled(cfg, group_trees, top, inputs):
    """Python-loop forward over pre-sliced per-group param trees (so leaf
    object ids are stable for the tap)."""
    x = embed_inputs(cfg, top, inputs)
    positions = jnp.arange(x.shape[1])
    aux = jnp.zeros((), jnp.float32)
    for gp in group_trees:
        for i, spec in enumerate(cfg.pattern):
            x, aux, _ = _apply_layer(cfg, spec, gp[f"L{i}"], x, positions, aux)
    x = L.rmsnorm(x, top["final_ln"], cfg.norm_eps)
    return unembed(cfg, top, x), aux


def _fold(ent, xs):
    """Stream captured activations into the entry's accumulator(s)."""
    leaf = ent["leaf"]
    if leaf.ndim == 3:                   # expert stack: per-expert H
        E, K = leaf.shape[0], leaf.shape[1]
        if ent["acc"] is None:
            ent["acc"] = [HessianAccumulator(K) for _ in range(E)]
        for x in xs:
            for e in range(E):
                ent["acc"][e].update(x[e])
    else:
        if ent["acc"] is None:
            ent["acc"] = HessianAccumulator(leaf.shape[0])
        for x in xs:
            ent["acc"].update(x)


def collect_hessians(cfg, params, calib_batches, include_head=False, *,
                     spec=None):
    """Run calibration batches, return {key: (path, g, leaf, H or [H_e])}.

    calib_batches: iterable of token (B, S) arrays (or frames).
    Activations are folded into streaming HessianAccumulators batch by
    batch — nothing beyond the (K, K) sums is retained. With a spec,
    only leaves the spec resolves to a plan are tracked.
    """
    if spec is None:
        spec = QuantSpec.from_config(cfg.quant, include_head=include_head)
    blocks = params["blocks"]
    n_groups = cfg.n_groups
    group_trees = [jax.tree.map(lambda a: a[g], blocks) for g in range(n_groups)]
    top = {k: v for k, v in params.items() if k != "blocks"}

    # id -> path map over the sliced trees (spec-eligible leaves only)
    id2path = {}
    for g, gp in enumerate(group_trees):
        for path, leaf in jax.tree_util.tree_leaves_with_path(gp):
            name = leaf_name(path)
            dotted = "blocks." + dotted_path(path)
            if spec.resolve(dotted, name, getattr(leaf, "ndim", 0)):
                id2path[id(leaf)] = (g, path, leaf)
    if "lm_head" in top and spec.resolve("lm_head", "lm_head",
                                         getattr(top["lm_head"], "ndim", 0)):
        id2path[id(top["lm_head"])] = (-1, (jax.tree_util.DictKey("lm_head"),),
                                       top["lm_head"])

    acc: dict = {}
    with L.tap_activations() as rec:
        for batch in calib_batches:
            forward_unrolled(cfg, group_trees, top, batch)
            for wid, xs in rec.items():
                if wid not in id2path:
                    continue
                g, path, leaf = id2path[wid]
                key = (g, jax.tree_util.keystr(path))
                ent = acc.setdefault(key, {"leaf": leaf, "g": g, "path": path,
                                           "acc": None})
                _fold(ent, xs)
            rec.clear()

    if not acc:
        raise RuntimeError(
            "calibration captured no activations for any quantizable "
            "weight — are the param leaves jax Arrays?")
    out = {}
    for key, ent in acc.items():
        if isinstance(ent["acc"], list):
            hs = [a.finalize()[0] for a in ent["acc"]]
            out[key] = (ent["path"], ent["g"], ent["leaf"], hs)
        else:
            out[key] = (ent["path"], ent["g"], ent["leaf"],
                        ent["acc"].finalize()[0])
    return out


# --------------------------------------------------------------------------
# per-matrix dispatch (registry)
# --------------------------------------------------------------------------

def quantize_matrix(W, H, method=None, qcfg=None, mode="fake",
                    exact_search=False, *, plan=None):
    """W: layer layout (K, N); H: (K, K). Returns (new leaf, stats).

    Dispatches through the quantizer registry. Pass `plan` (a resolved
    spec.LeafPlan) directly, or the legacy (method, qcfg, mode,
    exact_search) arguments which are folded into one.
    """
    if plan is None:
        plan = LeafPlan(
            method=method, bits=qcfg.bits, mode=mode,
            intermediate_bits=qcfg.intermediate_bits,
            group_size=qcfg.group_size,
            reexplore_range=qcfg.reexplore_range,
            reexplore_points=qcfg.reexplore_points,
            exact_search=exact_search)
    q = get_quantizer(plan.method)
    if plan.mode == "packed" and not q.supports_packed:
        raise ValueError(
            f"method {plan.method!r} has no packed (binary-coding) "
            f"representation; use mode='fake' or a packable method "
            f"(e.g. 'gptqt', 'bcq')")
    plan.n_groups(W.shape[-2])   # group_size must divide K_in (clear error)
    Wt = W.astype(jnp.float32).T                         # (N, K)
    res = q.quantize(Wt, H, plan, orig_dtype=str(W.dtype))
    stats = {"err": output_error(Wt, res.wq_t, H),
             "method": plan.method, "bits": plan.bits}
    if plan.mode == "packed":
        return res.qt, stats
    return res.wq_t.T.astype(W.dtype), stats


# --------------------------------------------------------------------------
# whole-model quantization
# --------------------------------------------------------------------------

_LEGACY_SENTINEL = object()


def _legacy_spec(cfg, method, qcfg, mode, include_head, exact_search):
    qcfg = qcfg if qcfg is not None else cfg.quant
    return QuantSpec.from_config(
        qcfg,
        method=method if method is not None else "gptqt",
        mode=mode if mode is not None else "fake",
        include_head=bool(include_head),
        exact_search=bool(exact_search))


def quantize_model(cfg, params, calib_batches, *, spec=None, method=None,
                   qcfg=None, mode=None, include_head=None,
                   exact_search=None, verbose=False):
    """Returns (new params, report dict). See module docstring.

    Canonical call: quantize_model(cfg, params, batches, spec=QuantSpec(...)).
    The legacy keywords (method=, qcfg=, mode=, include_head=,
    exact_search=) are a deprecation shim that builds the equivalent spec.
    """
    legacy = [v is not None
              for v in (method, qcfg, mode, include_head, exact_search)]
    if spec is None:
        if any(legacy):
            warnings.warn(
                "quantize_model(method=/qcfg=/mode=/include_head=/"
                "exact_search=) is deprecated; pass spec=QuantSpec(...) "
                "instead", DeprecationWarning, stacklevel=2)
        spec = _legacy_spec(cfg, method, qcfg, mode, include_head,
                            exact_search)
    elif any(legacy):
        raise TypeError("pass either spec= or the legacy keywords, not both")

    # validate every method the spec can name before any heavy work
    for m in {spec.method} | {r.method for r in spec.overrides if r.method}:
        q = get_quantizer(m)
        if spec.mode == "packed" and not q.supports_packed:
            raise ValueError(
                f"method {m!r} has no packed representation; spec mode "
                f"is 'packed'")

    hs = collect_hessians(cfg, params, calib_batches, spec=spec)
    blocks = params["blocks"]
    report = {}

    # regroup: stacked block leaves quantized per group then restacked
    by_path: dict = {}
    for key, (path, g, leaf, H) in hs.items():
        by_path.setdefault(jax.tree_util.keystr(path), []).append(
            (g, path, leaf, H))

    new_params = params
    for pstr, entries in sorted(by_path.items()):
        entries.sort(key=lambda e: e[0])
        g0, path0, leaf0, _ = entries[0]
        name = leaf_name(path0)
        dotted = ("blocks." if g0 != -1 else "") + dotted_path(path0)
        plan = spec.resolve(dotted, name, getattr(leaf0, "ndim", 0))
        assert plan is not None, dotted   # collect_hessians already filtered
        try:
            plan.n_groups(leaf0.shape[-2])
        except ValueError as e:
            raise ValueError(f"{dotted}: {e}") from None
        if g0 == -1:    # top-level (lm_head)
            new_leaf, st = quantize_matrix(leaf0, entries[0][3], plan=plan)
            new_params = {**new_params, "lm_head": new_leaf}
            report[pstr] = st
            continue
        stacked_src = _get_by_path(blocks, path0)        # (G, ...) original
        news, errs = [], []
        for g, path, leaf, H in entries:
            src = stacked_src[g]
            if src.ndim == 3:                            # expert stack
                per_e = [quantize_matrix(src[e], H[e], plan=plan)
                         for e in range(src.shape[0])]
                new_e = _stack_leaves([p for p, _ in per_e])
                errs.extend(s["err"] for _, s in per_e)
                news.append(new_e)
            else:
                nl, st = quantize_matrix(src, H, plan=plan)
                errs.append(st["err"])
                news.append(nl)
        stacked_new = _stack_leaves(news)
        new_blocks = _set_by_path(new_params["blocks"], path0, stacked_new)
        new_params = {**new_params, "blocks": new_blocks}
        report[pstr] = {"err": float(np.mean(errs)), "method": plan.method,
                        "bits": plan.bits}
        if verbose:
            print(f"  quantized {pstr} [{plan.method} w{plan.bits}]: "
                  f"mean tr-err {report[pstr]['err']:.4g}")
    return new_params, report


def _stack_leaves(items):
    if isinstance(items[0], QuantizedTensor):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *items,
                            is_leaf=lambda x: isinstance(x, jax.Array))
    return jnp.stack(items)


def _get_by_path(tree, path):
    node = tree
    for k in path:
        node = node[getattr(k, "key", getattr(k, "idx", None))]
    return node


def _set_by_path(tree, path, value):
    k = path[0]
    key = getattr(k, "key", getattr(k, "idx", None))
    if len(path) == 1:
        new = dict(tree)
        new[key] = value
        return new
    new = dict(tree)
    new[key] = _set_by_path(tree[key], path[1:], value)
    return new
