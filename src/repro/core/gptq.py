"""GPTQ solver (Frantar et al., paper Eqs. 1-2), generic over the level
grid: quantize column-by-column, compensating not-yet-quantized columns
through the Cholesky factor of H^-1. Because the grid is an argument
(per-row arbitrary level sets), the same solver backs GPTQ (linear grid),
GPTQ+BCQ (BCQ grid), GPTQ(min-MSE) (clipped grid) and GPTQT (BCchoice
grid) — exactly the comparison structure of Tab. V.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.hessian import damp


def _chol_inv_upper(H):
    """Upper Cholesky factor U (with H^-1 = U^T... per GPTQ convention:
    row U[c, c:] drives the compensation of columns > c)."""
    L = jnp.linalg.cholesky(H)
    eye = jnp.eye(H.shape[0], dtype=H.dtype)
    Linv = jax.scipy.linalg.solve_triangular(L, eye, lower=True)
    Hinv = Linv.T @ Linv
    return jnp.linalg.cholesky(Hinv).T


@functools.partial(jax.jit, static_argnames=())
def _solve_loop(Wt, U, levels):
    """Wt (N, K); U (K, K) upper; levels (N, L). Returns (Q, idx)."""
    N, K = Wt.shape

    def col_step(c, carry):
        W, Q, I = carry
        w = jax.lax.dynamic_slice_in_dim(W, c, 1, axis=1)[:, 0]   # (N,)
        urow = jax.lax.dynamic_slice_in_dim(U, c, 1, axis=0)[0]   # (K,)
        d = urow[c]
        idx = jnp.argmin(jnp.abs(w[:, None] - levels), axis=1)    # (N,)
        q = jnp.take_along_axis(levels, idx[:, None], axis=1)[:, 0]
        err = (w - q) / d
        mask = (jnp.arange(K) > c).astype(W.dtype)
        W = W - err[:, None] * (urow * mask)[None, :]
        Q = Q.at[:, c].set(q)
        I = I.at[:, c].set(idx.astype(jnp.int32))
        return W, Q, I

    Q0 = jnp.zeros_like(Wt)
    I0 = jnp.zeros(Wt.shape, jnp.int32)
    _, Q, I = jax.lax.fori_loop(0, K, col_step, (Wt, Q0, I0))
    return Q, I


def gptq_solve(Wt, H, levels, *, percdamp: float = 0.01, actorder: bool = True):
    """Quantize Wt (N_out, K_in) against level sets `levels` (N, L) using
    Hessian H (K, K). Returns (Wq (N,K) fp32, idx (N,K) int32)."""
    Wt = Wt.astype(jnp.float32)
    H, dead_cols = damp(H.astype(jnp.float32), percdamp)
    Wt = jnp.where(dead_cols[None, :], 0.0, Wt)

    K = Wt.shape[1]
    if actorder:
        perm = jnp.argsort(-jnp.diag(H))
        inv_perm = jnp.argsort(perm)
        Wt_p = Wt[:, perm]
        H_p = H[perm][:, perm]
    else:
        perm = inv_perm = None
        Wt_p, H_p = Wt, H

    U = _chol_inv_upper(H_p)
    Q, I = _solve_loop(Wt_p, U, levels.astype(jnp.float32))

    if actorder:
        Q, I = Q[:, inv_perm], I[:, inv_perm]
    return Q, I


def output_error(Wt, Wq, H):
    """tr((W-Wq) H (W-Wq)^T): the layer output MSE proxy (Eq. 1 objective,
    summed over rows). Used by tests and the Tab. V reproduction."""
    D = (Wt - Wq).astype(jnp.float32)
    return float(jnp.einsum("nk,kj,nj->", D, H.astype(jnp.float32), D))
