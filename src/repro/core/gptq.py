"""GPTQ solver (Frantar et al., paper Eqs. 1-2), generic over the level
grid: quantize column-by-column, compensating not-yet-quantized columns
through the Cholesky factor of H^-1. Because the grid is an argument
(per-row arbitrary level sets), the same solver backs GPTQ (linear grid),
GPTQ+BCQ (BCQ grid), GPTQ(min-MSE) (clipped grid) and GPTQT (BCchoice
grid) — exactly the comparison structure of Tab. V.

Group-wise grids: pass `levels` of shape (N, G, L) and the solver
switches to the column's group grid as the sweep crosses each group
boundary (`col_group` maps solve-order column -> group; with actorder
the map is permuted alongside the columns, so a column always quantizes
against its ORIGINAL group's grid — the static-groups convention).
`gptq_solve_refresh` is the sequential variant for linear grids without
actorder: at every group boundary it re-fits the group's scale/center
from the *current* (error-compensated) residual block, the literal
"refresh the scale as the sweep enters the group" schedule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.hessian import damp


def _chol_inv_upper(H):
    """Upper Cholesky factor U (with H^-1 = U^T... per GPTQ convention:
    row U[c, c:] drives the compensation of columns > c)."""
    L = jnp.linalg.cholesky(H)
    eye = jnp.eye(H.shape[0], dtype=H.dtype)
    Linv = jax.scipy.linalg.solve_triangular(L, eye, lower=True)
    Hinv = Linv.T @ Linv
    return jnp.linalg.cholesky(Hinv).T


@functools.partial(jax.jit, static_argnames=())
def _solve_loop(Wt, U, levels, col_group):
    """Wt (N, K); U (K, K) upper; levels (N, G, L); col_group (K,) int32
    mapping solve-order column -> grid index along G. Returns (Q, idx)."""
    N, K = Wt.shape

    def col_step(c, carry):
        W, Q, I = carry
        w = jax.lax.dynamic_slice_in_dim(W, c, 1, axis=1)[:, 0]   # (N,)
        urow = jax.lax.dynamic_slice_in_dim(U, c, 1, axis=0)[0]   # (K,)
        d = urow[c]
        lv = jax.lax.dynamic_index_in_dim(
            levels, col_group[c], axis=1, keepdims=False)         # (N, L)
        idx = jnp.argmin(jnp.abs(w[:, None] - lv), axis=1)        # (N,)
        q = jnp.take_along_axis(lv, idx[:, None], axis=1)[:, 0]
        err = (w - q) / d
        mask = (jnp.arange(K) > c).astype(W.dtype)
        W = W - err[:, None] * (urow * mask)[None, :]
        Q = Q.at[:, c].set(q)
        I = I.at[:, c].set(idx.astype(jnp.int32))
        return W, Q, I

    Q0 = jnp.zeros_like(Wt)
    I0 = jnp.zeros(Wt.shape, jnp.int32)
    _, Q, I = jax.lax.fori_loop(0, K, col_step, (Wt, Q0, I0))
    return Q, I


def gptq_solve(Wt, H, levels, *, percdamp: float = 0.01, actorder: bool = True,
               col_group=None):
    """Quantize Wt (N_out, K_in) against level sets `levels` using
    Hessian H (K, K). Returns (Wq (N,K) fp32, idx (N,K) int32).

    levels: (N, L) per-row grids, or (N, G, L) per-(row, K-group) grids
    with contiguous groups of length K/G (override the group of each
    column via `col_group` (K,) if the grouping is not contiguous).
    """
    Wt = Wt.astype(jnp.float32)
    H, dead_cols = damp(H.astype(jnp.float32), percdamp)
    Wt = jnp.where(dead_cols[None, :], 0.0, Wt)

    K = Wt.shape[1]
    levels = levels.astype(jnp.float32)
    if levels.ndim == 2:
        levels = levels[:, None, :]                      # (N, 1, L)
    G = levels.shape[1]
    if col_group is None:
        if K % G:
            raise ValueError(
                f"grouped levels (G={G}) need G to divide K={K} (or an "
                f"explicit col_group map)")
        col_group = jnp.arange(K, dtype=jnp.int32) // (K // G)
    col_group = jnp.asarray(col_group, jnp.int32)

    if actorder:
        perm = jnp.argsort(-jnp.diag(H))
        inv_perm = jnp.argsort(perm)
        Wt_p = Wt[:, perm]
        H_p = H[perm][:, perm]
        col_group = col_group[perm]
    else:
        perm = inv_perm = None
        Wt_p, H_p = Wt, H

    U = _chol_inv_upper(H_p)
    Q, I = _solve_loop(Wt_p, U, levels, col_group)

    if actorder:
        Q, I = Q[:, inv_perm], I[:, inv_perm]
    return Q, I


@functools.partial(jax.jit, static_argnames=("bits", "group_size"))
def _solve_loop_refresh(Wt, U, *, bits: int, group_size: int):
    """Linear-grid sweep that re-fits (S, center) per row from the
    CURRENT residual block each time the column index enters a new
    group. Requires natural column order (no actorder)."""
    N, K = Wt.shape
    n_levels = 2.0 ** bits
    off = (n_levels - 1.0) / 2.0

    def col_step(c, carry):
        W, Q, I, S, Cen = carry

        def refresh(_):
            blk = jax.lax.dynamic_slice_in_dim(W, c, group_size, axis=1)
            wmax = jnp.max(blk, axis=1)
            wmin = jnp.min(blk, axis=1)
            s = jnp.maximum((wmax - wmin) / (n_levels - 1.0), 1e-12)
            return s, (wmax + wmin) / 2.0

        S, Cen = jax.lax.cond(c % group_size == 0, refresh,
                              lambda _: (S, Cen), None)
        w = jax.lax.dynamic_slice_in_dim(W, c, 1, axis=1)[:, 0]   # (N,)
        urow = jax.lax.dynamic_slice_in_dim(U, c, 1, axis=0)[0]   # (K,)
        d = urow[c]
        idx = jnp.clip(jnp.round((w - Cen) / S + off), 0, n_levels - 1)
        q = S * (idx - off) + Cen
        err = (w - q) / d
        mask = (jnp.arange(K) > c).astype(W.dtype)
        W = W - err[:, None] * (urow * mask)[None, :]
        Q = Q.at[:, c].set(q)
        I = I.at[:, c].set(idx.astype(jnp.int32))
        return W, Q, I, S, Cen

    Q0 = jnp.zeros_like(Wt)
    I0 = jnp.zeros(Wt.shape, jnp.int32)
    S0 = jnp.ones((N,), jnp.float32)
    C0 = jnp.zeros((N,), jnp.float32)
    _, Q, I, _, _ = jax.lax.fori_loop(0, K, col_step, (Wt, Q0, I0, S0, C0))
    return Q, I


def gptq_solve_refresh(Wt, H, *, bits: int, group_size: int,
                       percdamp: float = 0.01):
    """GPTQ with a linear grid whose per-group scale is refreshed from
    the compensated residual at every group boundary (the reference
    GPTQ `groupsize` schedule; incompatible with actorder, which
    scatters a group's columns across the sweep)."""
    Wt = Wt.astype(jnp.float32)
    K = Wt.shape[1]
    if group_size <= 0 or K % group_size:
        raise ValueError(
            f"group_size={group_size} must be positive and divide K={K}")
    H, dead_cols = damp(H.astype(jnp.float32), percdamp)
    Wt = jnp.where(dead_cols[None, :], 0.0, Wt)
    U = _chol_inv_upper(H)
    return _solve_loop_refresh(Wt, U, bits=bits, group_size=group_size)


def output_error(Wt, Wq, H):
    """tr((W-Wq) H (W-Wq)^T): the layer output MSE proxy (Eq. 1 objective,
    summed over rows). Used by tests and the Tab. V reproduction."""
    D = (Wt - Wq).astype(jnp.float32)
    return float(jnp.einsum("nk,kj,nj->", D, H.astype(jnp.float32), D))
