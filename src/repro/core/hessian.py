"""Calibration Hessian accumulation: H = 2 X X^T (paper Eq. 1 context).

X is the layer *input* matrix; rows of W are quantized independently so a
single (K, K) Hessian serves all output channels. Accumulated in fp32,
averaged over samples (scale cancels in the solver except through the
relative damping, matching the GPTQ reference implementation).

`HessianAccumulator` is the streaming form: calibration folds each
activation batch into the running (K, K) sum as it is captured, so peak
memory per tracked weight is O(K^2) — independent of the number of
calibration batches. The old list-of-activations path retained every
(T_i, K) batch until the end of calibration; `hessian_from_inputs` is
kept as the one-shot wrapper over the accumulator (and as the reference
the streaming-equivalence test checks against).
"""
from __future__ import annotations

import jax.numpy as jnp


class HessianAccumulator:
    """Streaming H = (2/n) * sum_i x_i x_i^T over activation batches.

    update() folds one (..., K) activation array into the running fp32
    (K, K) sum; finalize() returns (H, n). Constant memory: only the
    (K, K) sum and a row count persist between batches.
    """

    def __init__(self, k: int):
        self.k = int(k)
        self._H = jnp.zeros((self.k, self.k), jnp.float32)
        self.n = 0

    def update(self, x) -> None:
        x = x.reshape(-1, self.k).astype(jnp.float32)
        self._H = self._H + 2.0 * (x.T @ x)
        self.n += x.shape[0]

    def finalize(self):
        """-> (H (K, K) fp32 averaged over samples, n rows seen)."""
        return self._H / max(self.n, 1), self.n


def hessian_from_inputs(xs):
    """xs: iterable of (T_i, K) activation matrices -> (H (K,K) fp32, n)."""
    acc = None
    for x in xs:
        if acc is None:
            acc = HessianAccumulator(x.shape[-1])
        acc.update(x)
    if acc is None:
        raise ValueError("hessian_from_inputs: no activation batches")
    return acc.finalize()


def damp(H, percdamp: float = 0.01):
    """GPTQ-style damping + dead-column handling. Returns (H, dead mask)."""
    diag = jnp.diag(H)
    dead = diag <= 0.0
    H = H + jnp.diag(jnp.where(dead, 1.0, 0.0))
    lam = percdamp * jnp.mean(jnp.where(dead, 0.0, diag))
    H = H + lam * jnp.eye(H.shape[0], dtype=H.dtype)
    return H, dead
