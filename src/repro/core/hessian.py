"""Calibration Hessian accumulation: H = 2 X X^T (paper Eq. 1 context).

X is the layer *input* matrix; rows of W are quantized independently so a
single (K, K) Hessian serves all output channels. Accumulated in fp32,
averaged over samples (scale cancels in the solver except through the
relative damping, matching the GPTQ reference implementation).
"""
from __future__ import annotations

import jax.numpy as jnp


def hessian_from_inputs(xs):
    """xs: list of (T_i, K) activation matrices -> (H (K,K) fp32, n)."""
    K = xs[0].shape[-1]
    H = jnp.zeros((K, K), jnp.float32)
    n = 0
    for x in xs:
        x = x.reshape(-1, K).astype(jnp.float32)
        H = H + 2.0 * (x.T @ x)
        n += x.shape[0]
    return H / max(n, 1), n


def damp(H, percdamp: float = 0.01):
    """GPTQ-style damping + dead-column handling. Returns (H, dead mask)."""
    diag = jnp.diag(H)
    dead = diag <= 0.0
    H = H + jnp.diag(jnp.where(dead, 1.0, 0.0))
    lam = percdamp * jnp.mean(jnp.where(dead, 0.0, diag))
    H = H + lam * jnp.eye(H.shape[0], dtype=H.dtype)
    return H, dead
