"""Built-in Quantizer implementations (paper Tab. I/V grid), registered
with the repro.quant registry:

  rtn          round-to-nearest linear grid
  bcq          plain BCQ (no error compensation); packable
  gptq         GPTQ with linear grid
  gptq_minmse  GPTQ with per-row MSE-optimal clipped grid   (Tab. V)
  gptq_bcq     GPTQ with BCQ-fit binary-coding grid         (Tab. V)
  gptqt        the paper's method (two-step + re-explore + fuse); packable

Each wraps a solver from repro.core; importing this module is what
populates the registry (repro.quant.registry lazy-imports it).

All methods honor `plan.group_size`: scales (and for the binary-coding
methods the whole alpha/beta coding) are fit per contiguous K-group.
Groups fold into rows via core/rtn.group_rows, so the per-row solvers
batch over (row, group) pairs; the GPTQ solver consumes grouped level
sets of shape (N, G, L) and switches grids at group boundaries.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import binary_coding as bc
from repro.core import rtn as rtn_mod
from repro.core.gptq import gptq_solve
from repro.core.gptqt import gptqt_quantize
from repro.core.rtn import group_rows
from repro.quant.packing import pack_signs
from repro.quant.qlinear import QuantizedTensor
from repro.quant.registry import QuantResult, Quantizer, register_quantizer


@register_quantizer("rtn")
class RTNQuantizer(Quantizer):
    def quantize(self, Wt, H, plan, *, orig_dtype="bfloat16"):
        wq, _ = rtn_mod.quantize_rtn(Wt, plan.bits,
                                     group_size=plan.group_size)
        return QuantResult(wq_t=wq)


@register_quantizer("bcq")
class BCQQuantizer(Quantizer):
    supports_packed = True

    def quantize(self, Wt, H, plan, *, orig_dtype="bfloat16"):
        N, K = Wt.shape
        wq, alphas, signs = bc.bcq_alternating(Wt, plan.bits,
                                               group_size=plan.group_size)
        qt = None
        if plan.mode == "packed":
            if alphas.ndim == 2:                         # (N, k) -> (1, N, k)
                alphas = alphas[None]
            else:                                        # (N, G, k) -> (G, N, k)
                alphas = jnp.swapaxes(alphas, 0, 1)
            G = alphas.shape[0]
            codes = pack_signs(jnp.transpose(signs, (0, 2, 1)))  # (k,K,N)
            qt = QuantizedTensor(codes, alphas,
                                 jnp.zeros((G, N), jnp.float32),
                                 k_in=K, orig_dtype=orig_dtype)
        return QuantResult(wq_t=wq, qt=qt)


class _GPTQBase(Quantizer):
    """GPTQ solver against a per-row (or per-row-group) level grid;
    subclasses pick the grid."""

    def levels(self, Wt, bits, group_size):
        raise NotImplementedError

    def quantize(self, Wt, H, plan, *, orig_dtype="bfloat16"):
        wq, _ = gptq_solve(Wt, H, self.levels(Wt, plan.bits,
                                              plan.group_size))
        return QuantResult(wq_t=wq)


@register_quantizer("gptq")
class GPTQQuantizer(_GPTQBase):
    def levels(self, Wt, bits, group_size):
        Wr, G = group_rows(Wt, group_size)
        S, center = rtn_mod.row_grid(Wr, bits)
        lv = rtn_mod.linear_levels(S, center, bits)      # (N*G, L)
        return lv.reshape(Wt.shape[0], G, -1) if G > 1 else lv


@register_quantizer("gptq_minmse")
class GPTQMinMSEQuantizer(_GPTQBase):
    def levels(self, Wt, bits, group_size):
        Wr, G = group_rows(Wt, group_size)
        S, center = rtn_mod.minmse_grid(Wr, bits)
        lv = rtn_mod.linear_levels(S, center, bits)
        return lv.reshape(Wt.shape[0], G, -1) if G > 1 else lv


@register_quantizer("gptq_bcq")
class GPTQBCQQuantizer(_GPTQBase):
    def levels(self, Wt, bits, group_size):
        return bc.bcq_levels(Wt, bits, group_size=group_size)


@register_quantizer("gptqt")
class GPTQTQuantizer(Quantizer):
    supports_packed = True

    def quantize(self, Wt, H, plan, *, orig_dtype="bfloat16"):
        res = gptqt_quantize(
            Wt, H, bits=plan.bits,
            intermediate_bits=plan.intermediate_bits,
            reexplore_range=plan.reexplore_range,
            reexplore_points=plan.reexplore_points,
            exact=plan.exact_search, group_size=plan.group_size,
            orig_dtype=orig_dtype)
        qt = res.qt if plan.mode == "packed" else None
        return QuantResult(wq_t=res.wq_t, qt=qt)
