"""Built-in Quantizer implementations (paper Tab. I/V grid), registered
with the repro.quant registry:

  rtn          round-to-nearest linear grid
  bcq          plain BCQ (no error compensation); packable
  gptq         GPTQ with linear grid
  gptq_minmse  GPTQ with per-row MSE-optimal clipped grid   (Tab. V)
  gptq_bcq     GPTQ with BCQ-fit binary-coding grid         (Tab. V)
  gptqt        the paper's method (two-step + re-explore + fuse); packable

Each wraps a solver from repro.core; importing this module is what
populates the registry (repro.quant.registry lazy-imports it).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import binary_coding as bc
from repro.core import rtn as rtn_mod
from repro.core.gptq import gptq_solve
from repro.core.gptqt import gptqt_quantize
from repro.quant.packing import pack_signs
from repro.quant.qlinear import QuantizedTensor
from repro.quant.registry import QuantResult, Quantizer, register_quantizer


@register_quantizer("rtn")
class RTNQuantizer(Quantizer):
    def quantize(self, Wt, H, plan, *, orig_dtype="bfloat16"):
        wq, _ = rtn_mod.quantize_rtn(Wt, plan.bits)
        return QuantResult(wq_t=wq)


@register_quantizer("bcq")
class BCQQuantizer(Quantizer):
    supports_packed = True

    def quantize(self, Wt, H, plan, *, orig_dtype="bfloat16"):
        wq, alphas, signs = bc.bcq_alternating(Wt, plan.bits)
        qt = None
        if plan.mode == "packed":
            codes = pack_signs(jnp.transpose(signs, (0, 2, 1)))  # (k,K,N)
            qt = QuantizedTensor(codes, alphas[None],            # (1,N,k)
                                 jnp.zeros((1, Wt.shape[0]), jnp.float32),
                                 k_in=Wt.shape[1], orig_dtype=orig_dtype)
        return QuantResult(wq_t=wq, qt=qt)


class _GPTQBase(Quantizer):
    """GPTQ solver against a per-row level grid; subclasses pick the grid."""

    def levels(self, Wt, bits):
        raise NotImplementedError

    def quantize(self, Wt, H, plan, *, orig_dtype="bfloat16"):
        wq, _ = gptq_solve(Wt, H, self.levels(Wt, plan.bits))
        return QuantResult(wq_t=wq)


@register_quantizer("gptq")
class GPTQQuantizer(_GPTQBase):
    def levels(self, Wt, bits):
        S, center = rtn_mod.row_grid(Wt, bits)
        return rtn_mod.linear_levels(S, center, bits)


@register_quantizer("gptq_minmse")
class GPTQMinMSEQuantizer(_GPTQBase):
    def levels(self, Wt, bits):
        S, center = rtn_mod.minmse_grid(Wt, bits)
        return rtn_mod.linear_levels(S, center, bits)


@register_quantizer("gptq_bcq")
class GPTQBCQQuantizer(_GPTQBase):
    def levels(self, Wt, bits):
        return bc.bcq_levels(Wt, bits)


@register_quantizer("gptqt")
class GPTQTQuantizer(Quantizer):
    supports_packed = True

    def quantize(self, Wt, H, plan, *, orig_dtype="bfloat16"):
        res = gptqt_quantize(
            Wt, H, bits=plan.bits,
            intermediate_bits=plan.intermediate_bits,
            reexplore_range=plan.reexplore_range,
            reexplore_points=plan.reexplore_points,
            exact=plan.exact_search, orig_dtype=orig_dtype)
        qt = res.qt if plan.mode == "packed" else None
        return QuantResult(wq_t=res.wq_t, qt=qt)
