"""Round-to-nearest (RTN) linear quantization + min-MSE clip search.

Grid convention (shared across the project): per output channel r,
    level(c) = S_r * (c - off) + center_r,  c in {0 .. 2^n - 1},
    off = (2^n - 1) / 2,  center_r = (Wmax_r + Wmin_r) / 2,
    S_r = (Wmax_r - Wmin_r) / (2^n - 1).
This is the paper's asymmetric grid written in centered form, so that
re-exploring S (Eq. 7) stretches the axis symmetrically about the row's
center ("like a spring", Fig. 2).

All functions take W_t of shape (N_rows=out, K_cols=in).
"""
from __future__ import annotations

import jax.numpy as jnp


def row_grid(Wt, bits: int, clip: float = 1.0):
    """Per-row (scale, center). clip < 1 shrinks the covered range."""
    wmax = jnp.max(Wt, axis=1)
    wmin = jnp.min(Wt, axis=1)
    center = (wmax + wmin) / 2.0
    S = clip * (wmax - wmin) / (2.0 ** bits - 1.0)
    S = jnp.maximum(S, 1e-12)
    return S.astype(jnp.float32), center.astype(jnp.float32)


def linear_levels(S, center, bits: int):
    """(N,) grids -> (N, 2^n) float level values."""
    n_levels = int(2 ** bits)
    off = (n_levels - 1) / 2.0
    c = jnp.arange(n_levels, dtype=jnp.float32) - off
    return S[:, None] * c[None, :] + center[:, None]


def quantize_rtn(Wt, bits: int, clip: float = 1.0):
    """-> (Wq, int codes) with the row grid above."""
    S, center = row_grid(Wt, bits, clip)
    off = (2.0 ** bits - 1.0) / 2.0
    q = jnp.round((Wt - center[:, None]) / S[:, None] + off)
    q = jnp.clip(q, 0, 2 ** bits - 1)
    wq = S[:, None] * (q - off) + center[:, None]
    return wq.astype(jnp.float32), q.astype(jnp.int32)


def minmse_grid(Wt, bits: int, n_grid: int = 32, lo: float = 0.4):
    """GPTQ(min MSE) baseline (Tab. V): per-row clip ratio minimizing the
    plain weight MSE. Returns (S, center) of the winning clipped grid."""
    ratios = jnp.linspace(lo, 1.0, n_grid)

    def err_for(r):
        wq, _ = quantize_rtn(Wt, bits, clip=float(r))
        return jnp.sum((wq - Wt) ** 2, axis=1)

    errs = jnp.stack([err_for(r) for r in ratios])      # (G, N)
    best = jnp.argmin(errs, axis=0)                     # (N,)
    best_ratio = ratios[best]
    S, center = row_grid(Wt, bits)
    return (S * best_ratio).astype(jnp.float32), center
