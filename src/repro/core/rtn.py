"""Round-to-nearest (RTN) linear quantization + min-MSE clip search.

Grid convention (shared across the project): per output channel r,
    level(c) = S_r * (c - off) + center_r,  c in {0 .. 2^n - 1},
    off = (2^n - 1) / 2,  center_r = (Wmax_r + Wmin_r) / 2,
    S_r = (Wmax_r - Wmin_r) / (2^n - 1).
This is the paper's asymmetric grid written in centered form, so that
re-exploring S (Eq. 7) stretches the axis symmetrically about the row's
center ("like a spring", Fig. 2).

All functions take W_t of shape (N_rows=out, K_cols=in).

Group-wise scaling (FineQuant-style): `group_rows` folds contiguous
K-groups of length `group_size` into extra rows, so every per-row
routine above becomes per-(row, group) for free — one reshape, no
vmap needed (the rows ARE the batch). `group_size=0` keeps one group
per row (per-channel). Non-divisible K raises: callers either pick a
divisor of K or pad before calling.
"""
from __future__ import annotations

import jax.numpy as jnp


def n_k_groups(k: int, group_size: int) -> int:
    """Number of contiguous K-groups; validates divisibility."""
    if group_size == 0:
        return 1
    if group_size < 0:
        raise ValueError(f"group_size must be >= 0, got {group_size}")
    if k % group_size:
        raise ValueError(
            f"group_size={group_size} does not divide K={k}; pick a "
            f"divisor of K (or 0 for per-channel scales) — padding is "
            f"not applied implicitly")
    return k // group_size


def group_rows(Wt, group_size: int):
    """(N, K) -> ((N*G, K/G) view with groups as rows, G). Row order is
    (n, g) -> n*G + g, i.e. a plain row-major reshape, so
    `X.reshape(N, G, ...)` inverts it."""
    N, K = Wt.shape
    G = n_k_groups(K, group_size)
    return Wt.reshape(N * G, K // G), G


def row_grid(Wt, bits: int, clip: float = 1.0):
    """Per-row (scale, center). clip < 1 shrinks the covered range."""
    wmax = jnp.max(Wt, axis=1)
    wmin = jnp.min(Wt, axis=1)
    center = (wmax + wmin) / 2.0
    S = clip * (wmax - wmin) / (2.0 ** bits - 1.0)
    S = jnp.maximum(S, 1e-12)
    return S.astype(jnp.float32), center.astype(jnp.float32)


def linear_levels(S, center, bits: int):
    """(N,) grids -> (N, 2^n) float level values."""
    n_levels = int(2 ** bits)
    off = (n_levels - 1) / 2.0
    c = jnp.arange(n_levels, dtype=jnp.float32) - off
    return S[:, None] * c[None, :] + center[:, None]


def quantize_rtn(Wt, bits: int, clip: float = 1.0, group_size: int = 0):
    """-> (Wq, int codes) with the row grid above; `group_size > 0`
    fits one grid per contiguous K-group instead of per row."""
    if group_size:
        Wg, _ = group_rows(Wt, group_size)
        wq, q = quantize_rtn(Wg, bits, clip)
        return wq.reshape(Wt.shape), q.reshape(Wt.shape)
    S, center = row_grid(Wt, bits, clip)
    off = (2.0 ** bits - 1.0) / 2.0
    q = jnp.round((Wt - center[:, None]) / S[:, None] + off)
    q = jnp.clip(q, 0, 2 ** bits - 1)
    wq = S[:, None] * (q - off) + center[:, None]
    return wq.astype(jnp.float32), q.astype(jnp.int32)


def minmse_grid(Wt, bits: int, n_grid: int = 32, lo: float = 0.4):
    """GPTQ(min MSE) baseline (Tab. V): per-row clip ratio minimizing the
    plain weight MSE. Returns (S, center) of the winning clipped grid."""
    ratios = jnp.linspace(lo, 1.0, n_grid)

    def err_for(r):
        wq, _ = quantize_rtn(Wt, bits, clip=float(r))
        return jnp.sum((wq - Wt) ** 2, axis=1)

    errs = jnp.stack([err_for(r) for r in ratios])      # (G, N)
    best = jnp.argmin(errs, axis=0)                     # (N,)
    best_ratio = ratios[best]
    S, center = row_grid(Wt, bits)
    return (S * best_ratio).astype(jnp.float32), center
