"""Mesh context + in-graph batch anchoring.

`mesh_context(mesh)` establishes the active mesh for a region of code;
`constrain_batch(x, *rest)` is the model-side anchor: inside a mesh
context it pins dim 0 of an activation to the batch (data) axes and the
remaining dims to the given axis names, and outside any mesh (the
single-device test/CPU path) it is an exact no-op. Model code can
therefore call it unconditionally.
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding

_MESH_STACK: list = []


def _thread_mesh():
    """Mesh installed by a plain `with mesh:` block (legacy global mesh)."""
    try:
        m = jax.interpreters.pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:  # noqa: BLE001 — internals moved; treat as no mesh
        pass
    return None


def current_mesh():
    if _MESH_STACK:
        return _MESH_STACK[-1]
    return _thread_mesh()


@contextlib.contextmanager
def mesh_context(mesh):
    """Install `mesh` as the active mesh (stacked; reentrant)."""
    _MESH_STACK.append(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _MESH_STACK.pop()


def constrain_batch(x, *rest):
    """Anchor activation `x`: dim 0 on the batch (data) axes, dims 1..n on
    the given axis names (None = unsharded). No-op without a mesh or on a
    1-device mesh. Extra/missing `rest` entries are padded with None."""
    from repro.dist.sharding import batch_pspec

    mesh = current_mesh()
    if mesh is None or mesh.devices.size <= 1:
        return x
    names = tuple(rest) + (None,) * (x.ndim - 1 - len(rest))
    spec = batch_pspec(mesh, x.shape[0], names[:x.ndim - 1])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
