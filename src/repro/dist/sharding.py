"""GSPMD sharding rules for params, optimizer state, caches and inputs.

One rule set, three consumers: the training launcher, the dry-run
compiler, and the serving path. Rules are *total* functions of
(config, tree path, leaf shape, mesh) with a divisibility guard — an
axis is only applied when the dim is divisible by the mesh axis size,
otherwise it is dropped (replicated) rather than erroring.

Conventions (2-axis production mesh ("data", "model")):
  - "expand" projections (wq/wk/wv/wg/wu/...):  K on data (FSDP), N on model
  - "contract" projections (wo/wd/out_proj):    K on model, N on data
  - embed (V, D): vocab on model, d_model on data; lm_head transposed
  - MoE expert stacks (G, E, K, N): experts on model when E % model == 0
    (expert parallelism), else TP inside each expert
  - KV caches (G, B, H, S, hd): batch on data; heads on model when
    divisible, else *sequence* on model (flash-decode partial softmax);
    B=1 shards sequence over both axes
  - paged KV pools (G, P, page, H, hd): pages on data, heads on model
  - QuantizedTensor leaves shard like the dense weight they replace
    (codes: K on data / N on model; alphas/betas: N on model)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# projections whose *input* dim carries the model axis (output of a
# model-sharded matmul feeds them; avoids a reshard between the pair)
_CONTRACT = {"wo", "wd", "out_proj"}
# matmul-weight leaves (everything else — norms, biases, conv filters,
# SSM decay params — replicates): any name starting with "w" plus these
_MATMUL_EXTRA = {"in_proj", "x_proj", "dt_w", "out_proj", "router",
                 "embed", "lm_head"}
_QT_LEAVES = {".codes", ".alphas", ".betas"}
# Leaves models/ constructs that intentionally replicate (norm scales,
# per-channel vectors, SSM decay params). repro-lint rule R006 checks
# every leaf name models/ constructs against this module: a new leaf
# must either match a placement rule below or be declared here, so
# replication is always a decision, never a silent default.
REPLICATED_LEAVES = frozenset({
    "ln", "ln2", "post_ln", "post_ln2", "final_ln",   # rmsnorm scales
    "qn", "kn", "q_a_norm", "kv_a_norm",              # qk / latent norms
    "conv_w", "conv_b", "dt_b", "A_log", "D",         # mamba per-channel
})


def _is_matmul(name: str) -> bool:
    return name.startswith("w") or name in _MATMUL_EXTRA


# --------------------------------------------------------------------------
# mesh helpers
# --------------------------------------------------------------------------

def mesh_axis_sizes(mesh) -> dict:
    """axis name -> size. Works for jax.sharding.Mesh AND shape-only
    stand-ins that expose .axis_names and .devices (tests use a
    FakeMesh). THE one derivation — engine/launcher shard counts must
    not re-zip this themselves."""
    return dict(zip(tuple(mesh.axis_names), np.shape(mesh.devices)))


_axis_sizes = mesh_axis_sizes


def _div(n: int, axis, sizes) -> bool:
    if axis is None:
        return True
    if isinstance(axis, tuple):
        total = 1
        for a in axis:
            total *= sizes[a]
        return n % total == 0
    return n % sizes[axis] == 0


def _guard(shape, spec, sizes):
    return P(*[a if _div(d, a, sizes) else None for d, a in zip(shape, spec)])


def batch_pspec(mesh, batch: int, rest=(None,)) -> P:
    """Batch-dim spec: all data-ish axes when divisible, the plain data
    axis as fallback, replicated otherwise. `rest` fills trailing dims."""
    sizes = _axis_sizes(mesh)
    combo = tuple(a for a in ("pod", "data") if a in sizes)
    ax = None
    if combo and _div(batch, combo, sizes):
        ax = combo if len(combo) > 1 else combo[0]
    elif "data" in sizes and _div(batch, "data", sizes):
        ax = "data"
    return P(ax, *rest)


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------

def _path_names(path):
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append("." + str(k.name))
        else:
            out.append(str(k))
    return out


def param_pspec(cfg, path, leaf, mesh, *, fsdp: bool = True) -> P:
    """Sharding rule for one parameter leaf. `path` is a jax key path."""
    return named_pspec(cfg, _path_names(path), leaf, mesh, fsdp=fsdp)


def named_pspec(cfg, names, leaf, mesh, *, fsdp: bool = True) -> P:
    """param_pspec over plain string path components — the manifest
    writer (ckpt/packed.py) walks a nested dict and has no jax key
    paths. QuantizedTensor children are addressed by appending
    ".codes"/".alphas"/".betas" to the weight's path."""
    sizes = _axis_sizes(mesh)
    name = names[-1]
    shape = tuple(leaf.shape)
    data_ax = "data" if (fsdp and "data" in sizes) else None
    model_ax = "model" if "model" in sizes else None

    if name in _QT_LEAVES:
        return _qt_pspec(name, names[-2] if len(names) > 1 else "", shape,
                         sizes, data_ax, model_ax,
                         is_expert=any(n == "moe" for n in names))

    if len(shape) < 2 or not _is_matmul(name):
        return P(*([None] * len(shape)))

    if name == "embed" and len(shape) == 2:
        return _guard(shape, P(model_ax, data_ax), sizes)
    if name == "lm_head":
        return _guard(shape, (None,) * (len(shape) - 2) + (data_ax, model_ax),
                      sizes)

    is_expert = any(n == "moe" for n in names) and len(shape) >= 3 \
        and name != "router"
    if is_expert:
        lead = (None,) * (len(shape) - 3)
        E, K, N = shape[-3:]
        if model_ax is not None and sizes[model_ax] and E % sizes[model_ax] == 0:
            # expert parallelism: E on model, FSDP on K, N replicated
            return _guard(shape, lead + (model_ax, data_ax, None), sizes)
        if name in _CONTRACT:
            return _guard(shape, lead + (None, model_ax, data_ax), sizes)
        return _guard(shape, lead + (None, data_ax, model_ax), sizes)

    lead = (None,) * (len(shape) - 2)
    if name in _CONTRACT:
        return _guard(shape, lead + (model_ax, data_ax), sizes)
    return _guard(shape, lead + (data_ax, model_ax), sizes)


def _qt_pspec(leaf_name, base_name, shape, sizes, data_ax, model_ax,
              is_expert=False):
    """QuantizedTensor children shard like the dense weight they stand
    in for: codes (..., bits, K/32, N), alphas (..., G, N, bits),
    betas (..., G, N). Batched-expert stacks (leading E dim under a
    "moe" path) mirror the dense expert-parallel rule: E rides the
    model axis when divisible, codes keep FSDP on the packed-K dim and
    scales replicate within an expert."""
    if base_name in _CONTRACT:
        k_ax, n_ax = model_ax, data_ax
    else:
        k_ax, n_ax = data_ax, model_ax
    base_rank = {".codes": 3, ".alphas": 3, ".betas": 2}[leaf_name]
    if (is_expert and base_name != "router" and len(shape) > base_rank
            and model_ax is not None and shape[0] % sizes[model_ax] == 0):
        mid = (None,) * (len(shape) - base_rank - 1)
        if leaf_name == ".codes":
            spec = (model_ax,) + mid + (None, data_ax, None)
        elif leaf_name == ".alphas":
            spec = (model_ax,) + mid + (None, None, None)
        else:  # .betas
            spec = (model_ax,) + mid + (None, None)
        return _guard(shape, spec, sizes)
    if leaf_name == ".codes":
        spec = (None,) * (len(shape) - 2) + (k_ax, n_ax)
    elif leaf_name == ".alphas":
        spec = (None,) * (len(shape) - 2) + (n_ax, None)
    else:  # .betas
        spec = (None,) * (len(shape) - 1) + (n_ax,)
    return _guard(shape, spec, sizes)


def params_shardings(cfg, params, mesh, *, fsdp: bool = True):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, param_pspec(cfg, p, l, mesh,
                                                     fsdp=fsdp)), params)


def opt_state_shardings(cfg, opt_state, mesh, *, fsdp: bool = True):
    """Optimizer moments mirror the param rules (path minus the mu/nu/
    master prefix); scalars (step) replicate."""
    def rule(path, leaf):
        if len(leaf.shape) == 0:
            return NamedSharding(mesh, P())
        sub = path[1:] if len(path) > 1 else path
        return NamedSharding(mesh, param_pspec(cfg, sub, leaf, mesh,
                                               fsdp=fsdp))
    return jax.tree_util.tree_map_with_path(rule, opt_state)


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------

def cache_pspec(cfg, path, leaf, mesh) -> P:
    sizes = _axis_sizes(mesh)
    names = _path_names(path)
    name = names[-1]
    shape = tuple(leaf.shape)
    data_ax = "data" if "data" in sizes else None
    model_ax = "model" if "model" in sizes else None

    if name in ("k_pages", "v_pages") and len(shape) == 5:
        # (G, P, page, H, hd): pages across data, kv heads across model
        return _guard(shape, P(None, data_ax, None, model_ax, None), sizes)

    if name in ("ckv_pages", "kpe_pages") and len(shape) == 5:
        # MLA latent pages (G, P, page, 1, r): pages across data; the
        # per-token latent/rope vectors are small and replicate
        return _guard(shape, P(None, data_ax, None, None, None), sizes)

    # binary-coded pool leaves (quant/kv.py layout): same placement —
    # pages ride the data axis, kv heads the model axis — applied to the
    # codes and both scale leaves so a page's codes and scales always
    # land on the same devices
    if name in ("k_codes", "v_codes", "k_alphas", "v_alphas") \
            and len(shape) == 6:
        # (G, P, page, H, bits, hd/32) / (G, P, page, H, Gk, bits)
        return _guard(shape, P(None, data_ax, None, model_ax, None, None),
                      sizes)
    if name in ("k_betas", "v_betas") and len(shape) == 5:
        # (G, P, page, H, Gk)
        return _guard(shape, P(None, data_ax, None, model_ax, None), sizes)

    if name in ("k", "v") and len(shape) == 5:
        G, B, H, S, hd = shape
        batch_ax = data_ax if _div(B, data_ax, sizes) else None
        head_ax = model_ax if _div(H, model_ax, sizes) else None
        seq_ax = None
        if head_ax is None and model_ax is not None:
            both = tuple(a for a in (data_ax, model_ax) if a)
            if batch_ax is None and len(both) > 1 and _div(S, both, sizes):
                seq_ax = both
            elif _div(S, model_ax, sizes):
                seq_ax = model_ax
        return P(None, batch_ax, head_ax, seq_ax, None)

    if name in ("c_kv", "k_pe") and len(shape) == 4:   # MLA latent cache
        G, B, S, r = shape
        batch_ax = data_ax if _div(B, data_ax, sizes) else None
        seq_ax = model_ax if _div(S, model_ax, sizes) else None
        return P(None, batch_ax, seq_ax, None)

    if name in ("ssm", "conv") and len(shape) >= 3:    # mamba state
        batch_ax = data_ax if _div(shape[1], data_ax, sizes) else None
        spec = [None, batch_ax] + [None] * (len(shape) - 2)
        # d_inner rides the model axis when divisible (last dim for conv,
        # dim 2 for ssm)
        di_dim = 2 if name == "ssm" else len(shape) - 1
        if _div(shape[di_dim], model_ax, sizes):
            spec[di_dim] = model_ax
        return P(*spec)

    # unknown cache leaf: batch on data when it looks batched, else repl.
    if len(shape) >= 2 and _div(shape[1], data_ax, sizes):
        return P(None, data_ax, *([None] * (len(shape) - 2)))
    return P(*([None] * len(shape)))


def cache_shardings(cfg, cache, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, cache_pspec(cfg, p, l, mesh)), cache)


# --------------------------------------------------------------------------
# inputs / outputs
# --------------------------------------------------------------------------

def inputs_shardings(cfg, mesh, shape_spec):
    """NamedShardings for the input dict of this (cfg, shape) cell —
    mirrors launch.dryrun.input_specs."""
    B = shape_spec.global_batch
    tok = NamedSharding(mesh, batch_pspec(mesh, B))
    if cfg.embed_input == "tokens":
        inp = tok
    else:
        inp = NamedSharding(mesh, batch_pspec(mesh, B, (None, None)))
    if shape_spec.kind == "train":
        return {"inputs": inp, "labels": tok}
    if shape_spec.kind == "prefill":
        return {"inputs": inp}
    return {"tokens": tok,
            "pos": NamedSharding(mesh, batch_pspec(mesh, B, ()))}


def last_logits_sharding(cfg, mesh, batch: int):
    sizes = _axis_sizes(mesh)
    v_ax = "model" if ("model" in sizes
                       and cfg.vocab_size % sizes["model"] == 0) else None
    return NamedSharding(mesh, batch_pspec(mesh, batch, (v_ax,)))


# --------------------------------------------------------------------------
# symbolic specs (packed-artifact manifests)
# --------------------------------------------------------------------------
# A packed artifact records each leaf's *symbolic* PartitionSpec — axis
# names without sizes — so any later mesh can place the leaf directly
# (ckpt/packed.py). The symbolic mesh below has size-1 axes, which makes
# the divisibility guard in the rules above vacuous: the rule's full
# intent survives into the manifest, and `guard_pspec` re-applies the
# guard against the real mesh at load time.

SYMBOLIC_AXES = ("data", "model")


class _SymbolicMesh:
    def __init__(self, axes):
        self.axis_names = tuple(axes)
        self.devices = np.empty((1,) * len(self.axis_names))


def symbolic_mesh(axes=SYMBOLIC_AXES):
    """Shape-only stand-in whose every axis divides everything — rules
    evaluated against it return the unguarded symbolic spec."""
    return _SymbolicMesh(axes)


def pspec_to_json(spec) -> list:
    """PartitionSpec -> JSON-safe list (entries: None | str | [str])."""
    return [list(a) if isinstance(a, tuple) else a for a in tuple(spec)]


def pspec_from_json(entries) -> P:
    return P(*[tuple(a) if isinstance(a, list) else a for a in entries])


def drop_axes(spec, axes) -> P:
    """Remove the named mesh axes from a spec (replicating those dims):
    serving loads drop "data" by default — weights replicate over the
    data-parallel shards, FSDP-style gathering is a training concern."""
    axes = set(axes)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, tuple):
            left = tuple(a for a in entry if a not in axes)
            return left if len(left) > 1 else (left[0] if left else None)
        return None if entry in axes else entry
    return P(*[keep(a) for a in tuple(spec)])


def guard_pspec(shape, spec, mesh) -> P:
    """Re-apply the divisibility guard of a symbolic spec against a
    real mesh: an axis is dropped (dim replicated) when the mesh lacks
    it or the dim does not divide its size. Short specs are padded with
    None to the leaf's rank."""
    sizes = _axis_sizes(mesh)
    entries = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))

    def ok(dim, ax):
        if ax is None:
            return True
        axes = ax if isinstance(ax, tuple) else (ax,)
        if any(a not in sizes for a in axes):
            return False
        return _div(dim, ax, sizes)

    return P(*[a if ok(d, a) else None for d, a in zip(shape, entries)])
