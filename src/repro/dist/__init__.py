from repro.dist.context import constrain_batch, current_mesh, mesh_context
from repro.dist.sharding import (batch_pspec, cache_pspec, cache_shardings,
                                 inputs_shardings, last_logits_sharding,
                                 opt_state_shardings, param_pspec,
                                 params_shardings)

__all__ = [
    "constrain_batch", "current_mesh", "mesh_context",
    "batch_pspec", "cache_pspec", "cache_shardings", "inputs_shardings",
    "last_logits_sharding", "opt_state_shardings", "param_pspec",
    "params_shardings",
]
