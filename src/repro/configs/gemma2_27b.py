"""gemma2-27b [dense] — local/global alternating attention, logit softcaps.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000
[arXiv:2408.00118]. Super-block of 2: (local window 4096, global).
Sandwich (pre+post) norms, attn softcap 50, final logit softcap 30.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    pattern=(LayerSpec(kind="attn", mlp="dense", window=4096),
             LayerSpec(kind="attn", mlp="dense", window=None)),
    attn_softcap=50.0,
    final_softcap=30.0,
    post_block_norms=True,
    rope_theta=10000.0,
    tie_embeddings=True,
)
