"""falcon-mamba-7b [ssm] — attention-free Mamba-1.

64L d_model=4096 vocab=65024 ssm_state=16 [arXiv:2410.05355]. Pure mamba
blocks (no separate FFN; d_ff=0 in the pool spec).
"""
from repro.configs.base import (LayerSpec, MambaConfig, ModelConfig,
                                QuantConfig)

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,       # unused (attention-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=65024,
    pattern=(LayerSpec(kind="mamba", mlp="none"),),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    tie_embeddings=True,
    subquadratic=True,
    quant=QuantConfig(exclude=("x_proj", "dt_proj")),
)
