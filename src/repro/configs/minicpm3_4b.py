"""minicpm3-4b [dense] — Multi-head Latent Attention (MLA).

62L d_model=2560 40H d_ff=6400 vocab=73448 [hf:openbmb/MiniCPM3-4B].
MLA: q_lora=768, kv_lora=256, rope 32, nope 64, v 64. The KV cache stores
the compressed latent (kv_lora + rope dims) per position.
"""
from repro.configs.base import LayerSpec, MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=96,  # qk head dim = nope(64)+rope(32)
    d_ff=6400,
    vocab_size=73448,
    pattern=(LayerSpec(kind="attn", mlp="dense"),),
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    rope_theta=10000.0,
    tie_embeddings=True,
)
