"""Model families the GPTQT paper itself quantizes (OPT, Llama2, Bloom),
mapped onto this framework's composable stack, plus the *tiny* trained-
from-scratch LMs used by the in-repo perplexity reproduction (the offline
container has no HF checkpoints — see DESIGN.md §6.2).

The tiny models keep each family's distinguishing block structure
(OPT: MHA+ReLU-ish dense FFN; Llama2: GQA+SwiGLU; Bloom: MHA+GeLU dense)
at a width that trains to meaningful perplexity on CPU in minutes.
"""
from repro.configs.base import LayerSpec, MLAConfig, ModelConfig, MoEConfig

# Full-size reference points (config fidelity; exercised via dry-run only)
OPT_125M = ModelConfig(
    name="opt-125m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072, vocab_size=50272,
    pattern=(LayerSpec(kind="attn", mlp="dense"),), tie_embeddings=True,
)
LLAMA2_7B = ModelConfig(
    name="llama2-7b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=32, head_dim=128, d_ff=11008, vocab_size=32000,
    pattern=(LayerSpec(kind="attn", mlp="dense"),), tie_embeddings=False,
)
BLOOM_560M = ModelConfig(
    name="bloom-560m", family="dense", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=250880,
    pattern=(LayerSpec(kind="attn", mlp="dense"),), tie_embeddings=True,
)

# Tiny trained-from-scratch models for the perplexity reproduction.
TINY_LM = ModelConfig(
    name="tiny-lm", family="dense", n_layers=4, d_model=256,
    n_heads=4, n_kv_heads=4, head_dim=64, d_ff=1024, vocab_size=258,
    pattern=(LayerSpec(kind="attn", mlp="dense"),), tie_embeddings=True,
    rope_theta=10000.0,
)
TINY_LM_WIDE = TINY_LM.replace(name="tiny-lm-wide", d_model=384, n_heads=6,
                               n_kv_heads=3, d_ff=1536, n_layers=4)
TINY_LM_DEEP = TINY_LM.replace(name="tiny-lm-deep", n_layers=8)

# Tiny zoo members for end-to-end CLI smokes: byte-tokenizer vocab (258)
# versions of the MLA and MoE block structures, trainable on CPU in well
# under a minute so CI can do train -> quantize -> serve for real.
TINY_MLA = TINY_LM.replace(
    name="tiny-mla", n_layers=2, d_model=128, d_ff=512,
    head_dim=24,  # qk head dim = nope(16)+rope(8)
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                  qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
)
TINY_MOE = TINY_LM.replace(
    name="tiny-moe", family="moe", n_layers=2, d_model=128, d_ff=512,
    pattern=(LayerSpec(kind="attn", mlp="moe"),),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=256),
)
