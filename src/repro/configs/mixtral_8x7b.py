"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000 [arXiv:2401.04088].
SWA window 4096 on every layer -> rolling KV buffer, sub-quadratic.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    pattern=(LayerSpec(kind="attn", mlp="moe", window=4096),),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336, sharding="auto"),
    rope_theta=1e6,
    tie_embeddings=False,
    subquadratic=True,   # SWA: KV is a rolling window buffer
)
