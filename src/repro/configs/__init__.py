"""Config registry: get_config(name) and per-arch reduced smoke configs."""
from __future__ import annotations

import dataclasses

from repro.configs.base import (LayerSpec, MambaConfig, MLAConfig,
                                ModelConfig, MoEConfig, QuantConfig,
                                ShapeSpec, SHAPES, runnable_shapes)

from repro.configs.jamba_1_5_large_398b import CONFIG as _jamba
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.qwen3_moe_235b_a22b import CONFIG as _qwen3_moe
from repro.configs.qwen3_4b import CONFIG as _qwen3_4b
from repro.configs.minicpm3_4b import CONFIG as _minicpm3
from repro.configs.qwen3_0_6b import CONFIG as _qwen3_06b
from repro.configs.gemma2_27b import CONFIG as _gemma2
from repro.configs.falcon_mamba_7b import CONFIG as _falcon_mamba
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.chameleon_34b import CONFIG as _chameleon
from repro.configs import paper_models as _paper

# The 10 assigned pool architectures
ASSIGNED = {
    c.name: c for c in [
        _jamba, _mixtral, _qwen3_moe, _qwen3_4b, _minicpm3,
        _qwen3_06b, _gemma2, _falcon_mamba, _hubert, _chameleon,
    ]
}

EXTRA = {
    c.name: c for c in [
        _paper.OPT_125M, _paper.LLAMA2_7B, _paper.BLOOM_560M,
        _paper.TINY_LM, _paper.TINY_LM_WIDE, _paper.TINY_LM_DEEP,
        _paper.TINY_MLA, _paper.TINY_MOE,
    ]
}

REGISTRY = {**ASSIGNED, **EXTRA}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: small widths/depth/experts/vocab, same
    block pattern and feature flags, suitable for a CPU forward/train step.
    """
    cfg = get_config(name)
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=len(cfg.pattern),          # one super-block
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=97,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=64)
    if cfg.mamba is not None:
        kw["mamba"] = dataclasses.replace(cfg.mamba, d_state=8, chunk=16)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
        kw["head_dim"] = 24
    if cfg.pattern and cfg.pattern[0].window is not None:
        kw["pattern"] = tuple(
            dataclasses.replace(s, window=32 if s.window else None)
            for s in cfg.pattern)
    # keep MoE-on-odd / attn-position structure for multi-layer patterns
    return cfg.replace(**kw)


__all__ = [
    "ModelConfig", "MoEConfig", "MambaConfig", "MLAConfig", "LayerSpec",
    "QuantConfig", "ShapeSpec", "SHAPES", "runnable_shapes",
    "ASSIGNED", "EXTRA", "REGISTRY", "get_config", "smoke_config",
]
