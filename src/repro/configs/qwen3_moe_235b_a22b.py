"""qwen3-moe-235b-a22b [moe] — 128 experts top-8.

94L d_model=4096 64H (GQA kv=4) d_ff_expert=1536 vocab=151936
[hf:Qwen/Qwen3-30B-A3B family]. qk_norm, head_dim=128.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    pattern=(LayerSpec(kind="attn", mlp="moe"),),
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536, sharding="auto"),
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=False,
)
