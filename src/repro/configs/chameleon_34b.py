"""chameleon-34b [vlm] — early-fusion token-in/token-out backbone.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 (text + VQ image
tokens) [arXiv:2405.09818]. The VQ-VAE image tokenizer is a STUB per the
task spec; the backbone consumes a unified token stream. Chameleon uses
qk-norm for training stability — kept.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    pattern=(LayerSpec(kind="attn", mlp="dense"),),
    qk_norm=True,
    rope_theta=10000.0,
    tie_embeddings=False,
)
