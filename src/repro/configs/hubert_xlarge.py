"""hubert-xlarge [audio] — encoder-only transformer backbone.

48L d_model=1280 16H d_ff=5120 vocab=504 [arXiv:2106.07447]. The conv
waveform frontend is a STUB per the task spec: input_specs() provides
precomputed frame embeddings (B, T, d_model); the backbone + masked
prediction head over 504 cluster targets is what we build. Bidirectional
attention, no decode step.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    pattern=(LayerSpec(kind="attn", mlp="dense"),),
    causal=False,
    has_decode=False,
    embed_input="frames",
    tie_embeddings=False,
    rope_theta=10000.0,
)
