"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
[arXiv:2403.19887; hf]. Super-block of 8 layers: attention at index 4
(1 attn : 7 mamba), MoE on odd layers (every other layer), dense FFN on
even layers.
"""
from repro.configs.base import (LayerSpec, MambaConfig, ModelConfig,
                                MoEConfig, QuantConfig)


def _pattern():
    specs = []
    for i in range(8):
        kind = "attn" if i == 4 else "mamba"
        mlp = "moe" if i % 2 == 1 else "dense"
        specs.append(LayerSpec(kind=kind, mlp=mlp))
    return tuple(specs)


CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    pattern=_pattern(),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    rope_theta=10000.0,
    tie_embeddings=False,
    subquadratic=True,   # 1:7 mamba; attn decode is O(S) with sharded KV
    quant=QuantConfig(exclude=("x_proj", "dt_proj")),
)
