"""Model configuration dataclasses.

A ModelConfig fully determines a model: the block *pattern* (a repeating
super-block of layer specs, scanned `n_groups` times), attention flavour,
MoE / Mamba / MLA sub-configs, and quantization registry defaults.

Configs are pure data — importing this module never touches jax device
state.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    router_jitter: float = 0.0
    capacity_factor: float = 1.25       # train-time dispatch capacity
    inference_capacity_factor: float = 2.0  # prefill; decode is dropless
    # "ep" shards experts over the model axis, "tp" shards each expert's
    # hidden dim; "auto" picks ep when n_experts % model_axis == 0.
    sharding: str = "auto"


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0          # 0 -> ceil(d_model / 16)
    chunk: int = 256          # chunked-scan block length (training)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank if self.dt_rank > 0 else -(-d_model // 16)


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating super-block."""
    kind: str = "attn"          # "attn" | "mamba"
    mlp: str = "dense"          # "dense" | "moe" | "none"
    window: Optional[int] = None  # sliding-window size; None = global


@dataclass(frozen=True)
class QuantConfig:
    """GPTQT defaults for this model (overridable at call time)."""
    bits: int = 3                 # final binary-coding bits (k)
    intermediate_bits: int = 5    # step-1 linear bits (n)
    group_size: int = 0           # 0 = per-channel (one group along K)
    reexplore_range: int = 1      # Eq.7 range in bits (Tab. VI "range")
    reexplore_points: int = 33    # grid points for S-hat search
    exclude: Tuple[str, ...] = () # substrings of param paths to skip


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # repeating super-block; len(pattern) must divide n_layers
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    # attention details
    qk_norm: bool = False
    rope_theta: float = 10000.0
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    causal: bool = True           # False -> encoder-only (bidirectional)
    post_block_norms: bool = False  # gemma2 sandwich norms
    # sub-modules
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    mla: Optional[MLAConfig] = None
    # embedding / head
    tie_embeddings: bool = True
    embed_input: str = "tokens"   # "tokens" | "frames" (precomputed frontend)
    norm_eps: float = 1e-6
    # serving
    has_decode: bool = True       # encoder-only archs: False
    subquadratic: bool = False    # eligible for long_500k
    # numerics
    dtype: str = "bfloat16"
    # unroll the over-groups scan (used by dry-run cost probes: XLA cost
    # analysis counts while-loop bodies once, so probes compile 1- and
    # 2-group unrolled models and extrapolate base + n_groups * delta)
    scan_unroll: bool = False
    # quantization defaults
    quant: QuantConfig = field(default_factory=QuantConfig)
    # activation remat policy for training: "none"|"dots"|"full"
    remat: str = "full"

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim > 0 else self.d_model // self.n_heads

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern length {len(self.pattern)}")
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:
        assert self.mamba is not None
        return self.mamba.expand * self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count (analytic; used for roofline MODEL_FLOPS and memory
    # budgeting). Counts embedding once when tied.
    def param_counts(self) -> dict:
        d, hd = self.d_model, self.resolved_head_dim
        nh, nkv = self.n_heads, self.n_kv_heads
        counts = {"embed": self.vocab_size * d}
        if not self.tie_embeddings:
            counts["lm_head"] = self.vocab_size * d
        per_pattern_total = 0
        per_pattern_active = 0
        for spec in self.pattern:
            p = 0
            a = 0
            if spec.kind == "attn":
                if self.mla is not None:
                    m = self.mla
                    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
                    p += d * m.q_lora_rank + m.q_lora_rank * nh * qk_hd
                    p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    p += m.kv_lora_rank * nh * (m.qk_nope_head_dim + m.v_head_dim)
                    p += nh * m.v_head_dim * d
                else:
                    p += d * nh * hd + 2 * d * nkv * hd + nh * hd * d
                a += p
            elif spec.kind == "mamba":
                assert self.mamba is not None
                mc = self.mamba
                di = mc.expand * d
                dtr = mc.resolved_dt_rank(d)
                p += d * 2 * di                      # in_proj (x and z)
                p += mc.d_conv * di                  # conv
                p += di * (dtr + 2 * mc.d_state)     # x_proj
                p += dtr * di + di                   # dt_proj (+bias)
                p += di * mc.d_state + di            # A_log, D
                p += di * d                          # out_proj
                a += p
            if spec.mlp == "dense":
                w = 3 * d * self.d_ff
                p += w
                a += w
            elif spec.mlp == "moe":
                assert self.moe is not None
                w1 = 3 * d * self.moe.d_ff_expert
                p += self.moe.n_experts * w1 + d * self.moe.n_experts
                a += self.moe.top_k * w1 + d * self.moe.n_experts
            # norms
            p += 2 * d + (2 * d if self.post_block_norms else 0)
            a += 2 * d
            per_pattern_total += p
            per_pattern_active += a
        counts["blocks_total"] = per_pattern_total * self.n_groups
        counts["blocks_active"] = per_pattern_active * self.n_groups
        counts["total"] = counts["embed"] + counts.get("lm_head", 0) + counts["blocks_total"]
        counts["active"] = counts["embed"] + counts.get("lm_head", 0) + counts["blocks_active"]
        return counts


# ---------------------------------------------------------------------------
# Input shapes assigned to the LM pool (seq_len, global_batch, kind)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def runnable_shapes(cfg: ModelConfig):
    """Which of the 4 pool shapes apply to this arch (spec-mandated skips)."""
    out = []
    for s in SHAPES.values():
        if s.kind == "decode" and not cfg.has_decode:
            continue  # encoder-only: no decode step
        if s.name == "long_500k" and not cfg.subquadratic:
            continue  # needs sub-quadratic attention
        out.append(s)
    return out
