"""Synthetic PCFG corpora + byte tokenizer.

The offline container has no WikiText2/PTB, so the perplexity
reproduction uses two *different* synthetic English-like distributions
generated from probabilistic grammars ("wiki" and "ptb" analogues —
different vocabulary, clause structure and punctuation). A ~5M-param LM
trained on slices of these reaches non-trivial perplexity, and the paper's
claims are about *orderings between quantization methods* on such a
model, which transfer (DESIGN.md §6.2).
"""
from __future__ import annotations

import numpy as np

_GRAMMARS = {
    "wiki": {
        "det": ["the", "a", "this", "each", "another"],
        "adj": ["ancient", "large", "notable", "famous", "small", "early",
                "modern", "regional", "central", "former"],
        "noun": ["city", "river", "empire", "treaty", "archive", "museum",
                 "region", "dynasty", "railway", "harbour", "council",
                 "province", "cathedral", "festival", "network"],
        "verb": ["established", "described", "contained", "bordered",
                 "governed", "recorded", "restored", "connected",
                 "commissioned", "preserved"],
        "adv": ["formally", "later", "originally", "briefly", "partly"],
        "conj": ["and", "while", "although", "because"],
        "punct": [".", ".", ".", ";"],
    },
    "ptb": {
        "det": ["the", "its", "that", "some", "no"],
        "adj": ["quarterly", "corporate", "pretax", "volatile", "junk",
                "fiscal", "preferred", "composite", "industrial", "net"],
        "noun": ["profit", "market", "index", "bond", "share", "trader",
                 "merger", "rate", "dollar", "earnings", "portfolio",
                 "contract", "exchange", "analyst", "broker"],
        "verb": ["rose", "fell", "reported", "traded", "acquired",
                 "slipped", "gained", "projected", "offset", "climbed"],
        "adv": ["sharply", "modestly", "unexpectedly", "slightly", "again"],
        "conj": ["but", "and", "as", "though"],
        "punct": [".", ".", ",", "."],
    },
}


_CONS = list("bcdfghklmnprstvz")
_VOW = list("aeiou")


def _name(rng):
    """Random pronounceable proper noun — irreducible entropy so a tiny
    LM cannot memorize the corpus to ~1.0 ppl (quantization effects
    would otherwise be invisible)."""
    n = rng.integers(2, 4)
    return "".join(rng.choice(_CONS) + rng.choice(_VOW)
                   for _ in range(n)).capitalize()


def _sentence(rng, g):
    words = []

    def np_():
        if rng.random() < 0.25:          # proper noun / numeral slots
            return [_name(rng)] if rng.random() < 0.7 else \
                [str(rng.integers(1000, 2100))]
        w = [rng.choice(g["det"])]
        if rng.random() < 0.6:
            w.append(rng.choice(g["adj"]))
        w.append(rng.choice(g["noun"]))
        return w

    words += np_()
    if rng.random() < 0.35:
        words.append(rng.choice(g["adv"]))
    words.append(rng.choice(g["verb"]))
    words += np_()
    if rng.random() < 0.3:
        words.append(rng.choice(g["conj"]))
        words += np_()
        words.append(rng.choice(g["verb"]))
        words += np_()
    return " ".join(words) + rng.choice(g["punct"]) + " "


def generate_corpus(name: str = "wiki", n_chars: int = 400_000,
                    seed: int = 0) -> str:
    rng = np.random.default_rng(seed + (0 if name == "wiki" else 7919))
    g = _GRAMMARS[name]
    parts, total = [], 0
    while total < n_chars:
        s = _sentence(rng, g)
        parts.append(s)
        total += len(s)
    return "".join(parts)[:n_chars]


class ByteTokenizer:
    """Raw bytes + BOS/EOS. vocab_size 258 (matches tiny-lm configs)."""
    vocab_size = 258
    bos = 256
    eos = 257

    def encode(self, text: str) -> np.ndarray:
        return np.frombuffer(text.encode("utf-8"), np.uint8).astype(np.int32)

    def decode(self, ids) -> str:
        ids = [i for i in np.asarray(ids).tolist() if i < 256]
        return bytes(ids).decode("utf-8", errors="replace")


def token_stream(name: str = "wiki", n_chars: int = 400_000, seed: int = 0):
    return ByteTokenizer().encode(generate_corpus(name, n_chars, seed))


def calibration_slices(tokens: np.ndarray, n_slices: int, slice_len: int,
                       seed: int = 0) -> np.ndarray:
    """Paper setup: random fixed-length slices (128 x 2048 at full scale;
    scaled down for the tiny models)."""
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, len(tokens) - slice_len, n_slices)
    return np.stack([tokens[s:s + slice_len] for s in starts]).astype(np.int32)


def batches(tokens: np.ndarray, batch: int, seq: int, *, seed: int = 0,
            n_batches: int | None = None):
    """Next-token LM batches: inputs/labels shifted by one."""
    rng = np.random.default_rng(seed)
    i = 0
    while n_batches is None or i < n_batches:
        starts = rng.integers(0, len(tokens) - seq - 1, batch)
        x = np.stack([tokens[s:s + seq] for s in starts])
        y = np.stack([tokens[s + 1:s + seq + 1] for s in starts])
        yield {"inputs": x.astype(np.int32), "labels": y.astype(np.int32)}
        i += 1


def eval_batches(tokens: np.ndarray, batch: int, seq: int):
    """Deterministic non-overlapping windows for perplexity."""
    n = (len(tokens) - 1) // seq
    xs, ys = [], []
    for w in range(n):
        s = w * seq
        xs.append(tokens[s:s + seq])
        ys.append(tokens[s + 1:s + seq + 1])
        if len(xs) == batch:
            yield {"inputs": np.stack(xs).astype(np.int32),
                   "labels": np.stack(ys).astype(np.int32)}
            xs, ys = [], []
    if xs:
        yield {"inputs": np.stack(xs).astype(np.int32),
               "labels": np.stack(ys).astype(np.int32)}
