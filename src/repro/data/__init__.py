from repro.data.corpus import (ByteTokenizer, batches, calibration_slices,
                               eval_batches, generate_corpus, token_stream)

__all__ = ["ByteTokenizer", "generate_corpus", "token_stream",
           "calibration_slices", "batches", "eval_batches"]
