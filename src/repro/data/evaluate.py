"""Perplexity evaluation (the paper's metric for every results table)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import compile_cache


def perplexity(cfg, params, batch_iter, *, max_batches=None) -> float:
    """Token-level perplexity over deterministic eval windows.

    The jitted forward comes from the process-wide compile cache
    (serve/compile_cache.py, kind "eval_forward"): repeated perplexity
    calls on the same config — every method/bits sweep — reuse one
    compiled program instead of re-tracing per call."""
    fwd = compile_cache.get("eval_forward", cfg)
    total_nll, total_tok = 0.0, 0
    for bi, batch in enumerate(batch_iter):
        if max_batches is not None and bi >= max_batches:
            break
        logits = fwd(params, jnp.asarray(batch["inputs"]))
        logits = logits.astype(jnp.float32)
        labels = jnp.asarray(batch["labels"])
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        nll = lse - gold
        total_nll += float(jnp.sum(nll))
        total_tok += int(np.prod(labels.shape))
    return math.exp(total_nll / max(total_tok, 1))
