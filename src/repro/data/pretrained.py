"""Train-once cache of tiny LMs for the perplexity reproduction.

The paper quantizes pretrained OPT/Llama2/Bloom checkpoints; offline we
train small LMs on the synthetic corpora (DESIGN.md §6.2) and cache the
weights under artifacts/models/<name>/ so every benchmark and example
reuses the same checkpoint.
"""
from __future__ import annotations

from pathlib import Path

import jax
import numpy as np

from repro.configs import get_config
from repro.data.corpus import batches, token_stream
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

ROOT = Path(__file__).resolve().parents[3]
MODELS_DIR = ROOT / "artifacts" / "models"


def get_trained_lm(name: str = "tiny-lm", *, corpus: str = "wiki",
                   steps: int = 300, batch: int = 12, seq: int = 192,
                   lr: float = 1.5e-3, force: bool = False):
    """Returns (cfg, params). Trains + caches on first call."""
    cfg = get_config(name).replace(dtype="float32", remat="none")
    ckpt_dir = MODELS_DIR / f"{name}-{corpus}-s{steps}"
    toks = token_stream(corpus, 400_000)
    data = batches(toks, batch, seq, seed=0)
    tr = Trainer(
        cfg,
        TrainerConfig(steps=steps, ckpt_every=max(steps // 3, 50),
                      ckpt_dir=str(ckpt_dir), log_every=50, warmup=30,
                      opt=AdamWConfig(lr=lr, weight_decay=0.01,
                                      master_fp32=False)),
        data, dtype="float32")
    if not force and tr.ckpt.latest_step() == steps:
        tr.try_resume()
        return cfg, tr.params
    print(f"[pretrained] training {name} on {corpus} for {steps} steps ...")
    tr.run()
    return cfg, tr.params


def corpus_tokens(corpus: str = "wiki", n_chars: int = 400_000,
                  split: str = "eval"):
    """Train/eval split of a corpus token stream (eval = disjoint tail)."""
    toks = token_stream(corpus, n_chars + 60_000, seed=0)
    return toks[:n_chars] if split == "train" else toks[n_chars:]
