"""Shared neural-net building blocks (pure functions over param pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., S, H, D) or (..., S, D); positions
    broadcastable to the S axis (e.g. (S,) or (B, S))."""
    d = x.shape[-1]
    assert d % 2 == 0, "rope dim must be even"
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    pos = positions.astype(jnp.float32)
    angles = pos[..., None] * freqs          # (S, d/2) or (B, S, d/2)
    if x.ndim == 4:                          # (B, S, H, D): add head axis
        angles = angles[..., None, :]
        if angles.ndim == 3:                 # positions were (S,)
            angles = angles[None]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# Activation tap: when set (calibration), every linear() records its input
# keyed by the weight's object id. Only used on unjitted, unrolled forwards.
_TAP = None


class tap_activations:
    """with tap_activations() as rec: ... ; rec[id(w)] -> list of inputs."""

    def __enter__(self):
        global _TAP
        self.rec = {}
        _TAP = self.rec
        return self.rec

    def __exit__(self, *exc):
        global _TAP
        _TAP = None
        return False


def linear(x, w):
    """Apply a (possibly quantized) weight: x (..., K) @ w (K, N)."""
    if _TAP is not None and isinstance(w, jax.Array):
        _TAP.setdefault(id(w), []).append(x.reshape(-1, x.shape[-1]))
    if hasattr(w, "quantized_matmul"):           # QuantizedTensor
        return w.quantized_matmul(x)
    return jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))


def swiglu(p, x):
    """Gated MLP: p = {wg:(D,F), wu:(D,F), wd:(F,D)}."""
    h = jax.nn.silu(linear(x, p["wg"])) * linear(x, p["wu"])
    return linear(h, p["wd"])


def init_linear(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def init_swiglu(key, d, f, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wg": init_linear(k1, d, f, dtype),
            "wu": init_linear(k2, d, f, dtype),
            "wd": init_linear(k3, f, d, dtype)}


def cross_entropy(logits, labels, *, final_cap=None, mask=None, z_loss=0.0):
    """Mean token cross-entropy (fp32 accumulation). labels < 0 ignored.

    Sharding-friendly form: the gold logit is a masked *reduction over
    the vocab axis* (partial sums + tiny all-reduce when vocab is
    model-sharded) rather than a take_along_axis gather, which makes
    GSPMD all-gather the full logits; see EXPERIMENTS.md §Perf H4.
    """
    logits = softcap(logits, final_cap)
    lmax = jax.lax.stop_gradient(
        jnp.max(logits, axis=-1, keepdims=True))
    shifted = (logits - lmax).astype(jnp.float32)
    sumexp = jnp.sum(jnp.exp(shifted), axis=-1)
    V = logits.shape[-1]
    is_gold = jnp.arange(V) == jnp.maximum(labels, 0)[..., None]
    gold_shifted = jnp.sum(jnp.where(is_gold, shifted, 0.0), axis=-1)
    nll = jnp.log(sumexp) - gold_shifted
    if z_loss:
        lse = jnp.log(sumexp) + lmax[..., 0].astype(jnp.float32)
        nll = nll + z_loss * lse ** 2
    valid = (labels >= 0)
    if mask is not None:
        valid = valid & mask
    valid = valid.astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)
