"""Mamba-1 (S6) block: causal depthwise conv + selective SSM scan.

The training/prefill path uses a *chunked* selective scan: the sequence is
split into chunks of `cfg.mamba.chunk`; within a chunk the first-order
recurrence is computed with an associative scan, across chunks a lax.scan
carries the (d_inner, d_state) boundary state. Live memory is
O(B * chunk * d_inner * d_state) instead of O(B * L * d_inner * d_state),
which is what makes the 500k-token cells fit. A = -exp(A_log) is diagonal
and negative, so per-step decays exp(dt*A) are in (0, 1] and cumulative
products are numerically stable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear, linear

# see attention.FORCE_UNROLL — set by dry-run cost probes
FORCE_UNROLL = False


def init_mamba(cfg, key, dtype):
    mc = cfg.mamba
    d = cfg.d_model
    di = mc.expand * d
    dtr = mc.resolved_dt_rank(d)
    ks = jax.random.split(key, 5)
    p = {
        "in_proj": init_linear(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (mc.d_conv, di), jnp.float32)
                   * mc.d_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": init_linear(ks[2], di, dtr + 2 * mc.d_state, dtype),
        "dt_w": init_linear(ks[3], dtr, di, dtype),
        # bias init so softplus(dt_bias) ~ [1e-3, 1e-1] (mamba default)
        "dt_b": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))
        ).astype(jnp.float32),
        "A_log": jnp.log(jnp.tile(
            jnp.arange(1, mc.d_state + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": init_linear(jax.random.fold_in(key, 9), di, d, dtype),
    }
    return p


def _causal_conv(x, w, b):
    """Depthwise causal conv via shift-add. x: (B, L, di); w: (K, di)."""
    K = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(K):
        sh = K - 1 - i
        xi = x if sh == 0 else jnp.pad(x, ((0, 0), (sh, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


def _ssm_features(cfg, p, xc):
    """xc: (B, L, di) post-conv+silu -> dt (B,L,di), Bm/Cm (B,L,ds)."""
    mc = cfg.mamba
    dtr = mc.resolved_dt_rank(cfg.d_model)
    feats = linear(xc, p["x_proj"])
    dt_r, Bm, Cm = jnp.split(feats, [dtr, dtr + mc.d_state], axis=-1)
    dt = linear(dt_r, p["dt_w"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_b"])
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def _scan_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def selective_scan(x, dt, A, Bm, Cm, h0, chunk):
    """Chunked selective scan.
    x, dt: (B, L, di) fp32; A: (di, ds); Bm, Cm: (B, L, ds); h0: (B, di, ds).
    Returns y (B, L, di), hN (B, di, ds)."""
    Bsz, L, di = x.shape
    ds = A.shape[1]
    nc = -(-L // chunk)
    pad = nc * chunk - L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    # chunk-major xs for lax.scan
    def cm(t):  # (B, L', ...) -> (nc, B, chunk, ...)
        return t.reshape(Bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    xs = (cm(x), cm(dt), cm(Bm), cm(Cm))

    def chunk_step(h, inp):
        xc, dtc, Bc, Cc = inp                       # (B, ck, ...)
        la = dtc[..., None] * A                     # (B, ck, di, ds), <= 0
        a = jnp.exp(la)
        b = (dtc * xc)[..., None] * Bc[:, :, None, :]
        aprod, bsum = jax.lax.associative_scan(_scan_combine, (a, b), axis=1)
        hseq = aprod * h[:, None] + bsum            # (B, ck, di, ds)
        y = jnp.einsum("bkds,bks->bkd", hseq, Cc)
        return hseq[:, -1], y

    hN, ys = jax.lax.scan(chunk_step, h0, xs, unroll=FORCE_UNROLL)
    y = ys.swapaxes(0, 1).reshape(Bsz, nc * chunk, di)[:, :L]
    return y, hN


def mamba_forward(cfg, p, x, *, state=None, return_state=False):
    """Full-sequence mamba block core. x: (B, L, D)."""
    mc = cfg.mamba
    di = cfg.d_inner
    xz = linear(x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))
    dt, Bm, Cm = _ssm_features(cfg, p, xc)
    A = -jnp.exp(p["A_log"])
    h0 = jnp.zeros((x.shape[0], di, mc.d_state), jnp.float32) if state is None else state
    y, hN = selective_scan(xc.astype(jnp.float32), dt, A, Bm, Cm, h0, mc.chunk)
    y = y + p["D"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = linear(y, p["out_proj"])
    if return_state:
        # conv tail: last (d_conv-1) post-in_proj inputs for decode continuity
        tail = xi[:, -(mc.d_conv - 1):]
        return out, {"ssm": hN, "conv": tail}
    return out


def init_mamba_cache(cfg, batch, dtype):
    mc = cfg.mamba
    return {
        "ssm": jnp.zeros((batch, cfg.d_inner, mc.d_state), jnp.float32),
        "conv": jnp.zeros((batch, mc.d_conv - 1, cfg.d_inner), dtype),
    }


def mamba_decode(cfg, p, x, cache):
    """Single-token step. x: (B, 1, D); cache {ssm, conv}."""
    mc = cfg.mamba
    xz = linear(x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)                 # (B, 1, di)
    win = jnp.concatenate([cache["conv"], xi], axis=1)  # (B, d_conv, di)
    xc = jnp.einsum("bkd,kd->bd", win, p["conv_w"].astype(x.dtype))
    xc = jax.nn.silu(xc + p["conv_b"].astype(x.dtype))[:, None]  # (B,1,di)
    dt, Bm, Cm = _ssm_features(cfg, p, xc)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[:, 0, :, None] * A)                # (B, di, ds)
    b = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0, None, :]
    h = a * cache["ssm"] + b
    y = jnp.einsum("bds,bs->bd", h, Cm[:, 0])
    y = y + p["D"] * xc[:, 0].astype(jnp.float32)
    y = y.astype(x.dtype)[:, None] * jax.nn.silu(z)
    out = linear(y, p["out_proj"])
    return out, {"ssm": h, "conv": win[:, 1:]}
