"""Attention: GQA with qk-norm / sliding window / softcap, memory-bounded
chunked ("flash-style") full-sequence path, and single-token decode with a
KV cache (rolling buffer for sliding-window layers).

Shapes: activations (B, S, D); q/k/v (B, S, H, hd); caches (B, H, S, hd).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear, linear, rmsnorm, rope, softcap

NEG_INF = -1e30
# full-sequence attention switches to the chunked path above this length
CHUNKED_THRESHOLD = 2048
KV_CHUNK = 1024
# dry-run cost probes set this: XLA cost analysis counts while-loop bodies
# once, so probes unroll the kv-chunk scan (with coarser chunks)
FORCE_UNROLL = False


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------

def init_attn(cfg, key, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, dtype),
        "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["qn"] = jnp.zeros((hd,), dtype)
        p["kn"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(cfg, p, x):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = linear(x, p["wq"])
    k = linear(x, p["wk"])
    v = linear(x, p["wv"])
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["qn"], cfg.norm_eps)
        k = rmsnorm(k, p["kn"], cfg.norm_eps)
    return q, k, v


def _group_q(q, n_kv):
    """(B, S, H, d) -> (B, S, Hkv, rep, d). GQA is computed in grouped
    form — K/V are never materialized at H heads (a jnp.repeat here
    costs rep x cache bytes AND forces SPMD reshards; see EXPERIMENTS.md
    §Perf H1)."""
    B, S, H, d = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, d)


# --------------------------------------------------------------------------
# full-sequence attention (training / prefill)
# --------------------------------------------------------------------------

def _mask_bias(sq, skv, *, causal, window, q_offset=0, dtype=jnp.float32):
    """(sq, skv) additive bias. q position i attends kv position j iff
    (not causal or j <= i+q_offset) and (window is None or i+q_offset-j < window)."""
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kj <= qi
    if window is not None:
        ok &= (qi - kj) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(dtype)


def _attend_dense(q, k, v, *, causal, window, cap, scale):
    """Direct S x S attention (small sequences / oracle). Grouped GQA;
    v head dim may differ from q/k head dim (MLA)."""
    B, Sq, H, hd = q.shape
    dv = v.shape[-1]
    qg = _group_q(q, k.shape[2])                         # (B,Sq,Hkv,r,d)
    logits = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k).astype(jnp.float32) * scale
    logits = softcap(logits, cap)
    logits = logits + _mask_bias(Sq, k.shape[1], causal=causal, window=window)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", w, v)
    return out.reshape(B, Sq, H, dv)


def _attend_chunked(q, k, v, *, causal, window, cap, scale):
    """Flash-style streaming over KV chunks: O(S * KV_CHUNK) live memory
    instead of O(S^2). Running (max, denom, acc) carried through a scan."""
    B, Sq, H, hd = q.shape
    Skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    rep = H // hkv
    qg = _group_q(q, hkv)                                # (B,Sq,Hkv,r,d)
    nc = -(-Skv // KV_CHUNK)
    pad = nc * KV_CHUNK - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nc, KV_CHUNK, hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, KV_CHUNK, hkv, dv).transpose(1, 0, 2, 3, 4)

    qi = jnp.arange(Sq)[:, None]

    def body(carry, inp):
        m, l, acc = carry
        ci, kb, vb = inp
        logits = jnp.einsum("bqhrd,bkhd->bhrqk", qg,
                            kb).astype(jnp.float32) * scale
        logits = softcap(logits, cap)
        kj = ci * KV_CHUNK + jnp.arange(KV_CHUNK)[None, :]
        ok = kj < Skv
        if causal:
            ok = ok & (kj <= qi)
        if window is not None:
            ok = ok & ((qi - kj) < window)
        logits = jnp.where(ok[None, None, None], logits, NEG_INF)
        bm = jnp.maximum(m, jnp.max(logits, axis=-1))
        r = jnp.exp(m - bm)
        p = jnp.exp(logits - bm[..., None])
        l = l * r + jnp.sum(p, axis=-1)
        acc = acc * r[..., None] + jnp.einsum(
            "bhrqk,bkhd->bhrqd", p.astype(q.dtype), vb).astype(jnp.float32)
        return (bm, l, acc), None

    m0 = jnp.full((B, hkv, rep, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, hkv, rep, Sq), jnp.float32)
    a0 = jnp.zeros((B, hkv, rep, Sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(nc), kc, vc), unroll=FORCE_UNROLL)
    out = acc / jnp.maximum(l, 1e-30)[..., None]         # (B,hkv,r,Sq,dv)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, dv).astype(q.dtype)


def attn_forward(cfg, spec, p, x, positions):
    """Full-sequence attention layer core (no residual/norm)."""
    q, k, v = _project_qkv(cfg, p, x)
    hd = cfg.resolved_head_dim
    if cfg.mla is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    scale = hd ** -0.5
    S = x.shape[1]
    fn = _attend_chunked if S > CHUNKED_THRESHOLD else _attend_dense
    out = fn(q, k, v, causal=cfg.causal, window=spec.window,
             cap=cfg.attn_softcap, scale=scale)
    out = out.reshape(*x.shape[:2], cfg.n_heads * hd)
    return linear(out, p["wo"])


# --------------------------------------------------------------------------
# decode (single new token, KV cache)
# --------------------------------------------------------------------------

def init_kv_cache(cfg, spec, batch, max_len, dtype):
    hd = cfg.resolved_head_dim
    S = max_len if spec.window is None else min(max_len, spec.window)
    shape = (batch, cfg.n_kv_heads, S, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_paged_kv(cfg, n_pages, page_size, dtype, kv_bits=0,
                  kv_group_size=0):
    """Global page pool for one attention layer: every sequence's K/V
    pages live here; ownership is the block table's concern
    (serve/kv_cache.py). Page 0 is the allocator's null page.

    With `kv_bits > 0` pages store binary-coded K/V (quant/kv.py): sign
    bitplanes packed along head_dim plus per-(token, head, group) alpha/
    beta scales, quantized on-write by the decode/extend/scatter paths
    and expanded inside the attention kernels. The presence of the
    "k_codes" leaf is what selects the quantized path downstream."""
    hd = cfg.resolved_head_dim
    if not kv_bits:
        shape = (n_pages, page_size, cfg.n_kv_heads, hd)
        return {"k_pages": jnp.zeros(shape, dtype),
                "v_pages": jnp.zeros(shape, dtype)}
    from repro.quant.kv import kv_layout
    G, hdw = kv_layout(hd, kv_bits, kv_group_size)
    Hkv = cfg.n_kv_heads
    lead = (n_pages, page_size, Hkv)
    pool = {}
    for side in ("k", "v"):
        pool[f"{side}_codes"] = jnp.zeros(lead + (kv_bits, hdw),
                                          jnp.uint32)
        pool[f"{side}_alphas"] = jnp.zeros(lead + (G, kv_bits),
                                           jnp.float32)
        pool[f"{side}_betas"] = jnp.zeros(lead + (G,), jnp.float32)
    return pool


def paged_kv_page_bytes(cfg, page_size, dtype, kv_bits=0,
                        kv_group_size=0) -> int:
    """Device bytes one page id costs across the whole model: every
    attention layer (x the n_groups scan stack) holds a K and a V page
    of `page_size` tokens per KV head. The single owner of the
    bytes-per-page arithmetic (EngineStats, the capacity bench and the
    serve CLI all read it)."""
    from repro.quant.kv import kv_bytes_per_token_head
    itemsize = jnp.dtype(dtype or cfg.dtype).itemsize
    n_attn = sum(1 for s in cfg.pattern if s.kind == "attn") * cfg.n_groups
    if cfg.mla is not None:
        # latent pages: one compressed c_kv + one shared rotary key per
        # token — no per-head factor, no separate V page
        m = cfg.mla
        per_tok = (m.kv_lora_rank + m.qk_rope_head_dim) * itemsize
        return page_size * per_tok * n_attn
    per_vec = kv_bytes_per_token_head(cfg.resolved_head_dim, kv_bits,
                                      kv_group_size, itemsize)
    return 2 * page_size * cfg.n_kv_heads * per_vec * n_attn


# None = auto (Pallas kernel iff backend is TPU; the pure-jnp gather
# otherwise). Tests may force the kernel in interpret mode.
FORCE_PAGED_KERNEL: bool | None = None


def _use_paged_kernel() -> bool:
    if FORCE_PAGED_KERNEL is not None:
        return FORCE_PAGED_KERNEL
    return jax.default_backend() == "tpu"


def paged_kv_bits(cache) -> int:
    """kv_bits of a paged layer cache (0 = unquantized). The layout is
    self-describing: bits/groups are leaf shapes, so jit wrappers need
    no extra static arguments to dispatch."""
    return cache["k_codes"].shape[-2] if "k_codes" in cache else 0


def _quant_scatter(cache, side, new, pid, off, mask=None):
    """Quantize-on-write: binary-code `new` K or V vectors (..., hd) and
    scatter codes+scales into the pool at (pid, off). With `mask`
    (matching new's leading dims), False rows re-write the null page's
    slot-0 content instead (the extend path's padding trick)."""
    from repro.quant.kv import kv_quantize
    bits = cache[f"{side}_codes"].shape[-2]
    G = cache[f"{side}_betas"].shape[-1]
    gs = new.shape[-1] // G
    codes, alphas, betas = kv_quantize(new, bits, gs)
    out = dict(cache)
    for name, val in ((f"{side}_codes", codes),
                      (f"{side}_alphas", alphas),
                      (f"{side}_betas", betas)):
        pool = cache[name]
        if mask is not None:
            m = mask.reshape(mask.shape + (1,) * (val.ndim - mask.ndim))
            null = pool[0, 0].reshape(
                (1,) * mask.ndim + pool.shape[2:])
            val = jnp.where(m, val, null)
        out[name] = pool.at[pid, off].set(val.astype(pool.dtype))
    return out


def _gather_dequant(cache, side, block_tables, hd):
    """Gather + expand a sequence's binary-coded pages:
    -> (B, T*page, Hkv, hd) fp32 (the extend path's dense view)."""
    from repro.quant.kv import kv_dequantize
    bt = block_tables
    B, T = bt.shape
    page = cache[f"{side}_codes"].shape[1]
    Hkv = cache[f"{side}_codes"].shape[2]
    x = kv_dequantize(cache[f"{side}_codes"][bt],
                      cache[f"{side}_alphas"][bt],
                      cache[f"{side}_betas"][bt])
    return x.reshape(B, T * page, Hkv, hd)


def attn_decode_paged(cfg, spec, p, x, cache, block_tables, pos):
    """Single-token decode against a paged KV pool.

    x: (B, 1, D); cache: {"k_pages","v_pages"} (P, page, Hkv, hd) — or
    the binary-coded layout {"k_codes","k_alphas","k_betas","v_..."}
    (init_paged_kv(kv_bits=...)), where the new token's K/V is quantized
    before the scatter and the kernel dequantizes inside its accumulator
    loop; block_tables: (B, T) int32 page ids; pos: (B,) absolute
    positions. Writes the new K/V into page block_tables[b, pos//page]
    at offset pos%page, then attends over the sequence's gathered pages.
    Window layers mask by absolute position (no rolling buffer — pages
    beyond the window stay allocated; the scheduler may reclaim them
    later). Returns (y, cache)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k, v = _project_qkv(cfg, p, x)          # (B,1,H,hd)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)

    quant = paged_kv_bits(cache) > 0
    page = (cache["k_codes"] if quant else cache["k_pages"]).shape[1]
    b_idx = jnp.arange(B)
    pid = block_tables[b_idx, pos // page]
    off = pos % page
    if quant:
        cache = _quant_scatter(cache, "k", k[:, 0], pid, off)
        cache = _quant_scatter(cache, "v", v[:, 0], pid, off)
    else:
        kp, vp = cache["k_pages"], cache["v_pages"]
        kp = kp.at[pid, off].set(k[:, 0].astype(kp.dtype))
        vp = vp.at[pid, off].set(v[:, 0].astype(vp.dtype))
        cache = {"k_pages": kp, "v_pages": vp}

    qg = q[:, 0].reshape(B, cfg.n_kv_heads,
                         cfg.n_heads // cfg.n_kv_heads, hd)
    ctx = pos + 1
    interpret = jax.default_backend() != "tpu"
    if quant:
        if _use_paged_kernel():
            from repro.kernels.paged_attention import paged_attention_quant
            out = paged_attention_quant(
                qg, cache["k_codes"], cache["k_alphas"], cache["k_betas"],
                cache["v_codes"], cache["v_alphas"], cache["v_betas"],
                block_tables, ctx, window=spec.window,
                cap=cfg.attn_softcap, interpret=interpret)
        else:
            from repro.kernels.ref import paged_attention_quant_ref
            out = paged_attention_quant_ref(
                qg, cache["k_codes"], cache["k_alphas"], cache["k_betas"],
                cache["v_codes"], cache["v_alphas"], cache["v_betas"],
                block_tables, ctx, window=spec.window,
                cap=cfg.attn_softcap)
    elif _use_paged_kernel():
        from repro.kernels.paged_attention import paged_attention
        out = paged_attention(qg, cache["k_pages"], cache["v_pages"],
                              block_tables, ctx,
                              window=spec.window, cap=cfg.attn_softcap,
                              interpret=interpret)
    else:
        # gather path: the kernel's oracle doubles as the non-TPU
        # execution path (same fp32 masked softmax the dense attn_decode
        # computes, so paged and dense engines agree token-for-token on
        # the fp32 CPU tests)
        from repro.kernels.ref import paged_attention_ref
        out = paged_attention_ref(qg, cache["k_pages"], cache["v_pages"],
                                  block_tables, ctx,
                                  window=spec.window, cap=cfg.attn_softcap)
    out = out.reshape(B, 1, cfg.n_heads * hd)
    y = linear(out, p["wo"])
    return y, cache


def attn_extend_paged(cfg, spec, p, h, cache, block_tables, start_pos,
                      chunk_mask):
    """Chunked-prefill step: C prompt tokens at absolute positions
    start_pos + [0..C) attend causally over everything already in the
    sequence's pages plus themselves. h: (B, C, D); chunk_mask: (B, C)
    bool — False marks padding tokens whose K/V must not land in pages.
    Returns (y, cache)."""
    B, C, _ = h.shape
    hd = cfg.resolved_head_dim
    q, k, v = _project_qkv(cfg, p, h)          # (B,C,H,hd)
    positions = start_pos[:, None] + jnp.arange(C)[None, :]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    quant = paged_kv_bits(cache) > 0
    page = (cache["k_codes"] if quant else cache["k_pages"]).shape[1]
    pid = jnp.take_along_axis(block_tables, positions // page, axis=1)
    off = positions % page
    # masked scatter: padding tokens write to the null page (id 0) slot 0,
    # re-writing its current content (a no-op by construction)
    pid = jnp.where(chunk_mask, pid, 0)
    off = jnp.where(chunk_mask, off, 0)
    T = block_tables.shape[1]
    if quant:
        cache = _quant_scatter(cache, "k", k, pid, off, mask=chunk_mask)
        cache = _quant_scatter(cache, "v", v, pid, off, mask=chunk_mask)
        ck = _gather_dequant(cache, "k", block_tables, hd)
        cv = _gather_dequant(cache, "v", block_tables, hd)
    else:
        kp, vp = cache["k_pages"], cache["v_pages"]
        m4 = chunk_mask[:, :, None, None]
        kw = jnp.where(m4, k.astype(kp.dtype), kp[0, 0][None, None])
        vw = jnp.where(m4, v.astype(vp.dtype), vp[0, 0][None, None])
        kp = kp.at[pid, off].set(kw)
        vp = vp.at[pid, off].set(vw)
        cache = {"k_pages": kp, "v_pages": vp}
        ck = kp[block_tables].reshape(B, T * page, cfg.n_kv_heads, hd)
        cv = vp[block_tables].reshape(B, T * page, cfg.n_kv_heads, hd)
    ck = ck.transpose(0, 2, 1, 3)
    cv = cv.transpose(0, 2, 1, 3)
    qg = q.reshape(B, C, cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, hd)
    logits = jnp.einsum("bqhrd,bhkd->bhrqk", qg,
                        ck.astype(q.dtype)).astype(jnp.float32) * hd ** -0.5
    logits = softcap(logits, cfg.attn_softcap)
    j = jnp.arange(T * page)[None, None, :]
    qi = positions[:, :, None]                  # (B, C, 1)
    ok = j <= qi
    if spec.window is not None:
        ok &= (qi - j) < spec.window
    logits = jnp.where(ok[:, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrqk,bhkd->bqhrd", w, cv.astype(q.dtype))
    out = out.reshape(B, C, cfg.n_heads * hd)
    y = linear(out, p["wo"])
    return y, cache


def attn_decode(cfg, spec, p, x, cache, pos):
    """x: (B, 1, D); pos: (B,) int32 absolute positions. Returns (y, cache).
    Sliding-window layers use a rolling buffer of size `window` indexed by
    pos % window."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k, v = _project_qkv(cfg, p, x)          # (B,1,H,hd)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)

    ck, cv = cache["k"], cache["v"]
    S = ck.shape[2]
    slot = pos if spec.window is None else pos % spec.window
    b_idx = jnp.arange(B)
    # k[:, 0] is (B, Hkv, hd); write each sample's new key at its slot.
    ck = ck.at[b_idx, :, slot].set(k[:, 0].astype(ck.dtype))
    cv = cv.at[b_idx, :, slot].set(v[:, 0].astype(cv.dtype))

    # grouped GQA against the cache (B, Hkv, S, hd): no head repeat.
    qg = q[:, 0].reshape(B, cfg.n_kv_heads,
                         cfg.n_heads // cfg.n_kv_heads, hd)
    logits = jnp.einsum("bhrd,bhkd->bhrk", qg,
                        ck.astype(q.dtype)).astype(jnp.float32) * hd ** -0.5
    logits = softcap(logits, cfg.attn_softcap)
    # valid slots: for global layers j <= pos; for window layers the buffer
    # holds the last `window` positions -> slot j valid iff its absolute
    # position <= pos, i.e. filled (pos - window < abs_j <= pos).
    j = jnp.arange(S)[None, :]
    if spec.window is None:
        ok = j <= pos[:, None]
    else:
        ok = j < jnp.minimum(pos[:, None] + 1, spec.window)
    logits = jnp.where(ok[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrk,bhkd->bhrd", w, cv.astype(q.dtype))
    out = out.reshape(B, 1, cfg.n_heads * hd)
    y = linear(out, p["wo"])
    return y, {"k": ck, "v": cv}
