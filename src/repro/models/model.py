"""Model assembly: composable decoder/encoder stack driven by ModelConfig.

Layers are grouped into the config's repeating super-block ("pattern");
parameters for each pattern position are stacked along a leading
`n_groups` axis and the stack is traversed with `lax.scan`, keeping HLO
size (and compile time) independent of depth. Activation rematerialization
wraps the scan body (policy from cfg.remat).

Public entry points:
  init_params(cfg, key)            -> param pytree
  forward(cfg, params, inputs)     -> (logits, aux_loss)        [train]
  prefill(cfg, params, tokens, max_len) -> (last_logits, cache) [serve]
  init_cache(cfg, batch, max_len)  -> cache pytree
  decode_step(cfg, params, cache, tokens, pos) -> (logits, cache)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import mla as mla_mod
from repro.dist.context import constrain_batch
from repro.models.layers import (cross_entropy, init_linear, init_swiglu,
                                 linear, rmsnorm, softcap, swiglu)
from repro.models.moe import init_moe, moe_forward


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_layer(cfg, spec, key, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {"ln": jnp.zeros((d,), dtype)}
    if spec.kind == "attn":
        if cfg.mla is not None:
            p["attn"] = mla_mod.init_mla(cfg, ks[0], dtype)
        else:
            p["attn"] = attn.init_attn(cfg, ks[0], dtype)
    else:
        p["mamba"] = mam.init_mamba(cfg, ks[0], dtype)
    if cfg.post_block_norms:
        p["post_ln"] = jnp.zeros((d,), dtype)
    if spec.mlp != "none":
        p["ln2"] = jnp.zeros((d,), dtype)
        if spec.mlp == "dense":
            p["mlp"] = init_swiglu(ks[1], d, cfg.d_ff, dtype)
        else:
            p["moe"] = init_moe(cfg, ks[1], dtype)
        if cfg.post_block_norms:
            p["post_ln2"] = jnp.zeros((d,), dtype)
    return p


def init_params(cfg, key, dtype=None):
    dtype = jnp.dtype(dtype or cfg.dtype)
    d = cfg.d_model
    k_embed, k_head, k_blocks = jax.random.split(key, 3)
    params = {"final_ln": jnp.zeros((d,), dtype)}
    if cfg.embed_input == "tokens":
        params["embed"] = (jax.random.normal(
            k_embed, (cfg.vocab_size, d), jnp.float32) * 0.02).astype(dtype)
    else:  # precomputed frame/patch embeddings -> learned input projection
        params["embed"] = init_linear(k_embed, d, d, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(k_head, d, cfg.vocab_size, dtype)

    blocks = {}
    gkeys = jax.random.split(k_blocks, cfg.n_groups)
    for i, spec in enumerate(cfg.pattern):
        init_one = functools.partial(_init_layer, cfg, spec, dtype=dtype)
        blocks[f"L{i}"] = jax.vmap(init_one)(
            jax.vmap(lambda k: jax.random.fold_in(k, i))(gkeys))
    params["blocks"] = blocks
    return params


# --------------------------------------------------------------------------
# embedding / unembedding
# --------------------------------------------------------------------------

def embed_inputs(cfg, params, inputs):
    if cfg.embed_input == "tokens":
        return jnp.take(params["embed"], inputs, axis=0)
    return linear(inputs, params["embed"])


def unembed(cfg, params, x):
    """Logits in the activation dtype — the fp32 upcast happens inside
    the loss reductions (avoids materializing fp32 (B,S,V))."""
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = linear(x, params["lm_head"])
    if logits.ndim == 3:       # anchor: batch on data, vocab on model
        logits = constrain_batch(logits, None, "model")
    return softcap(logits, cfg.final_softcap)


# --------------------------------------------------------------------------
# layer application
# --------------------------------------------------------------------------

def _apply_layer(cfg, spec, lp, x, positions, aux, *, collect_cache=False,
                 max_len=0):
    cache_out = None
    h = rmsnorm(x, lp["ln"], cfg.norm_eps)
    if spec.kind == "attn":
        if cfg.mla is not None:
            if collect_cache:
                y, cache_out = _mla_prefill(cfg, spec, lp["attn"], h,
                                            positions, max_len)
            else:
                y = mla_mod.mla_forward(cfg, spec, lp["attn"], h, positions)
        else:
            if collect_cache:
                y, cache_out = _attn_prefill(cfg, spec, lp["attn"], h,
                                             positions, max_len)
            else:
                y = attn.attn_forward(cfg, spec, lp["attn"], h, positions)
    else:
        if collect_cache:
            y, cache_out = mam.mamba_forward(cfg, lp["mamba"], h,
                                             return_state=True)
        else:
            y = mam.mamba_forward(cfg, lp["mamba"], h)
    if cfg.post_block_norms:
        y = rmsnorm(y, lp["post_ln"], cfg.norm_eps)
    x = x + y
    if spec.mlp != "none":
        h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if spec.mlp == "dense":
            y = swiglu(lp["mlp"], h)
        else:
            # prefill (collect_cache) uses the larger inference capacity
            cf = (cfg.moe.inference_capacity_factor if collect_cache
                  else cfg.moe.capacity_factor)
            y, a = moe_forward(cfg, lp["moe"], h, capacity_factor=cf)
            aux = aux + a
        if cfg.post_block_norms:
            y = rmsnorm(y, lp["post_ln2"], cfg.norm_eps)
        x = x + y
    return x, aux, cache_out


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": save only inputs


# --------------------------------------------------------------------------
# training / scoring forward
# --------------------------------------------------------------------------

def forward(cfg, params, inputs, *, remat=None):
    """inputs: tokens (B, S) int32 or frames (B, S, D). -> (logits, aux)."""
    x = embed_inputs(cfg, params, inputs)
    S = x.shape[1]
    positions = jnp.arange(S)

    def body(carry, gp):
        x, aux = carry
        x = constrain_batch(x, None, None)   # anchor: batch on data axes
        for i, spec in enumerate(cfg.pattern):
            x, aux, _ = _apply_layer(cfg, spec, gp[f"L{i}"], x, positions, aux)
        x = constrain_batch(x, None, None)
        return (x, aux), None

    body = _remat(body, remat if remat is not None else cfg.remat)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"], unroll=cfg.scan_unroll)
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    return unembed(cfg, params, x), aux


def loss_fn(cfg, params, batch, *, aux_coef=0.01, remat=None):
    logits, aux = forward(cfg, params, batch["inputs"], remat=remat)
    loss = cross_entropy(logits, batch["labels"])
    return loss + aux_coef * aux, {"xent": loss, "aux": aux}


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------

def _attn_prefill(cfg, spec, p, h, positions, max_len):
    y = attn.attn_forward(cfg, spec, p, h, positions)
    q, k, v = attn._project_qkv(cfg, p, h)
    k = attn.rope(k, positions, cfg.rope_theta)
    S = h.shape[1]
    ck = k.transpose(0, 2, 1, 3)   # (B, Hkv, S, hd)
    cv = v.transpose(0, 2, 1, 3)
    if spec.window is None:
        pad = max_len - S
        ck = jnp.pad(ck, ((0, 0), (0, 0), (0, pad), (0, 0)))
        cv = jnp.pad(cv, ((0, 0), (0, 0), (0, pad), (0, 0)))
    else:
        w = min(spec.window, max_len)
        lo = max(0, S - w)
        slots = jnp.arange(lo, S) % w
        buf_k = jnp.zeros((ck.shape[0], ck.shape[1], w, ck.shape[3]), ck.dtype)
        buf_v = jnp.zeros_like(buf_k)
        ck = buf_k.at[:, :, slots].set(ck[:, :, lo:])
        cv = buf_v.at[:, :, slots].set(cv[:, :, lo:])
    return y, {"k": ck, "v": cv}


def _mla_prefill(cfg, spec, p, h, positions, max_len):
    y = mla_mod.mla_forward(cfg, spec, p, h, positions)
    c_kv, k_pe = mla_mod._latent(cfg, p, h, positions)
    pad = max_len - h.shape[1]
    c_kv = jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0)))
    k_pe = jnp.pad(k_pe, ((0, 0), (0, pad), (0, 0)))
    return y, {"c_kv": c_kv, "k_pe": k_pe}


def init_cache(cfg, batch, max_len, dtype=None):
    """Cache pytree mirroring params['blocks'] layout: leaf leading dim is
    n_groups (scanned together with the block stack)."""
    dtype = jnp.dtype(dtype or cfg.dtype)
    cache = {}
    for i, spec in enumerate(cfg.pattern):
        if spec.kind == "attn":
            if cfg.mla is not None:
                one = mla_mod.init_mla_cache(cfg, batch, max_len, dtype)
            else:
                one = attn.init_kv_cache(cfg, spec, batch, max_len, dtype)
        else:
            one = mam.init_mamba_cache(cfg, batch, dtype)
        cache[f"L{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_groups,) + a.shape), one)
    return cache


def init_paged_cache(cfg, n_pages, page_size, max_seqs, dtype=None,
                     kv_bits=0, kv_group_size=0):
    """Paged cache pytree: attention layers get a global K/V page pool
    (n_pages, page_size, Hkv, hd) shared by all sequences; mamba layers
    keep per-slot constant-size state (max_seqs rows — recurrent state
    doesn't page). Same (n_groups,)-stacked layout as init_cache.

    `kv_bits > 0` stores pages binary-coded (quant/kv.py): packed sign
    bitplanes + per-(token, head, K-group) alpha/beta scale leaves
    instead of raw K/V — 4-8x fewer pool bytes per page at serving
    accuracy (see docs/SERVING.md §Quantized KV cache)."""
    dtype = jnp.dtype(dtype or cfg.dtype)
    if cfg.mla is not None and kv_bits:
        raise NotImplementedError(
            "binary-coded pages code per-head K/V vectors; the MLA latent "
            "cache is already compressed and serves with kv_bits=0")
    cache = {}
    for i, spec in enumerate(cfg.pattern):
        if spec.kind == "attn":
            if cfg.mla is not None:
                one = mla_mod.init_mla_paged(cfg, n_pages, page_size, dtype)
            else:
                one = attn.init_paged_kv(cfg, n_pages, page_size, dtype,
                                         kv_bits=kv_bits,
                                         kv_group_size=kv_group_size)
        else:
            one = mam.init_mamba_cache(cfg, max_seqs, dtype)
        cache[f"L{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_groups,) + a.shape), one)
    return cache


def is_page_leaf(leaf, n_pages) -> bool:
    """A paged-pool leaf: page axis at dim 1 after the group stack. Both
    the raw layout (ndim 5) and the quantized code/alpha/beta leaves
    (ndim 5-6) match; mamba per-slot state (G, max_seqs, ...) does not
    (its dim 1 is max_seqs, never n_pages in practice)."""
    return leaf.ndim >= 5 and leaf.shape[1] == n_pages


def copy_pages(cache, src, dst, n_pages):
    """Copy-on-write fork: duplicate page src[i] -> dst[i] in every
    attention layer's K/V pool (paged-cache layout, page axis at dim 1
    after the group stack; mamba per-slot state is left alone). On a
    quantized pool the codes AND the alpha/beta scale leaves all copy —
    a fork that missed the scales would decode the old page's
    magnitudes under the new page's signs. src/dst are (n,) int32 page
    ids; (0, 0) pairs are harmless null-page no-ops, used by the engine
    to pad the copy list to a fixed trace shape."""
    def move(leaf):
        if is_page_leaf(leaf, n_pages):
            return leaf.at[:, dst].set(leaf[:, src])
        return leaf
    return jax.tree.map(move, cache)


def _last_positions(x, last_pos):
    """x (B, S, D) -> (B, 1, D) at per-row index `last_pos` ((B,) int32),
    or the final position when last_pos is None (exact prompts)."""
    if last_pos is None:
        return x[:, -1:]
    idx = jnp.broadcast_to(last_pos[:, None, None],
                           (x.shape[0], 1, x.shape[2]))
    return jnp.take_along_axis(x, idx, axis=1)


def prefill(cfg, params, tokens, max_len, *, remat="none", last_pos=None):
    """Run the prompt, return (last-position logits, filled cache).
    `last_pos` ((B,) int32) selects the logits row for bucket-padded
    prompts (the engine pads prompt length to a power of two so the jit
    cache stays small; padding K/V slots are overwritten by later decode
    steps before they become visible to the causal mask)."""
    x = embed_inputs(cfg, params, tokens)
    S = x.shape[1]
    positions = jnp.arange(S)

    def body(carry, gp):
        x, aux = carry
        caches = {}
        for i, spec in enumerate(cfg.pattern):
            x, aux, caches[f"L{i}"] = _apply_layer(
                cfg, spec, gp[f"L{i}"], x, positions, aux,
                collect_cache=True, max_len=max_len)
        return (x, aux), caches

    body = _remat(body, remat)
    (x, _), cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                 params["blocks"], unroll=cfg.scan_unroll)
    x = rmsnorm(_last_positions(x, last_pos), params["final_ln"],
                cfg.norm_eps)
    return unembed(cfg, params, x)[:, 0], cache


def _decode_scan(cfg, params, cache, x, attn_step):
    """Shared single-step decode machinery: scan the group stack, with
    the attention flavour injected (dense cache / paged pool / MLA)."""
    def body(x, inp):
        gp, gc = inp
        new_gc = {}
        for i, spec in enumerate(cfg.pattern):
            lp = gp[f"L{i}"]
            h = rmsnorm(x, lp["ln"], cfg.norm_eps)
            if spec.kind == "attn":
                y, new_gc[f"L{i}"] = attn_step(spec, lp["attn"], h,
                                               gc[f"L{i}"])
            else:
                y, new_gc[f"L{i}"] = mam.mamba_decode(
                    cfg, lp["mamba"], h, gc[f"L{i}"])
            if cfg.post_block_norms:
                y = rmsnorm(y, lp["post_ln"], cfg.norm_eps)
            x = x + y
            if spec.mlp != "none":
                h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
                if spec.mlp == "dense":
                    y = swiglu(lp["mlp"], h)
                else:
                    # decode dispatch: 4x capacity slack instead of fully
                    # dropless (C=T) — C=T makes EVERY expert compute B
                    # tokens, inflating decode weight traffic E/k-fold
                    # (EXPERIMENTS.md §Perf iteration 2). At tiny T the
                    # min() keeps it exactly dropless (tests unaffected).
                    y, _ = moe_forward(cfg, lp["moe"], h, capacity_factor=4.0)
                if cfg.post_block_norms:
                    y = rmsnorm(y, lp["post_ln2"], cfg.norm_eps)
                x = x + y
        return x, new_gc

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache),
                                unroll=cfg.scan_unroll)
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    return unembed(cfg, params, x), new_cache


def decode_step(cfg, params, cache, tokens, pos):
    """One decode step. tokens: (B, 1) int32; pos: (B,) absolute positions.
    Returns (logits (B, V), new cache). Cache buffers are functionally
    updated; callers should donate them."""
    x = embed_inputs(cfg, params, tokens)
    if cfg.mla is not None:
        step = lambda spec, p, h, c: mla_mod.mla_decode(cfg, spec, p, h,
                                                        c, pos)
    else:
        step = lambda spec, p, h, c: attn.attn_decode(cfg, spec, p, h,
                                                      c, pos)
    logits, new_cache = _decode_scan(cfg, params, cache, x, step)
    return logits[:, 0], new_cache


def decode_step_paged(cfg, params, cache, tokens, pos, block_tables):
    """One decode step against a paged cache (init_paged_cache layout).
    block_tables: (B, T) int32 page ids, row b = sequence in slot b.
    Same contract as decode_step otherwise."""
    x = embed_inputs(cfg, params, tokens)
    if cfg.mla is not None:
        step = lambda spec, p, h, c: mla_mod.mla_decode_paged(
            cfg, spec, p, h, c, block_tables, pos)
    else:
        step = lambda spec, p, h, c: attn.attn_decode_paged(
            cfg, spec, p, h, c, block_tables, pos)
    logits, new_cache = _decode_scan(cfg, params, cache, x, step)
    return logits[:, 0], new_cache


def draft_propose_paged(cfg, params, cache, cur, base_pos, block_tables,
                        k_eff, null_row, k):
    """k greedy draft decode steps fused into ONE pass: the token
    feedback loop (argmax of step j feeds step j+1) runs on device, so
    a speculative tick costs one dispatch for all k proposals instead
    of k host round-trips with a logits transfer each. `k` is static
    (the unrolled step count); `k_eff` (B,) int32 clamps per-row depth —
    step j routes rows with k_eff <= j to `null_row`'s reserve page and
    position 0, exactly like any inactive decode row (their K/V writes
    land in the null page; their argmax feedback is computed but the
    caller ignores tokens past k_eff). Rows with k_eff == 0 never write
    anywhere real. Returns (draft tokens (B, k) int32, cache).

    Quantized draft weights are dequantized ONCE, before the step loop:
    at decode batch sizes the binary-code expansion (O(K*N*bits)) dwarfs
    the matmul it feeds (O(B*K*N)), and the k unrolled steps all consume
    the same weights — paying the expansion per step made propose cost
    ~k full draft decodes. The dense weights are trace-local workspace
    (alive only inside this dispatch), so the draft's zero-resident-HBM
    property is untouched: what persists is still just codes + re-fit
    scales."""
    is_qt = lambda l: hasattr(l, "dequant")
    params = jax.tree_util.tree_map(
        lambda l: l.dequant() if is_qt(l) else l, params, is_leaf=is_qt)
    toks = []
    for j in range(k):
        live_j = k_eff > j
        bt = jnp.where(live_j[:, None], block_tables, null_row[:, None])
        pos_j = jnp.where(live_j, base_pos + j, 0)
        logits, cache = decode_step_paged(cfg, params, cache,
                                          cur[:, None], pos_j, bt)
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(cur)
    return jnp.stack(toks, axis=1), cache


def _extend_scan(cfg, params, cache, tokens, start_pos, block_tables,
                 n_valid):
    """Shared multi-token paged pass: run C tokens (tokens (B, C) int32,
    padded; n_valid (B,) counts the real ones) at absolute positions
    start_pos + [0..C), writing their K/V into the sequences' pages and
    attending over pages + chunk causally. Returns logits at EVERY
    chunk position ((B, C, V), cache). Attention and MLA patterns only
    (recurrent mamba state needs sequential threading)."""
    if any(spec.kind != "attn" for spec in cfg.pattern):
        raise NotImplementedError(
            "multi-token paged passes require an attention-only pattern")
    C = tokens.shape[1]
    chunk_mask = jnp.arange(C)[None, :] < n_valid[:, None]
    x = embed_inputs(cfg, params, tokens)
    if cfg.mla is not None:
        step = lambda spec, p, h, c: mla_mod.mla_extend_paged(
            cfg, spec, p, h, c, block_tables, start_pos, chunk_mask)
    else:
        step = lambda spec, p, h, c: attn.attn_extend_paged(
            cfg, spec, p, h, c, block_tables, start_pos, chunk_mask)
    return _decode_scan(cfg, params, cache, x, step)


def extend_paged(cfg, params, cache, tokens, start_pos, block_tables,
                 n_valid):
    """Chunked prefill: _extend_scan reduced to the logits of the last
    valid chunk position ((B, V), cache) — all a prefill needs to seed
    its first decode token."""
    B, C = tokens.shape
    logits, new_cache = _extend_scan(cfg, params, cache, tokens,
                                     start_pos, block_tables, n_valid)
    idx = jnp.maximum(n_valid - 1, 0)[:, None, None]
    last = jnp.take_along_axis(
        logits, jnp.broadcast_to(idx, (B, 1, logits.shape[-1])), axis=1)
    return last[:, 0], new_cache


def verify_paged(cfg, params, cache, tokens, start_pos, block_tables,
                 n_valid):
    """Speculative verify: score C = k+1 positions in ONE batched pass
    and keep the logits at every position ((B, C, V), cache) — position
    j's row decides the fate of draft token j+1 (greedy acceptance:
    accept while draft token == argmax of the previous row). The pass
    also writes the TARGET's K/V for all C positions, overwriting
    whatever the draft speculatively wrote there — which is what makes
    greedy speculative decode token-identical to target-only decode
    regardless of the draft (serve/engine.py holds the accept/rollback
    logic)."""
    return _extend_scan(cfg, params, cache, tokens, start_pos,
                        block_tables, n_valid)


def scatter_prefill_cache(cfg, paged_cache, row_cache, slot, page_ids,
                          n_valid):
    """Merge one sequence's dense prefill cache (prefill() on a single
    padded row: attn leaves (G, 1, Hkv, S_pad, hd)) into the paged cache.
    page_ids: (S_pad // page_size,) int32 pages owned by the sequence;
    n_valid: true prompt length (padding K/V is masked out — pages only
    ever hold live tokens). Mamba state rows land at `slot`. On a
    binary-coded pool the dense prefill K/V is quantized page-by-page
    here (quantize-on-write), so pages never hold raw values."""
    out = {}
    for i, spec in enumerate(cfg.pattern):
        key = f"L{i}"
        pooled, row = paged_cache[key], row_cache[key]
        if spec.kind != "attn":
            out[key] = jax.tree.map(
                lambda pool, one: pool.at[:, slot].set(one[:, 0]),
                pooled, row)
            continue
        if "ckv_pages" in pooled:
            page = pooled["ckv_pages"].shape[2]
            npg = page_ids.shape[0]

            def put_latent(pool, one):
                # one (G, 1, S_pad, r) -> (G, npg, page, 1, r)
                G, _, S_pad, r = one.shape
                rows = one[:, 0]
                pad = npg * page - S_pad
                if pad:
                    rows = jnp.pad(rows, ((0, 0), (0, pad), (0, 0)))
                rows = rows.reshape(G, npg, page, 1, r)
                keep = (jnp.arange(npg * page) < n_valid).reshape(npg, page)
                cur = pool[:, page_ids]
                return pool.at[:, page_ids].set(
                    jnp.where(keep[None, :, :, None, None],
                              rows.astype(pool.dtype), cur))

            out[key] = {
                "ckv_pages": put_latent(pooled["ckv_pages"], row["c_kv"]),
                "kpe_pages": put_latent(pooled["kpe_pages"], row["k_pe"])}
            continue
        quant = "k_codes" in pooled
        page = (pooled["k_codes"] if quant else pooled["k_pages"]).shape[2]
        npg = page_ids.shape[0]

        def paged_rows(one):
            # one (G, 1, Hkv, S_pad, hd) -> (G, npg, page, Hkv, hd)
            G, _, Hkv, S_pad, hd = one.shape
            r = one[:, 0].transpose(0, 2, 1, 3)            # (G,S_pad,Hkv,hd)
            pad = npg * page - S_pad
            if pad:
                r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return r.reshape(G, npg, page, Hkv, hd)

        if quant:
            from repro.quant.kv import kv_quantize

            bits = pooled["k_codes"].shape[-2]
            Gk = pooled["k_betas"].shape[-1]

            def put_q(side, one):
                hd = one.shape[-1]
                r = paged_rows(one)
                vals = kv_quantize(r, bits, hd // Gk)
                keep = (jnp.arange(npg * page) < n_valid).reshape(npg, page)
                leaves = {}
                for suffix, val in zip(("codes", "alphas", "betas"), vals):
                    pool = pooled[f"{side}_{suffix}"]
                    km = keep.reshape((1, npg, page) + (1,) * (val.ndim - 3))
                    cur = pool[:, page_ids]
                    leaves[f"{side}_{suffix}"] = pool.at[:, page_ids].set(
                        jnp.where(km, val.astype(pool.dtype), cur))
                return leaves

            out[key] = {**put_q("k", row["k"]), **put_q("v", row["v"])}
            continue

        def put(pool, one):
            r = paged_rows(one)
            keep = (jnp.arange(npg * page) < n_valid).reshape(npg, page)
            cur = pool[:, page_ids]
            return pool.at[:, page_ids].set(
                jnp.where(keep[None, :, :, None, None], r, cur))

        out[key] = {"k_pages": put(pooled["k_pages"], row["k"]),
                    "v_pages": put(pooled["v_pages"], row["v"])}
    return out
