"""Top-k routed Mixture-of-Experts with capacity-bounded sparse dispatch.

Dispatch is gather-based (sort tokens by expert, equal per-expert capacity
slots, scatter-add combine) rather than the GShard one-hot-einsum form:
the one-hot dispatch einsum costs O(T * E * C * D) MAC — orders of
magnitude above the expert FLOPs at pool scale — while the sort/gather
form is O(Tk log Tk) index work. Expert weights are (E, D, F): the E axis
shards over `model` (EP) when divisible, else F shards (TP-in-expert).

Tokens beyond an expert's capacity are dropped (standard GShard-style
training behaviour; capacity_factor config).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear


def init_moe(cfg, key, dtype):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 4)
    scale = d ** -0.5

    def ew(k, a, b):
        return (jax.random.normal(k, (e, a, b), jnp.float32) * scale).astype(dtype)

    return {
        "router": init_linear(ks[0], d, e, jnp.float32),  # router in fp32
        "wg": ew(ks[1], d, f),
        "wu": ew(ks[2], d, f),
        "wd": ew(ks[3], f, d) * (f ** -0.5) / scale,
    }


def moe_forward(cfg, p, x, *, capacity_factor=None, dropless=False):
    """x: (B, S, D) -> (out, aux_loss). Capacity C = ceil(T*k/E * cf);
    dropless=True sets C = T (exact; used for decode where T is tiny)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    if dropless:
        C = T
    else:
        cf = capacity_factor or m.capacity_factor
        C = min(T, max(1, int(-(-T * K // E) * cf)))

    xf = x.reshape(T, D)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, K)                    # (T, K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(
        jnp.ones((T * K,), jnp.float32)) / (T * K)
    aux = E * jnp.sum(me * ce)

    # ---- sparse dispatch ----
    e_flat = topi.reshape(T * K)
    w_flat = topv.reshape(T * K)
    order = jnp.argsort(e_flat, stable=True)
    se = e_flat[order]
    pos_in_e = jnp.arange(T * K) - jnp.searchsorted(se, se, side="left")
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)        # E*C = drop bin
    tok = order // K

    slot_tok = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(tok)
    slot_w = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(w_flat[order])

    xpad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)], axis=0)
    xe = xpad[slot_tok[: E * C]].reshape(E, C, D)

    # ---- expert computation (SwiGLU) ----
    def emul(v, name):
        """v (E, C, k) @ expert stack (E, k, n) -> (E, C, n).
        QuantizedTensor stacks dispatch through ops.bcq_apply: the
        batched-expert Pallas kernel on TPU (one launch covers the whole
        stack, dequant fused) and the vmapped dequant oracle elsewhere."""
        w = p[name]
        if hasattr(w, "quantized_matmul"):
            return w.quantized_matmul(v)
        return jnp.einsum("eck,ekn->ecn", v, w.astype(v.dtype))

    from repro.models import layers as _L
    if _L._TAP is not None:   # calibration: per-expert inputs
        _L._TAP.setdefault(id(p["wg"]), []).append(xe)
        _L._TAP.setdefault(id(p["wu"]), []).append(xe)
    h = jax.nn.silu(emul(xe, "wg"))
    h = h * emul(xe, "wu")
    if _L._TAP is not None:
        _L._TAP.setdefault(id(p["wd"]), []).append(h)
    ye = emul(h, "wd")

    # ---- combine ----
    contrib = ye.reshape(E * C, D) * slot_w[: E * C, None].astype(ye.dtype)
    out = jnp.zeros((T + 1, D), ye.dtype).at[slot_tok[: E * C]].add(contrib)[:T]
    return out.reshape(B, S, D), aux
