from repro.models.model import (decode_step, forward, init_cache,
                                init_params, loss_fn, prefill)

__all__ = ["init_params", "forward", "loss_fn", "prefill", "init_cache",
           "decode_step"]
