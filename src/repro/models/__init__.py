from repro.models.model import (copy_pages, decode_step, decode_step_paged,
                                draft_propose_paged, extend_paged,
                                verify_paged, forward, init_cache,
                                init_paged_cache, init_params, loss_fn,
                                prefill, scatter_prefill_cache)

__all__ = ["init_params", "forward", "loss_fn", "prefill", "init_cache",
           "decode_step", "decode_step_paged", "draft_propose_paged",
           "extend_paged", "verify_paged", "init_paged_cache",
           "scatter_prefill_cache", "copy_pages"]
