"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

KV is compressed to a per-position latent c_kv (kv_lora_rank) plus a
shared rotary key k_pe (qk_rope_head_dim); the decode cache stores only
(latent, k_pe) — a large KV-memory reduction that compounds with GPTQT
weight quantization in the decode roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear, linear, rmsnorm, rope, softcap

NEG_INF = -1e30


def init_mla(cfg, key, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": init_linear(ks[0], d, m.q_lora_rank, dtype),
        "q_a_norm": jnp.zeros((m.q_lora_rank,), dtype),
        "wq_b": init_linear(ks[1], m.q_lora_rank, H * qk_hd, dtype),
        "wkv_a": init_linear(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_a_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "wkv_b": init_linear(ks[3], m.kv_lora_rank,
                             H * (m.qk_nope_head_dim + m.v_head_dim), dtype),
        "wo": init_linear(ks[4], H * m.v_head_dim, d, dtype),
    }


def _queries(cfg, p, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    qa = rmsnorm(linear(x, p["wq_a"]), p["q_a_norm"], cfg.norm_eps)
    q = linear(qa, p["wq_b"])
    q = q.reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_pe = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_pe = rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def _latent(cfg, p, x, positions):
    m = cfg.mla
    kv = linear(x, p["wkv_a"])
    c_kv, k_pe = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, p["kv_a_norm"], cfg.norm_eps)
    k_pe = rope(k_pe, positions, cfg.rope_theta)   # (B, S, rope_hd), shared
    return c_kv, k_pe


def _expand_kv(cfg, p, c_kv):
    m = cfg.mla
    B, S, _ = c_kv.shape
    H = cfg.n_heads
    kvb = linear(c_kv, p["wkv_b"])
    kvb = kvb.reshape(B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kvb, [m.qk_nope_head_dim], axis=-1)
    return k_nope, v


def mla_forward(cfg, spec, p, x, positions):
    """Full-sequence MLA. For long sequences the score computation is
    routed through the shared chunked flash path using the concatenation
    identity [q_nope||q_pe]·[k_nope||k_pe] = q_nope·k_nope + q_pe·k_pe
    (k_pe broadcast across heads), so no S x S tensor is materialized."""
    from repro.models import attention as attn_mod

    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_pe = _queries(cfg, p, x, positions)
    c_kv, k_pe = _latent(cfg, p, x, positions)
    k_nope, v = _expand_kv(cfg, p, c_kv)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_cat = jnp.concatenate([q_nope, q_pe], axis=-1)     # (B,S,H,dn+dr)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    fn = (attn_mod._attend_chunked if S > attn_mod.CHUNKED_THRESHOLD
          else attn_mod._attend_dense)
    out = fn(q_cat, k_cat, v, causal=cfg.causal, window=spec.window,
             cap=cfg.attn_softcap, scale=scale)          # (B,S,H,dv)
    out = out.reshape(B, S, H * m.v_head_dim)
    return linear(out, p["wo"])


def init_mla_cache(cfg, batch, max_len, dtype):
    m = cfg.mla
    return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "k_pe": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype)}


def init_mla_paged(cfg, n_pages, page_size, dtype):
    """Global latent page pool for one MLA layer. Pages hold the
    compressed cache — one kv_lora_rank latent plus one shared rotary
    key per token, NOT per-head K/V — so a page costs
    page_size * (kv_lora_rank + qk_rope_head_dim) elements instead of
    2 * page_size * Hkv * hd. The singleton dim-2 axis keeps the leaves
    shaped like attention pools ((pages, page, heads, vec)) so
    is_page_leaf / copy_pages / compact treat them identically."""
    m = cfg.mla
    return {"ckv_pages": jnp.zeros((n_pages, page_size, 1,
                                    m.kv_lora_rank), dtype),
            "kpe_pages": jnp.zeros((n_pages, page_size, 1,
                                    m.qk_rope_head_dim), dtype)}


def _paged_latent_views(cache, block_tables):
    """Gather a sequence's latent pages into dense (B, T*page, ·) views."""
    B, T = block_tables.shape
    page = cache["ckv_pages"].shape[1]
    c_kv = cache["ckv_pages"][block_tables].reshape(B, T * page, -1)
    k_pe = cache["kpe_pages"][block_tables].reshape(B, T * page, -1)
    return c_kv, k_pe


def _mla_attend(cfg, p, q_nope, q_pe, c_kv, k_pe, ok):
    """Masked-softmax MLA attention over a dense latent view: absorb the
    up-projection (expand latents to K/V), score nope+rope parts, mask
    with `ok` (broadcastable to (B, 1, Sq, Skv)). Returns (B, Sq, D)."""
    m = cfg.mla
    B, Sq = q_nope.shape[:2]
    k_nope, v = _expand_kv(cfg, p, c_kv.astype(q_nope.dtype))
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    logits = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
              + jnp.einsum("bqhd,bkd->bhqk", q_pe,
                           k_pe.astype(q_nope.dtype)))
    logits = logits.astype(jnp.float32) * scale
    logits = softcap(logits, cfg.attn_softcap)
    logits = jnp.where(ok, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q_nope.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    out = out.reshape(B, Sq, cfg.n_heads * m.v_head_dim)
    return linear(out, p["wo"])


def mla_decode_paged(cfg, spec, p, x, cache, block_tables, pos):
    """Single-token MLA decode against a latent page pool. Writes the
    new (c_kv, k_pe) into page block_tables[b, pos//page] at offset
    pos%page, then attends over the gathered latent pages with the
    up-projection absorbed the way attn_decode_paged expands raw pages.
    Same block-table/COW/null-page contract as attn_decode_paged."""
    B = x.shape[0]
    q_nope, q_pe = _queries(cfg, p, x, pos[:, None])     # (B,1,H,·)
    c_new, kpe_new = _latent(cfg, p, x, pos[:, None])    # (B,1,·)
    page = cache["ckv_pages"].shape[1]
    b_idx = jnp.arange(B)
    pid = block_tables[b_idx, pos // page]
    off = pos % page
    ckv = cache["ckv_pages"].at[pid, off, 0].set(
        c_new[:, 0].astype(cache["ckv_pages"].dtype))
    kpe = cache["kpe_pages"].at[pid, off, 0].set(
        kpe_new[:, 0].astype(cache["kpe_pages"].dtype))
    cache = {"ckv_pages": ckv, "kpe_pages": kpe}
    c_kv, k_pe = _paged_latent_views(cache, block_tables)
    S = c_kv.shape[1]
    ok = (jnp.arange(S)[None, :] <= pos[:, None])[:, None, None, :]
    y = _mla_attend(cfg, p, q_nope, q_pe, c_kv, k_pe, ok)
    return y, cache


def mla_extend_paged(cfg, spec, p, h, cache, block_tables, start_pos,
                     chunk_mask):
    """Chunked-prefill / verify step for MLA: C tokens at absolute
    positions start_pos + [0..C) write their latents into the
    sequence's pages (padding rows rewrite the null page's slot 0) and
    attend causally over pages + chunk. Mirrors attn_extend_paged."""
    B, C, _ = h.shape
    positions = start_pos[:, None] + jnp.arange(C)[None, :]
    q_nope, q_pe = _queries(cfg, p, h, positions)        # (B,C,H,·)
    c_new, kpe_new = _latent(cfg, p, h, positions)       # (B,C,·)
    page = cache["ckv_pages"].shape[1]
    pid = jnp.take_along_axis(block_tables, positions // page, axis=1)
    off = positions % page
    pid = jnp.where(chunk_mask, pid, 0)
    off = jnp.where(chunk_mask, off, 0)
    ckv, kpe = cache["ckv_pages"], cache["kpe_pages"]
    m3 = chunk_mask[:, :, None]
    cw = jnp.where(m3, c_new.astype(ckv.dtype), ckv[0, 0, 0][None, None])
    kw = jnp.where(m3, kpe_new.astype(kpe.dtype), kpe[0, 0, 0][None, None])
    cache = {"ckv_pages": ckv.at[pid, off, 0].set(cw),
             "kpe_pages": kpe.at[pid, off, 0].set(kw)}
    c_kv, k_pe = _paged_latent_views(cache, block_tables)
    S = c_kv.shape[1]
    ok = (jnp.arange(S)[None, :] <= positions[:, :, None])[:, None]
    y = _mla_attend(cfg, p, q_nope, q_pe, c_kv, k_pe, ok)
    return y, cache


def mla_decode(cfg, spec, p, x, cache, pos):
    m = cfg.mla
    B = x.shape[0]
    q_nope, q_pe = _queries(cfg, p, x, pos[:, None])     # (B,1,H,·)
    c_new, kpe_new = _latent(cfg, p, x, pos[:, None])    # (B,1,·)
    b_idx = jnp.arange(B)
    c_kv = cache["c_kv"].at[b_idx, pos].set(c_new[:, 0].astype(cache["c_kv"].dtype))
    k_pe = cache["k_pe"].at[b_idx, pos].set(kpe_new[:, 0].astype(cache["k_pe"].dtype))
    k_nope, v = _expand_kv(cfg, p, c_kv.astype(x.dtype))
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    logits = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
              + jnp.einsum("bqhd,bkd->bhqk", q_pe, k_pe.astype(x.dtype)))
    logits = logits.astype(jnp.float32) * scale
    logits = softcap(logits, cfg.attn_softcap)
    S = c_kv.shape[1]
    ok = jnp.arange(S)[None, :] <= pos[:, None]
    logits = jnp.where(ok[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    out = out.reshape(B, 1, cfg.n_heads * m.v_head_dim)
    y = linear(out, p["wo"])
    return y, {"c_kv": c_kv, "k_pe": k_pe}
