"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

KV is compressed to a per-position latent c_kv (kv_lora_rank) plus a
shared rotary key k_pe (qk_rope_head_dim); the decode cache stores only
(latent, k_pe) — a large KV-memory reduction that compounds with GPTQT
weight quantization in the decode roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear, linear, rmsnorm, rope, softcap

NEG_INF = -1e30


def init_mla(cfg, key, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": init_linear(ks[0], d, m.q_lora_rank, dtype),
        "q_a_norm": jnp.zeros((m.q_lora_rank,), dtype),
        "wq_b": init_linear(ks[1], m.q_lora_rank, H * qk_hd, dtype),
        "wkv_a": init_linear(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_a_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "wkv_b": init_linear(ks[3], m.kv_lora_rank,
                             H * (m.qk_nope_head_dim + m.v_head_dim), dtype),
        "wo": init_linear(ks[4], H * m.v_head_dim, d, dtype),
    }


def _queries(cfg, p, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    qa = rmsnorm(linear(x, p["wq_a"]), p["q_a_norm"], cfg.norm_eps)
    q = linear(qa, p["wq_b"])
    q = q.reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_pe = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_pe = rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def _latent(cfg, p, x, positions):
    m = cfg.mla
    kv = linear(x, p["wkv_a"])
    c_kv, k_pe = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, p["kv_a_norm"], cfg.norm_eps)
    k_pe = rope(k_pe, positions, cfg.rope_theta)   # (B, S, rope_hd), shared
    return c_kv, k_pe


def _expand_kv(cfg, p, c_kv):
    m = cfg.mla
    B, S, _ = c_kv.shape
    H = cfg.n_heads
    kvb = linear(c_kv, p["wkv_b"])
    kvb = kvb.reshape(B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kvb, [m.qk_nope_head_dim], axis=-1)
    return k_nope, v


def mla_forward(cfg, spec, p, x, positions):
    """Full-sequence MLA. For long sequences the score computation is
    routed through the shared chunked flash path using the concatenation
    identity [q_nope||q_pe]·[k_nope||k_pe] = q_nope·k_nope + q_pe·k_pe
    (k_pe broadcast across heads), so no S x S tensor is materialized."""
    from repro.models import attention as attn_mod

    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_pe = _queries(cfg, p, x, positions)
    c_kv, k_pe = _latent(cfg, p, x, positions)
    k_nope, v = _expand_kv(cfg, p, c_kv)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_cat = jnp.concatenate([q_nope, q_pe], axis=-1)     # (B,S,H,dn+dr)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    fn = (attn_mod._attend_chunked if S > attn_mod.CHUNKED_THRESHOLD
          else attn_mod._attend_dense)
    out = fn(q_cat, k_cat, v, causal=cfg.causal, window=spec.window,
             cap=cfg.attn_softcap, scale=scale)          # (B,S,H,dv)
    out = out.reshape(B, S, H * m.v_head_dim)
    return linear(out, p["wo"])


def init_mla_cache(cfg, batch, max_len, dtype):
    m = cfg.mla
    return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "k_pe": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype)}


def mla_decode(cfg, spec, p, x, cache, pos):
    m = cfg.mla
    B = x.shape[0]
    q_nope, q_pe = _queries(cfg, p, x, pos[:, None])     # (B,1,H,·)
    c_new, kpe_new = _latent(cfg, p, x, pos[:, None])    # (B,1,·)
    b_idx = jnp.arange(B)
    c_kv = cache["c_kv"].at[b_idx, pos].set(c_new[:, 0].astype(cache["c_kv"].dtype))
    k_pe = cache["k_pe"].at[b_idx, pos].set(kpe_new[:, 0].astype(cache["k_pe"].dtype))
    k_nope, v = _expand_kv(cfg, p, c_kv.astype(x.dtype))
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    logits = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
              + jnp.einsum("bqhd,bkd->bhqk", q_pe, k_pe.astype(x.dtype)))
    logits = logits.astype(jnp.float32) * scale
    logits = softcap(logits, cfg.attn_softcap)
    S = c_kv.shape[1]
    ok = jnp.arange(S)[None, :] <= pos[:, None]
    logits = jnp.where(ok[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    out = out.reshape(B, 1, cfg.n_heads * m.v_head_dim)
    y = linear(out, p["wo"])
    return y, {"c_kv": c_kv, "k_pe": k_pe}
