"""Declarative quantization spec: what to quantize, with which method,
at which bit-widths — resolved per weight leaf.

A `QuantSpec` is pure data. It carries the model-wide defaults (method,
bits, mode, GPTQT knobs) plus an ordered tuple of `OverrideRule`s that
rewrite those defaults for leaves matched by name or dotted path — the
FineQuant-style mixed-precision hook (e.g. keep `lm_head` and `wv` at
higher bits than the rest of the network). Rules are matched first-hit
against the leaf name and the dotted tree path ("blocks.L0.attn.wq");
patterns use fnmatch glob syntax, so "wv", "blocks.L1.*" and "*.wd"
all work. Paths address the repeating pattern block (L0, L1, ...), not
unrolled layer indices — the over-groups scan stacks all groups of a
slot into one leaf, so a slot is the natural override granularity.

The module also owns the ONE quantizable-leaf predicate
(`is_quantizable`) shared by calibration (core/api.py), the abstract
dry-run path (quant/abstract.py) and the spec resolver, so eligibility
cannot drift between them.

`spec.resolve(path, name)` returns a `LeafPlan` (the fully-resolved
per-leaf settings handed to a registered quantizer) or None when the
leaf should be skipped. Specs serialize to/from plain dicts so packed
artifacts (repro/ckpt/packed.py) can record exactly how a model was
quantized.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from fnmatch import fnmatchcase
from typing import Optional, Tuple

# param-leaf names eligible for quantization (2D GEMM weights + 3D expert
# stacks); everything else (norms, convs, A_log, embeddings) is left alone.
QUANTIZABLE = {
    "wq", "wk", "wv", "wo", "wg", "wu", "wd", "in_proj", "out_proj",
    "x_proj", "dt_w", "wq_a", "wq_b", "wkv_a", "wkv_b", "lm_head",
}

MODES = ("fake", "packed")


def leaf_name(path) -> str:
    """Last component of a jax tree path (DictKey / GetAttrKey / ...)."""
    last = path[-1]
    return getattr(last, "key", getattr(last, "name", str(last)))


def dotted_path(path) -> str:
    """jax tree path -> "blocks.L0.attn.wq" (for rule matching)."""
    return ".".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def is_quantizable(name: str, *, include_head: bool = False,
                   exclude: Tuple[str, ...] = (), ndim: int = 2) -> bool:
    """THE shared eligibility predicate. A leaf is quantizable iff its
    name is a known GEMM weight, it is at least 2D (matrix or expert
    stack), the head is opted in, and no exclude substring matches."""
    return (name in QUANTIZABLE
            and ndim >= 2
            and (name != "lm_head" or include_head)
            and not any(sub in name for sub in exclude))


@dataclass(frozen=True)
class LeafPlan:
    """Fully-resolved settings for quantizing ONE weight leaf; this is
    what a registered Quantizer receives."""
    method: str
    bits: int
    mode: str = "fake"
    intermediate_bits: int = 5
    group_size: int = 0
    reexplore_range: int = 1
    reexplore_points: int = 33
    exact_search: bool = False

    def __post_init__(self):
        _check_group_size(self.group_size)

    def n_groups(self, k_in: int) -> int:
        """Scale groups along a K_in-length contraction axis; raises a
        clear error when group_size does not divide k_in (no implicit
        padding)."""
        return n_groups_for(k_in, self.group_size)


def n_groups_for(k_in: int, group_size: int) -> int:
    """THE quant-layer divisibility check (shared by LeafPlan and the
    abstract dry-run so the error message cannot drift)."""
    if group_size == 0:
        return 1
    if k_in % group_size:
        raise ValueError(
            f"group_size={group_size} does not divide the weight's "
            f"K_in={k_in}; pick a divisor of every quantized leaf's K "
            f"(or add an OverrideRule with a fitting group_size / "
            f"group_size=0 for the odd leaves)")
    return k_in // group_size


def _check_group_size(group_size) -> None:
    if group_size is None:
        return
    if not isinstance(group_size, int) or isinstance(group_size, bool):
        raise ValueError(
            f"group_size must be an int (K entries per scale group), "
            f"got {group_size!r}")
    if group_size < 0:
        raise ValueError(
            f"group_size must be >= 0 (0 = per-channel scales), got "
            f"{group_size}")


@dataclass(frozen=True)
class OverrideRule:
    """Per-leaf override: first rule whose pattern matches the leaf name
    or dotted path wins. Fields left at None inherit the spec default;
    `skip=True` leaves the matched leaf dense."""
    pattern: str
    method: Optional[str] = None
    bits: Optional[int] = None
    intermediate_bits: Optional[int] = None
    group_size: Optional[int] = None
    skip: bool = False

    def __post_init__(self):
        _check_group_size(self.group_size)

    def matches(self, path: str, name: str) -> bool:
        return fnmatchcase(name, self.pattern) or fnmatchcase(path,
                                                              self.pattern)


@dataclass(frozen=True)
class QuantSpec:
    """Declarative description of a whole-model quantization run."""
    method: str = "gptqt"
    bits: int = 3
    mode: str = "fake"                 # "fake" | "packed"
    intermediate_bits: int = 5
    group_size: int = 0
    reexplore_range: int = 1
    reexplore_points: int = 33
    exact_search: bool = False
    include_head: bool = False
    exclude: Tuple[str, ...] = ()
    overrides: Tuple[OverrideRule, ...] = ()

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got "
                             f"{self.mode!r}")
        _check_group_size(self.group_size)

    # ---------------- construction ----------------
    @classmethod
    def from_config(cls, qcfg, **kw) -> "QuantSpec":
        """Spec from a configs.base.QuantConfig (the per-model defaults),
        with keyword overrides (method=, mode=, bits=, overrides=, ...)."""
        base = dict(
            bits=qcfg.bits, intermediate_bits=qcfg.intermediate_bits,
            group_size=qcfg.group_size, reexplore_range=qcfg.reexplore_range,
            reexplore_points=qcfg.reexplore_points,
            exclude=tuple(qcfg.exclude))
        base.update(kw)
        return cls(**base)

    def replace(self, **kw) -> "QuantSpec":
        return dataclasses.replace(self, **kw)

    # ---------------- resolution ----------------
    def eligible(self, name: str, ndim: int = 2) -> bool:
        return is_quantizable(name, include_head=self.include_head,
                              exclude=self.exclude, ndim=ndim)

    def resolve(self, path: str, name: str,
                ndim: int = 2) -> Optional[LeafPlan]:
        """-> LeafPlan for this leaf, or None to leave it dense."""
        if not self.eligible(name, ndim):
            return None
        method, bits, ibits = self.method, self.bits, self.intermediate_bits
        gsize = self.group_size
        for rule in self.overrides:
            if rule.matches(path, name):
                if rule.skip:
                    return None
                method = rule.method or method
                bits = rule.bits or bits
                ibits = rule.intermediate_bits or ibits
                if rule.group_size is not None:
                    gsize = rule.group_size
                break
        return LeafPlan(
            method=method, bits=bits, mode=self.mode,
            intermediate_bits=ibits, group_size=gsize,
            reexplore_range=self.reexplore_range,
            reexplore_points=self.reexplore_points,
            exact_search=self.exact_search)

    # ---------------- (de)serialization ----------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["exclude"] = list(self.exclude)
        d["overrides"] = [dataclasses.asdict(r) for r in self.overrides]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "QuantSpec":
        d = dict(d)
        d["exclude"] = tuple(d.get("exclude", ()))
        d["overrides"] = tuple(OverrideRule(**r)
                               for r in d.get("overrides", ()))
        return cls(**d)
