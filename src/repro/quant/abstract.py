"""Abstract (ShapeDtypeStruct) QuantizedTensor construction for the
dry-run: replaces eligible weight leaves with packed stand-ins without
allocating anything, so the quantized serving path can be lowered and
compiled at full scale."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.packing import WORD
from repro.quant.qlinear import QuantizedTensor


def quantized_leaf_abstract(leaf, bits: int):
    """leaf: SDS/array of shape (..., K, N) -> QuantizedTensor of SDS."""
    *lead, K, N = leaf.shape
    KW = -(-K // WORD)
    sds = jax.ShapeDtypeStruct
    return QuantizedTensor(
        codes=sds((*lead, bits, KW, N), jnp.uint32),
        alphas=sds((*lead, 1, N, bits), jnp.float32),
        betas=sds((*lead, 1, N), jnp.float32),
        k_in=K, orig_dtype=str(leaf.dtype))


def quantize_params_abstract(cfg, params, bits: int, include_head=False):
    """Replace every eligible weight leaf with an abstract QuantizedTensor.
    Works on a ShapeDtypeStruct pytree (from jax.eval_shape)."""
    from repro.core.api import QUANTIZABLE, _leaf_name

    def walk(tree, in_blocks=False):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if isinstance(v, dict):
                    out[k] = walk(v, in_blocks or k == "blocks")
                elif (k in QUANTIZABLE
                      and (k != "lm_head" or include_head)
                      and not any(s in k for s in cfg.quant.exclude)
                      and getattr(v, "ndim", 0) >= 2):
                    out[k] = quantized_leaf_abstract(v, bits)
                else:
                    out[k] = v
            return out
        return tree

    return walk(params)


def packed_param_bytes(params) -> int:
    """Total bytes of a (possibly quantized) abstract param tree."""
    total = 0
    for leaf in jax.tree.leaves(params):
        total += leaf.size * jnp.dtype(leaf.dtype).itemsize
    return total
