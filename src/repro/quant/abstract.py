"""Abstract (ShapeDtypeStruct) QuantizedTensor construction for the
dry-run: replaces eligible weight leaves with packed stand-ins without
allocating anything, so the quantized serving path can be lowered and
compiled at full scale.

Eligibility and per-leaf bit-widths come from the SAME QuantSpec
resolver the real quantizer uses (repro.quant.spec), so the dry-run
cannot drift from the concrete path — a spec with mixed-precision
override rules sizes each abstract leaf at its resolved bits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.packing import WORD
from repro.quant.qlinear import QuantizedTensor
from repro.quant.spec import QuantSpec, n_groups_for


def quantized_leaf_abstract(leaf, bits: int, group_size: int = 0):
    """leaf: SDS/array of shape (..., K, N) -> QuantizedTensor of SDS.
    `group_size > 0` sizes the scale leaves at G = K/group_size groups
    along K, so the dry-run memory model charges per-group alphas/betas
    exactly as the concrete quantizer would emit them."""
    *lead, K, N = leaf.shape
    KW = -(-K // WORD)
    G = n_groups_for(K, group_size)
    sds = jax.ShapeDtypeStruct
    return QuantizedTensor(
        codes=sds((*lead, bits, KW, N), jnp.uint32),
        alphas=sds((*lead, G, N, bits), jnp.float32),
        betas=sds((*lead, G, N), jnp.float32),
        k_in=K, orig_dtype=str(leaf.dtype))


def quantize_params_abstract(cfg, params, bits=None, include_head=False,
                             spec=None):
    """Replace every eligible weight leaf with an abstract QuantizedTensor
    sized at its spec-resolved bit-width. Works on a ShapeDtypeStruct
    pytree (from jax.eval_shape). Pass either `bits` (uniform, the
    legacy dry-run call) or a full `spec`."""
    if spec is None:
        spec = QuantSpec.from_config(cfg.quant, mode="packed",
                                     include_head=include_head)
        if bits is not None:
            spec = spec.replace(bits=bits)

    def walk(tree, path=()):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                sub = (*path, k)
                if isinstance(v, dict):
                    out[k] = walk(v, sub)
                else:
                    plan = spec.resolve(".".join(sub), k,
                                        getattr(v, "ndim", 0))
                    out[k] = (quantized_leaf_abstract(v, plan.bits,
                                                      plan.group_size)
                              if plan else v)
            return out
        return tree

    return walk(params)


def packed_param_bytes(params) -> int:
    """Total bytes of a (possibly quantized) abstract param tree."""
    total = 0
    for leaf in jax.tree.leaves(params):
        total += leaf.size * jnp.dtype(leaf.dtype).itemsize
    return total
