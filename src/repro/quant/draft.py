"""Draft views: a low-bit model sliced out of a higher-bit one, free.

GPTQT's greedy residual coding makes each sign plane a refinement of the
previous ones, so the leading `d` planes of a w3/w4 QuantizedTensor are
themselves a valid w`d` coding of the same weight — a draft model for
self-speculative decoding that shares the packed sign words
byte-for-byte with the target. The only new tensors a draft needs are
its scales: the target's leading alphas are fit *jointly* with the
trailing planes present, so reusing them under-weights the truncated
code. `refit_draft_scales` re-solves the per-(group, column) least
squares

    min_{a', b'} || S' a' + b' 1 - W ||^2    over each group's gs rows,

where S' is the (gs, d) matrix of leading sign planes and W the
full-bit dequant — the closed-form optimum given the frozen signs (the
same refit step quant/kv.py's alternating rounds apply, plus the offset
column). That is the whole HBM cost of the draft: (G, N, d) alphas and
(G, N) betas per leaf; codes and every unquantized leaf are shared by
reference (`draft_extra_bytes` audits exactly that).

Offline, `ckpt.packed.save_packed(draft_bits=...)` stores the re-fit
scales as a manifest-v4 optional block; `make_draft_params` consumes
that block when present and falls back to the on-the-fly refit for v3
artifacts (a few batched (d+1)x(d+1) solves per leaf, once at boot).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.packing import unpack_signs
from repro.quant.qlinear import QuantizedTensor

# Tikhonov floor for the (d+1)x(d+1) normal equations: sign columns can
# be linearly dependent on tiny groups (gs < d+1), where the unregular-
# ized system is singular by construction.
_LS_EPS = 1e-6


def refit_draft_scales(qt: QuantizedTensor, draft_bits: int):
    """LS re-fit (alphas, betas) for the leading `draft_bits` planes of
    `qt` against its full-bit dequant. Returns (alphas (..., G, N, d)
    float32, betas (..., G, N) float32); handles leading stack dims."""
    d = int(draft_bits)
    if not 1 <= d <= qt.bits:
        raise ValueError(
            f"draft_bits={d} must be in [1, {qt.bits}] (active planes)")
    N = qt.n_out
    G = qt.n_groups
    gs = qt.k_in // G if G > 1 else qt.k_in
    w = qt.dequant(jnp.float32)                          # (..., K, N)
    signs = unpack_signs(qt.codes, qt.k_in)[..., :d, :, :]
    lead = signs.shape[:-3]
    S = signs.reshape(*lead, d, G, gs, N)
    Wg = w.reshape(*lead, G, gs, N)
    SS = jnp.einsum("...igkn,...jgkn->...gnij", S, S)    # (...,G,N,d,d)
    S1 = jnp.einsum("...igkn->...gni", S)                # (...,G,N,d)
    Sw = jnp.einsum("...igkn,...gkn->...gni", S, Wg)     # (...,G,N,d)
    w1 = jnp.einsum("...gkn->...gn", Wg)                 # (...,G,N)
    # augmented system [[S'S', S'1], [1'S', gs]] [a'; b'] = [S'W; 1'W]
    top = jnp.concatenate([SS, S1[..., :, None]], axis=-1)
    bot = jnp.concatenate(
        [S1, jnp.full((*S1.shape[:-1], 1), float(gs), jnp.float32)],
        axis=-1)[..., None, :]
    A = jnp.concatenate([top, bot], axis=-2)
    A = A + _LS_EPS * jnp.eye(d + 1, dtype=jnp.float32)
    rhs = jnp.concatenate([Sw, w1[..., None]], axis=-1)
    c = jnp.linalg.solve(A, rhs[..., None])[..., 0]      # (...,G,N,d+1)
    return (c[..., :d].astype(jnp.float32),
            c[..., d].astype(jnp.float32))


def draft_view(qt: QuantizedTensor, draft_bits: int, scales=None):
    """A QuantizedTensor serving the leading `draft_bits` planes of
    `qt`. The codes leaf is the SAME array object as the target's —
    zero extra HBM beyond the draft scales. `scales=(alphas, betas)`
    installs precomputed (manifest-v4) scales; None refits on the fly.
    `draft_bits == qt.bits` returns `qt` itself."""
    d = int(draft_bits)
    if d == qt.bits and scales is None:
        return qt
    if d > qt.bits:
        raise ValueError(
            f"draft_bits={d} exceeds the target's {qt.bits} active planes")
    if scales is None:
        alphas, betas = refit_draft_scales(qt, d)
    else:
        alphas, betas = (jnp.asarray(scales[0]), jnp.asarray(scales[1]))
    # match the target's scale dtype so one kernel expand path serves
    # both (bf16-scaled artifacts keep bf16 drafts)
    alphas = alphas.astype(qt.alphas.dtype)
    betas = betas.astype(qt.betas.dtype)
    return QuantizedTensor(codes=qt.codes, alphas=alphas, betas=betas,
                           k_in=qt.k_in, orig_dtype=qt.orig_dtype)


def make_draft_params(params, draft_bits: int, scales_tree=None):
    """Map `draft_view` over a param tree. Unquantized leaves are shared
    by reference (the identical array object). `scales_tree`, when
    given, mirrors the tree structure with {"bits", "alphas", "betas"}
    dicts at QuantizedTensor positions (ckpt.packed.load_draft_scales);
    entries whose stored bits disagree with `draft_bits` fall back to
    the on-the-fly refit."""
    def walk(node, sc):
        if isinstance(node, QuantizedTensor):
            use = None
            if isinstance(sc, dict) and "alphas" in sc:
                if int(sc.get("bits", -1)) == int(draft_bits):
                    use = (sc["alphas"], sc["betas"])
            return draft_view(node, draft_bits, scales=use)
        if isinstance(node, dict):
            return {k: walk(v, sc.get(k) if isinstance(sc, dict) else None)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            sub = sc if isinstance(sc, (list, tuple)) else [None] * len(node)
            return type(node)(walk(v, s) for v, s in zip(node, sub))
        return node
    return walk(params, scales_tree)


def draft_extra_bytes(target_params, draft_params) -> int:
    """Device bytes the draft tree adds beyond the target: every array
    buffer present in the draft but not aliased from the target. For a
    proper draft view this is exactly the re-fit scales."""
    def arrays_of(tree):
        out = []
        for leaf in jax.tree.leaves(
                tree, is_leaf=lambda x: isinstance(x, QuantizedTensor)):
            if isinstance(leaf, QuantizedTensor):
                out.extend((leaf.codes, leaf.alphas, leaf.betas))
            else:
                out.append(leaf)
        return out

    seen = {id(a) for a in arrays_of(target_params)}
    extra = 0
    for a in arrays_of(draft_params):
        if id(a) not in seen:
            seen.add(id(a))
            extra += int(a.size) * a.dtype.itemsize
    return extra
