from repro.quant.packing import pack_signs, padded_k, unpack_signs
from repro.quant.qlinear import QuantizedTensor

__all__ = ["pack_signs", "unpack_signs", "padded_k", "QuantizedTensor"]
