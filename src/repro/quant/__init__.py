from repro.quant.draft import (draft_extra_bytes, draft_view,
                               make_draft_params, refit_draft_scales)
from repro.quant.kv import (kv_bytes_per_token_head, kv_dequantize,
                            kv_layout, kv_quantize)
from repro.quant.packing import (pack_signs, pack_signs_last, padded_k,
                                 unpack_signs, unpack_signs_last)
from repro.quant.qlinear import QuantizedTensor
from repro.quant.registry import (QuantResult, Quantizer,
                                  available_quantizers, get_quantizer,
                                  register_quantizer)
from repro.quant.search import (LeafScore, format_overrides, format_report,
                                sensitivity_sweep, suggest_overrides)
from repro.quant.spec import (QUANTIZABLE, LeafPlan, OverrideRule,
                              QuantSpec, is_quantizable)

__all__ = [
    "pack_signs", "unpack_signs", "padded_k", "QuantizedTensor",
    "pack_signs_last", "unpack_signs_last",
    "kv_quantize", "kv_dequantize", "kv_layout", "kv_bytes_per_token_head",
    "QuantSpec", "OverrideRule", "LeafPlan", "QUANTIZABLE",
    "is_quantizable", "Quantizer", "QuantResult", "register_quantizer",
    "get_quantizer", "available_quantizers", "LeafScore",
    "sensitivity_sweep", "suggest_overrides", "format_overrides",
    "format_report",
    "draft_view", "make_draft_params", "refit_draft_scales",
    "draft_extra_bytes",
]
