from repro.quant.packing import pack_signs, padded_k, unpack_signs
from repro.quant.qlinear import QuantizedTensor
from repro.quant.registry import (QuantResult, Quantizer,
                                  available_quantizers, get_quantizer,
                                  register_quantizer)
from repro.quant.search import (LeafScore, format_overrides, format_report,
                                sensitivity_sweep, suggest_overrides)
from repro.quant.spec import (QUANTIZABLE, LeafPlan, OverrideRule,
                              QuantSpec, is_quantizable)

__all__ = [
    "pack_signs", "unpack_signs", "padded_k", "QuantizedTensor",
    "QuantSpec", "OverrideRule", "LeafPlan", "QUANTIZABLE",
    "is_quantizable", "Quantizer", "QuantResult", "register_quantizer",
    "get_quantizer", "available_quantizers", "LeafScore",
    "sensitivity_sweep", "suggest_overrides", "format_overrides",
    "format_report",
]
