"""QuantizedTensor: the fused binary-coding weight representation (Eq. 11).

W[k, n] = sum_i alphas[g(k), n, i] * s_i[k, n] + betas[g(k), n],
s in {-1,+1} packed as uint32 bitplanes, g(k) = k // group_size the
contiguous K-group of row k. This is a pytree, so it slots directly into
param trees: lax.scan slices the leading (group/expert) axes of its
leaves, pjit shards them (N on the `model` axis), and `layers.linear`
dispatches on it transparently.

The G axis invariant is validated at construction: alphas (..., G, N,
bits) and betas (..., G, N) must agree on G and N with the codes, and
G > 1 must divide k_in exactly (per-channel G=1 tolerates any k_in).
Validation is shape-only — tracers and ShapeDtypeStructs pass through —
and skipped for leaves that carry no shape (tree-structure plumbing).

A tensor's *active* bit-width is `alphas.shape[-1]`, which may be LESS
than the number of stored code planes (`codes.shape[-3]`): the greedy
residual coding makes each plane a refinement of the previous ones, so
slicing the leading planes of a w4 tensor plus re-fit alphas yields a
valid w2 "draft" view that shares the packed sign words byte-for-byte
(quant/draft.py). `bits` reports the active width; `stored_bits` the
planes physically present in `codes`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.packing import unpack_signs


def _shape(x):
    s = getattr(x, "shape", None)
    return tuple(s) if isinstance(s, (tuple, list)) else None


@jax.tree_util.register_pytree_with_keys_class
class QuantizedTensor:
    """Quantized stand-in for a weight of shape (..., k_in, n_out)."""

    def __init__(self, codes, alphas, betas, k_in, orig_dtype="bfloat16"):
        self.codes = codes        # (..., bits, ceil(K/32), N) uint32
        self.alphas = alphas      # (..., G, N, bits) float32
        self.betas = betas        # (..., G, N) float32
        self.k_in = int(k_in)
        self.orig_dtype = str(orig_dtype)
        self._validate()

    def _validate(self):
        cs, as_, bs = _shape(self.codes), _shape(self.alphas), _shape(self.betas)
        if (cs is None or as_ is None or bs is None
                or len(cs) < 3 or len(as_) < 3 or len(bs) < 2):
            return                  # no/partial shape info: trust the caller
        bits, KW, N = cs[-3:]
        G = as_[-3]
        if as_[-2] != N or not (1 <= as_[-1] <= bits):
            raise ValueError(
                f"alphas {as_} do not match codes {cs}: want "
                f"(..., G, N={N}, bits<={bits}) — active bits are the "
                f"alpha width and may not exceed the stored code planes")
        if bs[-2:] != (G, N):
            raise ValueError(
                f"betas {bs} do not match alphas {as_}: want "
                f"(..., G={G}, N={N})")
        if not (cs[:-3] == as_[:-3] == bs[:-2]):
            raise ValueError(
                f"leading (stack) dims disagree: codes {cs}, alphas "
                f"{as_}, betas {bs}")
        if G > 1 and self.k_in % G:
            raise ValueError(
                f"G={G} scale groups must divide k_in={self.k_in} "
                f"(group boundaries are contiguous K slices)")
        if self.k_in > KW * 32:
            raise ValueError(
                f"k_in={self.k_in} exceeds packed capacity {KW * 32}")

    # ---- pytree ----
    def tree_flatten_with_keys(self):
        children = [(jax.tree_util.GetAttrKey(n), getattr(self, n))
                    for n in ("codes", "alphas", "betas")]
        return children, (self.k_in, self.orig_dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, k_in=aux[0], orig_dtype=aux[1])

    # ---- metadata ----
    @property
    def bits(self):
        """Active bit-width: planes the scales actually weight. For a
        draft view this is smaller than `stored_bits`."""
        return self.alphas.shape[-1]

    @property
    def stored_bits(self):
        """Code planes physically present in the packed sign words."""
        return self.codes.shape[-3]

    @property
    def n_out(self):
        return self.codes.shape[-1]

    @property
    def n_groups(self):
        """Scale groups along K (G axis length)."""
        return self.alphas.shape[-3]

    @property
    def group_size(self):
        """K entries per scale group; 0 means per-channel (G=1)."""
        G = self.n_groups
        return 0 if G == 1 else self.k_in // G

    @property
    def shape(self):
        return (*self.codes.shape[:-3], self.k_in, self.n_out)

    @property
    def ndim(self):
        return len(self.shape)

    @property
    def scale_dtype(self):
        """dtype of the alpha/beta scale leaves (fp32 by default; packed
        artifacts may round them through bf16 — ckpt/packed.py)."""
        return str(jnp.dtype(self.alphas.dtype))

    def packed_bytes(self):
        return sum(a.size * a.dtype.itemsize
                   for a in (self.codes, self.alphas, self.betas))

    def cast_scales(self, dtype):
        """New QuantizedTensor with alphas/betas cast to `dtype` (codes
        are integer bitplanes and never cast). Casting fp32 -> bf16 ->
        fp32 reproduces exactly what a `scale_dtype="bfloat16"` packed
        artifact round-trips, so parity tests build their reference
        through this."""
        return QuantizedTensor(
            codes=self.codes,
            alphas=jnp.asarray(self.alphas, dtype),
            betas=jnp.asarray(self.betas, dtype),
            k_in=self.k_in, orig_dtype=self.orig_dtype)

    # ---- numerics ----
    def dequant(self, dtype=None):
        """Materialize W (..., k_in, n_out)."""
        signs = unpack_signs(self.codes, self.k_in)      # (...,bits,K,N)
        signs = signs[..., : self.bits, :, :]            # active planes
        G = self.alphas.shape[-3]
        rep = self.k_in // G + (1 if self.k_in % G else 0)
        # bf16 scales (packed artifacts) expand in fp32
        a = jnp.repeat(self.alphas.astype(jnp.float32),
                       rep, axis=-3)[..., :self.k_in, :, :]
        b = jnp.repeat(self.betas.astype(jnp.float32),
                       rep, axis=-2)[..., :self.k_in, :]
        w = jnp.einsum("...ikn,...kni->...kn", signs, a) + b
        return w.astype(dtype or self.orig_dtype)

    def quantized_matmul(self, x):
        """x (..., k_in) @ W -> (..., n_out). Dispatches to the Pallas
        kernel on TPU, pure-jnp dequant elsewhere."""
        from repro.kernels import ops
        return ops.bcq_apply(x, self)
