"""QuantizedTensor: the fused binary-coding weight representation (Eq. 11).

W[k, n] = sum_i alphas[g(k), n, i] * s_i[k, n] + betas[g(k), n],
s in {-1,+1} packed as uint32 bitplanes. This is a pytree, so it slots
directly into param trees: lax.scan slices the leading (group/expert)
axes of its leaves, pjit shards them (N on the `model` axis), and
`layers.linear` dispatches on it transparently.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.packing import unpack_signs


@jax.tree_util.register_pytree_with_keys_class
class QuantizedTensor:
    """Quantized stand-in for a weight of shape (..., k_in, n_out)."""

    def __init__(self, codes, alphas, betas, k_in, orig_dtype="bfloat16"):
        self.codes = codes        # (..., bits, ceil(K/32), N) uint32
        self.alphas = alphas      # (..., G, N, bits) float32
        self.betas = betas        # (..., G, N) float32
        self.k_in = int(k_in)
        self.orig_dtype = str(orig_dtype)

    # ---- pytree ----
    def tree_flatten_with_keys(self):
        children = [(jax.tree_util.GetAttrKey(n), getattr(self, n))
                    for n in ("codes", "alphas", "betas")]
        return children, (self.k_in, self.orig_dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, k_in=aux[0], orig_dtype=aux[1])

    # ---- metadata ----
    @property
    def bits(self):
        return self.codes.shape[-3]

    @property
    def n_out(self):
        return self.codes.shape[-1]

    @property
    def shape(self):
        return (*self.codes.shape[:-3], self.k_in, self.n_out)

    @property
    def ndim(self):
        return len(self.shape)

    def packed_bytes(self):
        return sum(a.size * a.dtype.itemsize
                   for a in (self.codes, self.alphas, self.betas))

    # ---- numerics ----
    def dequant(self, dtype=None):
        """Materialize W (..., k_in, n_out)."""
        signs = unpack_signs(self.codes, self.k_in)      # (...,bits,K,N)
        G = self.alphas.shape[-3]
        rep = self.k_in // G + (1 if self.k_in % G else 0)
        a = jnp.repeat(self.alphas, rep, axis=-3)[..., :self.k_in, :, :]
        b = jnp.repeat(self.betas, rep, axis=-2)[..., :self.k_in, :]
        w = jnp.einsum("...ikn,...kni->...kn", signs, a) + b
        return w.astype(dtype or self.orig_dtype)

    def quantized_matmul(self, x):
        """x (..., k_in) @ W -> (..., n_out). Dispatches to the Pallas
        kernel on TPU, pure-jnp dequant elsewhere."""
        from repro.kernels import ops
        return ops.bcq_apply(x, self)
