"""FineQuant-style per-leaf sensitivity sweep (the ROADMAP's "per-layer
bit search" follow-up, first half): score every quantizable leaf by its
Hessian-diagonal-weighted quantization error at a grid of bit-widths,
then emit a ready-to-paste `OverrideRule` tuple that keeps the most
sensitive leaves at higher precision.

The score for leaf W (GPTQ orientation, rows = output channels) at b
bits is the *relative* diag(H)-weighted error of a cheap RTN proxy:

    err(b) = sum_k hd[k] * (W - RTN_b(W))^2  /  sum_k hd[k] * W^2

— the same second-order proxy the GPTQT BCchoice search minimizes, so
the ranking orders leaves by how much layer-output MSE each one
contributes at a given width, without running the (much slower) GPTQ
solves per leaf. Scores are comparable across leaves because they are
normalized by the leaf's own weighted energy.

Typical use (also wired to `python -m repro.launch.serve
--suggest-overrides`):

    scores = sensitivity_sweep(cfg, params, calib_batches)
    rules = suggest_overrides(scores, base_bits=cfg.quant.bits)
    print(format_overrides(rules))     # paste into your QuantSpec
    spec = QuantSpec.from_config(cfg.quant, overrides=rules)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.rtn import quantize_rtn
from repro.quant.spec import OverrideRule, QuantSpec, dotted_path, leaf_name

DEFAULT_BITS_GRID = (2, 3, 4)


@dataclass(frozen=True)
class LeafScore:
    """Sensitivity of one weight leaf (averaged over layer groups and
    experts when the leaf is stacked)."""
    path: str                       # dotted path, e.g. "blocks.L0.attn.wv"
    err: Dict[int, float]           # bits -> relative weighted error
    params: int                     # elements (for budget accounting)

    def sensitivity(self, bits: int) -> float:
        """Error at `bits`, snapped to the nearest scored width (the
        sweep grid is fixed; callers may ask about e.g. 5 bits)."""
        return self.err[min(self.err, key=lambda b: abs(b - bits))]


def _leaf_err(Wt, hd, bits: int, group_size: int) -> float:
    """Relative diag(H)-weighted RTN error for one (N, K) matrix."""
    Wt = Wt.astype(jnp.float32)
    try:
        wq, _ = quantize_rtn(Wt, bits, group_size=group_size)
    except ValueError:              # group_size does not divide this K
        wq, _ = quantize_rtn(Wt, bits)
    hd = jnp.clip(hd, 1e-12, None)[None, :]
    num = jnp.sum(hd * (Wt - wq) ** 2)
    den = jnp.sum(hd * Wt ** 2) + 1e-12
    return float(num / den)


def sensitivity_sweep(cfg, params, calib_batches, *,
                      bits_grid: Tuple[int, ...] = DEFAULT_BITS_GRID,
                      spec: QuantSpec | None = None,
                      hessians=None) -> Tuple[LeafScore, ...]:
    """Calibrate (or reuse `hessians` from collect_hessians) and score
    every spec-eligible leaf at each width in `bits_grid`. Returns
    LeafScores sorted most-sensitive-first at the spec's base bits."""
    from repro.core.api import collect_hessians   # lazy: api imports quant
    if spec is None:
        spec = QuantSpec.from_config(cfg.quant)
    if hessians is None:
        hessians = collect_hessians(cfg, params, calib_batches, spec=spec)

    by_path: Dict[str, list] = {}
    for path, g, leaf, H in hessians.values():
        dotted = ("blocks." if g != -1 else "") + dotted_path(path)
        by_path.setdefault(dotted, []).append((leaf, H))

    scores = []
    for dotted, entries in sorted(by_path.items()):
        name = dotted.rsplit(".", 1)[-1]
        plan = spec.resolve(dotted, name, getattr(entries[0][0], "ndim", 2))
        gsize = plan.group_size if plan is not None else 0
        errs: Dict[int, list] = {b: [] for b in bits_grid}
        n_params = 0
        for leaf, H in entries:
            mats = ([(leaf[e], H[e]) for e in range(leaf.shape[0])]
                    if leaf.ndim == 3 else [(leaf, H)])
            for W, He in mats:
                Wt = jnp.asarray(W).T                     # (N, K)
                hd = jnp.diag(jnp.asarray(He, jnp.float32))
                for b in bits_grid:
                    errs[b].append(_leaf_err(Wt, hd, b, gsize))
                n_params += W.size
        scores.append(LeafScore(
            path=dotted,
            err={b: float(np.mean(errs[b])) for b in bits_grid},
            params=n_params))

    base = min(bits_grid, key=lambda b: abs(b - spec.bits))
    scores.sort(key=lambda s: -s.sensitivity(base))
    return tuple(scores)


def bump_cost_bytes(score: LeafScore, base_bits: int, bump_to: int) -> int:
    """Extra checkpoint bytes of raising one leaf from base_bits to
    bump_to: (bump_to - base_bits) extra sign bitplanes over `params`
    elements, 1 bit per element per plane (scale overhead is per-group,
    negligible at leaf granularity and identical for every candidate)."""
    return max(bump_to - base_bits, 0) * score.params // 8


def suggest_overrides(scores: Iterable[LeafScore], *, base_bits: int,
                      bump_frac: float = 0.25,
                      bump_to: int | None = None,
                      bytes_budget: int | None = None,
                      ) -> Tuple[OverrideRule, ...]:
    """Pick which leaves get an OverrideRule raising them to `bump_to`
    (default base_bits + 1) — the FineQuant recipe: spend the extra bits
    where the weighted error concentrates.

    Two selection modes:
      - default: top `bump_frac` most-sensitive leaves at `base_bits`
        (quantile recipe — size-blind: a tiny norm leaf and a d_ff x
        d_model matmul cost the same slot).
      - `bytes_budget`: greedily spend a byte allowance by error
        reduction *per byte* — candidates are ranked by
        (err[base] - err[bump_to]) / bump_cost_bytes and taken while
        they fit, skipping any leaf too large for the remaining budget
        (greedy knapsack cover). This is the mode the serving CLI's
        `--bytes-budget` exposes: "I can afford 2 MiB more checkpoint,
        place it where it buys the most accuracy."
    """
    scores = list(scores)
    if not scores:
        return ()
    bump_to = bump_to if bump_to is not None else base_bits + 1
    if bytes_budget is None:
        ranked = sorted(scores, key=lambda s: -s.sensitivity(base_bits))
        n_bump = max(1, int(round(len(ranked) * bump_frac)))
        return tuple(OverrideRule(pattern=s.path, bits=bump_to)
                     for s in ranked[:n_bump])

    if bytes_budget < 0:
        raise ValueError(f"bytes_budget must be >= 0, got {bytes_budget}")

    def gain_per_byte(s: LeafScore) -> float:
        cost = bump_cost_bytes(s, base_bits, bump_to)
        if cost <= 0:
            return 0.0
        gain = s.sensitivity(base_bits) - s.sensitivity(bump_to)
        return max(gain, 0.0) / cost

    ranked = sorted(scores, key=lambda s: -gain_per_byte(s))
    chosen, remaining = [], int(bytes_budget)
    for s in ranked:
        cost = bump_cost_bytes(s, base_bits, bump_to)
        if cost <= 0 or gain_per_byte(s) <= 0.0:
            continue                  # bump buys nothing for this leaf
        if cost > remaining:
            continue                  # too big — a cheaper leaf may fit
        chosen.append(s)
        remaining -= cost
    return tuple(OverrideRule(pattern=s.path, bits=bump_to)
                 for s in chosen)


def format_overrides(rules: Iterable[OverrideRule]) -> str:
    """Render rules as paste-ready QuantSpec construction source."""
    lines = ["overrides = ("]
    for r in rules:
        parts = [repr(r.pattern)]
        for f in ("method", "bits", "intermediate_bits", "group_size"):
            v = getattr(r, f)
            if v is not None:
                parts.append(f"{f}={v!r}")
        if r.skip:
            parts.append("skip=True")
        lines.append(f"    OverrideRule({', '.join(parts)}),")
    lines.append(")")
    return "\n".join(lines)


def format_report(scores: Iterable[LeafScore],
                  bits_grid: Tuple[int, ...] = DEFAULT_BITS_GRID) -> str:
    """Human-readable sensitivity table (one line per leaf)."""
    header = "leaf".ljust(32) + "".join(f"  err@w{b}" for b in bits_grid)
    lines = [header, "-" * len(header)]
    for s in scores:
        lines.append(s.path.ljust(32) + "".join(
            f"  {s.err[b]:7.4f}" for b in bits_grid))
    return "\n".join(lines)
