"""Bitplane packing for binary-coded weights.

Sign tensors s in {-1,+1} of shape (..., bits, K, N) are stored as uint32
words packed along K (the contraction dim): bit j of word w covers
K index w*32 + j. K is padded to a multiple of 32 with zeros (-1 signs);
`k_in` metadata on QuantizedTensor masks the pad out of dequantization.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.hw import WORD


def padded_k(k: int) -> int:
    return -(-k // WORD) * WORD


def pack_signs(signs):
    """signs: (..., bits, K, N) bool/int (truthy = +1) -> uint32
    (..., bits, ceil(K/32), N)."""
    s = (signs > 0) if signs.dtype != jnp.bool_ else signs
    *lead, bits, K, N = s.shape
    Kp = padded_k(K)
    if Kp != K:
        pad = [(0, 0)] * (len(lead) + 1) + [(0, Kp - K), (0, 0)]
        s = jnp.pad(s, pad)
    s = s.reshape(*lead, bits, Kp // WORD, WORD, N).astype(jnp.uint32)
    shifts = (jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32))
    return jnp.sum(s * shifts[:, None], axis=-2, dtype=jnp.uint32)


def unpack_signs(codes, k_in: int):
    """codes: (..., bits, K/32, N) uint32 -> float32 signs (..., bits, k_in, N)."""
    *lead, bits, KW, N = codes.shape
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    b = (codes[..., :, None, :] >> shifts[:, None]) & jnp.uint32(1)
    b = b.reshape(*lead, bits, KW * WORD, N)[..., :k_in, :]
    return (2.0 * b - 1.0).astype(jnp.float32)


def pack_signs_last(signs):
    """Pack along the LAST axis: signs (..., K) bool/int (truthy = +1)
    -> uint32 (..., K/32). K must be a multiple of 32 (the KV-cache
    layout pads nothing: head_dim is required to divide WORD). Bit j of
    word w covers index w*32 + j, matching `pack_signs`."""
    s = (signs > 0) if signs.dtype != jnp.bool_ else signs
    *lead, K = s.shape
    assert K % WORD == 0, f"pack_signs_last needs K % {WORD} == 0, got {K}"
    s = s.reshape(*lead, K // WORD, WORD).astype(jnp.uint32)
    shifts = (jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32))
    return jnp.sum(s * shifts, axis=-1, dtype=jnp.uint32)


def unpack_signs_last(codes):
    """codes (..., K/32) uint32 -> float32 signs (..., K)."""
    *lead, KW = codes.shape
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    b = (codes[..., None] >> shifts) & jnp.uint32(1)
    return (2.0 * b - 1.0).astype(jnp.float32).reshape(*lead, KW * WORD)
