"""Quantizer registry: string method names -> Quantizer implementations.

Every quantization method (`rtn`, `bcq`, `gptq`, `gptq_minmse`,
`gptq_bcq`, `gptqt`, ...) is a `Quantizer` registered under its name
with `@register_quantizer("name")`; `core/api.quantize_matrix` and
`quantize_model` dispatch through `get_quantizer` — there is no
string if/elif chain anywhere. Registration is open: downstream code
can plug in new methods (experimental grids, per-layer searches)
without touching the core, which is what FineQuant-style method x bits
sweeps need.

The built-in quantizers live in repro/core/quantizers.py (they wrap the
paper's solvers, which live in repro/core); this module stays
import-light so repro.quant never depends on repro.core at import time.
`get_quantizer` lazily imports the built-ins on first lookup.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

_REGISTRY: dict = {}
_BUILTINS_LOADED = False


@dataclass
class QuantResult:
    """What a Quantizer returns for one matrix (GPTQ orientation).

    wq_t: dequantized fp32 weights (N_out, K_in) — always present, used
          for fake-quant installs and output-error reporting.
    qt:   packed QuantizedTensor (layer layout K, N) when the method has
          a fused binary-coding representation and the plan asked for
          mode="packed"; None otherwise.
    """
    wq_t: object
    qt: object = None


class Quantizer:
    """Protocol for one quantization method.

    Subclasses implement `quantize(Wt, H, plan, orig_dtype=...)` where
    Wt is the fp32 weight in GPTQ orientation (N_out, K_in), H the
    (K, K) calibration Hessian and plan a spec.LeafPlan. Set
    `supports_packed = True` iff the method can emit a QuantizedTensor.
    """
    name: str = "?"
    supports_packed: bool = False

    def quantize(self, Wt, H, plan, *, orig_dtype="bfloat16") -> QuantResult:
        raise NotImplementedError


def register_quantizer(name: str):
    """Class decorator: `@register_quantizer("gptqt")`. Instantiates the
    class and binds it under `name` (later registrations override)."""
    def deco(cls):
        inst = cls()
        inst.name = name
        _REGISTRY[name] = inst
        return cls
    return deco


def _ensure_builtins():
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        import repro.core.quantizers  # noqa: F401  (registers built-ins)
        _BUILTINS_LOADED = True       # only after a successful import


def get_quantizer(name: str) -> Quantizer:
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown quantizer {name!r}; registered: "
            f"{', '.join(available_quantizers())}") from None


def available_quantizers() -> list:
    _ensure_builtins()
    return sorted(_REGISTRY)
