"""Binary-coded KV cache quantization: the storage format for quantized
page pools (models/attention.py:init_paged_kv(kv_bits=...)).

Each K/V vector of head_dim entries is stored as GPTQT's binary-coding
representation — the same alphas + sign-bitplane form the weight path
uses (core/binary_coding.py:bcq_greedy), fitted *per token, per KV head,
per contiguous head_dim group*:

    x[g*gs:(g+1)*gs] ~= beta_g + sum_i alpha_{g,i} * s_{g,i}

with s in {-1,+1} packed 32 signs per uint32 word along head_dim
(quant/packing.py:pack_signs_last). The coding is greedy residual sign
coding plus a mean offset (beta): per bit, alpha = mean|r| and
s = sign(r) — the closed-form per-step optimum the weight solvers start
from. Quantization happens on-write inside the jitted decode/extend/
scatter steps (it is a handful of vector ops per token), dequantization
happens inside the paged-attention kernel's VMEM accumulator loop
(kernels/paged_attention.py:paged_attention_quant) or the jnp oracle
(kernels/ref.py:paged_attention_quant_ref).

Layout per (token, head), head_dim = hd, G = hd / group_size:
    codes  (..., bits, hd/32)  uint32   sign bitplanes
    alphas (..., G, bits)      float32  per-group magnitudes
    betas  (..., G)            float32  per-group offsets

Bytes per (token, head): 4*bits*hd/32 + 4*G*bits + 4*G, vs 4*hd for an
fp32 page and 2*hd for bf16 — at hd=64, bits=4, G=1: 52 B vs 256/128 B
(4.9x / 2.5x). `kv_bytes_per_token_head` is the single owner of that
arithmetic (EngineStats and the capacity bench both read it).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.quant.packing import WORD, pack_signs_last, unpack_signs_last


def kv_layout(head_dim: int, kv_bits: int, kv_group_size: int = 0):
    """Validate a quantized-KV layout; returns (G, words_per_head).
    head_dim must be a multiple of 32 (signs pack with no padding) and
    kv_group_size (0 = one group spanning head_dim) must divide it."""
    if kv_bits < 1:
        raise ValueError(f"kv_bits must be >= 1, got {kv_bits}")
    if head_dim % WORD:
        raise ValueError(
            f"quantized KV needs head_dim % {WORD} == 0 (sign words pack "
            f"along head_dim), got head_dim={head_dim}")
    gs = kv_group_size or head_dim
    if head_dim % gs:
        raise ValueError(
            f"kv_group_size={gs} must divide head_dim={head_dim}")
    return head_dim // gs, head_dim // WORD


# alternating-refinement rounds inside kv_quantize: greedy residual
# coding alone saturates around 10% relative error regardless of bits
# (each bit only fixes the sign pattern the previous residual left);
# LS-refit + nearest-level-reassign rounds (Eq. 4, the same refinement
# core/binary_coding.py:bcq_alternating applies to weights) restore the
# expected per-bit decay. 6 rounds puts 4-bit coding at ~11% relative
# error — the level where greedy decode on the toy model is
# token-identical to the fp pool (tests/test_kv_quant.py) — at a cost
# of a few batched (bits x bits) solves per written token, noise next
# to the attention math itself. Read at trace time: a process that
# wants a different trade-off sets this before building engines.
KV_REFINE_ITERS = 6


def kv_quantize(x, kv_bits: int, kv_group_size: int = 0,
                iters: int | None = None):
    """Binary-code vectors along the last axis. x (..., hd) float ->
    (codes (..., bits, hd/32) u32, alphas (..., G, bits) f32,
    betas (..., G) f32). Greedy residual coding per contiguous group,
    then `iters` (default KV_REFINE_ITERS, resolved at trace time)
    alternating rounds: refit alphas by per-group least squares,
    reassign each entry to the nearest of the 2^bits representable
    levels."""
    if iters is None:
        iters = KV_REFINE_ITERS
    hd = x.shape[-1]
    G, _ = kv_layout(hd, kv_bits, kv_group_size)
    gs = hd // G
    xg = x.astype(jnp.float32).reshape(*x.shape[:-1], G, gs)
    beta = jnp.mean(xg, axis=-1)                         # (..., G)
    r0 = xg - beta[..., None]
    r = r0
    alphas, signs = [], []
    for _ in range(kv_bits):
        s = jnp.where(r >= 0, 1.0, -1.0)
        a = jnp.mean(jnp.abs(r), axis=-1)                # (..., G)
        alphas.append(a)
        signs.append(s)
        r = r - a[..., None] * s
    S = jnp.stack(signs, axis=-2)                        # (..., G, bits, gs)
    a = jnp.stack(alphas, axis=-1)                       # (..., G, bits)
    if iters:
        from repro.core.binary_coding import sign_combos
        combos = jnp.asarray(sign_combos(kv_bits))       # (L, bits)
        eye = jnp.eye(kv_bits, dtype=jnp.float32)
        for _ in range(iters):
            # refit: per-group LS  (S S^T) a = S r0
            Gm = jnp.einsum("...ik,...jk->...ij", S, S) + 1e-6 * eye
            rhs = jnp.einsum("...ik,...k->...i", S, r0)
            a = jnp.abs(jnp.linalg.solve(Gm, rhs[..., None])[..., 0])
            # reassign: nearest of the 2^bits levels
            levels = jnp.einsum("...b,lb->...l", a, combos)  # (..., G, L)
            idx = jnp.argmin(
                jnp.abs(r0[..., None, :] - levels[..., None]), axis=-2)
            S = jnp.moveaxis(combos[idx], -1, -2)        # (..., G, bits, gs)
    signs = jnp.moveaxis(S, -2, -3)                      # (..., bits, G, gs)
    signs = signs.reshape(*x.shape[:-1], kv_bits, hd)
    return pack_signs_last(signs), a, beta


def kv_dequantize(codes, alphas, betas, dtype=jnp.float32):
    """Inverse of kv_quantize: codes (..., bits, hd/32) u32, alphas
    (..., G, bits), betas (..., G) -> (..., hd) in `dtype`."""
    signs = unpack_signs_last(codes)                     # (..., bits, hd)
    *lead, bits, hd = signs.shape
    G = betas.shape[-1]
    sg = signs.reshape(*lead, bits, G, hd // G)
    w = jnp.einsum("...bgk,...gb->...gk", sg,
                   alphas.astype(jnp.float32)) + betas[..., None]
    return w.reshape(*lead, hd).astype(dtype)


def kv_bytes_per_token_head(head_dim: int, kv_bits: int,
                            kv_group_size: int = 0,
                            dtype_itemsize: int = 4) -> int:
    """Device bytes one (token, KV head) vector occupies. kv_bits=0 is
    the unquantized layout (head_dim raw entries of the pool dtype)."""
    if not kv_bits:
        return head_dim * dtype_itemsize
    G, hdw = kv_layout(head_dim, kv_bits, kv_group_size)
    # codes u32 + alphas f32 + betas f32
    return 4 * kv_bits * hdw + 4 * G * kv_bits + 4 * G
