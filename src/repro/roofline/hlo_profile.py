"""Static per-op profiling of compiled HLO text: approximate bytes/flops
per op category, sorted hot list. This is the 'profiler' of the dry-run
environment (no real hardware): it tells us WHICH ops dominate the
memory/compute terms and whether collectives are redundant.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_ARR_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<rtype>\([^)]*\)|[a-z0-9]+\[[^\]]*\]"
    r"(?:\{[^}]*\})?)\s+(?P<op>[\w\-]+)\(")


def _bytes_of(type_str):
    total = 0
    for dt, dims in _ARR_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def profile_hlo(hlo_text: str, top: int = 25):
    """Group output-bytes by op kind; list the largest single ops."""
    by_kind = defaultdict(lambda: {"bytes": 0, "count": 0})
    biggest = []
    in_while_body = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        op = m.group("op")
        b = _bytes_of(m.group("rtype"))
        by_kind[op]["bytes"] += b
        by_kind[op]["count"] += 1
        biggest.append((b, op, line.strip()[:140]))
    biggest.sort(key=lambda x: -x[0])
    kinds = sorted(by_kind.items(), key=lambda kv: -kv[1]["bytes"])
    return {"by_kind": kinds, "top_ops": biggest[:top]}


def print_profile(hlo_text: str, top: int = 20):
    p = profile_hlo(hlo_text, top)
    print(f"{'op kind':28s} {'count':>6s} {'output GB':>10s}")
    for k, v in p["by_kind"][:20]:
        print(f"{k:28s} {v['count']:6d} {v['bytes']/1e9:10.3f}")
    print("\n-- largest single ops --")
    for b, op, line in p["top_ops"][:top]:
        print(f"{b/1e9:8.3f} GB  {line}")
    return p
