"""Three-term roofline analysis from a compiled dry-run artifact.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_wire_bytes_per_device / link_bw

cost_analysis() on the compiled (SPMD-partitioned) module is PER-DEVICE,
so no further division by chip count is needed (verified empirically:
global flops / n_devices matches the reported number).

Collective bytes are parsed from compiled.as_text(): for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op we take the result array bytes and apply the standard ring-wire factor:
  all-reduce      2 (g-1)/g x bytes
  all-gather      (g-1)/g x bytes      (result = gathered size)
  reduce-scatter  (g-1)   x bytes      (result = scattered size)
  all-to-all      (g-1)/g x bytes
  collective-permute  1.0 x bytes
Group size g comes from replica_groups (iota [n,g]<=... or explicit
{{...}} form).

Hardware model (TPU v5e, per task spec): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per direction budget per chip).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link per chip

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<rtype>\([^)]*\)|[a-z0-9_]+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute|ragged-all-to-all)"
    r"(?P<start>-start)?\(")

_ARR_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_WIRE_FACTOR = {
    "all-reduce": lambda g: 2.0 * (g - 1) / g,
    "all-gather": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: float(g - 1),
    "all-to-all": lambda g: (g - 1) / g,
    "ragged-all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


@dataclass
class CollectiveStats:
    total_wire_bytes: float = 0.0
    by_op: dict = field(default_factory=dict)
    count: int = 0
    top: list = field(default_factory=list)   # (wire_bytes, op, line snippet)


def _array_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARR_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


def parse_collectives(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        g = _group_size(line)
        if g <= 1:
            continue
        b = _array_bytes(m.group("rtype")) * _WIRE_FACTOR[op](g)
        st.total_wire_bytes += b
        ent = st.by_op.setdefault(op, {"bytes": 0.0, "count": 0})
        ent["bytes"] += b
        ent["count"] += 1
        st.count += 1
        st.top.append((b, op, line.strip()[:180]))
    st.top.sort(key=lambda x: -x[0])
    st.top = st.top[:15]
    return st


def roofline_terms(cost: dict, coll: CollectiveStats):
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll.total_wire_bytes / ICI_BW
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))
    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll.total_wire_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bound": dom[1],
        "t_bound_s": dom[0],
        # fraction of the bound wall-time that is the compute term ==
        # achievable MFU ceiling under this binding
        "roofline_mfu": (t_compute / dom[0]) if dom[0] > 0 else 0.0,
    }


def model_flops(cfg, shape_spec) -> float:
    """MODEL_FLOPS = 6 N_active D for train, 2 N_active D for inference
    (per generated token for decode). Global, not per-device."""
    n_active = cfg.param_counts()["active"]
    if shape_spec.kind == "train":
        toks = shape_spec.seq_len * shape_spec.global_batch
        return 6.0 * n_active * toks
    if shape_spec.kind == "prefill":
        toks = shape_spec.seq_len * shape_spec.global_batch
        return 2.0 * n_active * toks
    return 2.0 * n_active * shape_spec.global_batch  # decode: 1 tok/seq
