"""jit'd wrappers + dispatch for the binary-coded GEMM.

`bcq_apply(x, qt)` is what `layers.linear` calls for QuantizedTensor
weights: it picks the Pallas kernel on TPU (or when FORCE_PALLAS is set,
running interpret=True off-TPU for tests) and the pure-jnp reference
otherwise. Group-wise scales (G > 1) ride the kernel whenever the
packed layout lines up (group_size a multiple of the 32-bit pack word,
so the zero-padded K tail never crosses into a phantom group). A
single-axis expert stack (codes (E, bits, K/32, N)) with a matching
batched activation (E, C, k_in) rides the batched-expert kernel — one
launch for the whole MoE layer; deeper leading dims and ragged
groupings fall back to the reference path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bcq_matmul import bcq_expert_matmul, bcq_gemv, bcq_matmul
from repro.quant.packing import WORD

# None = auto (use Pallas iff backend is TPU). Tests/benches may override.
FORCE_PALLAS: bool | None = None


def _use_pallas() -> bool:
    if FORCE_PALLAS is not None:
        return FORCE_PALLAS
    return jax.default_backend() == "tpu"


def _kernel_groups_ok(qt) -> bool:
    """G > 1 runs the fused kernel iff groups tile the packed K axis:
    group_size divides k_in (validated at construction) AND is a
    multiple of the 32-bit pack word, which together mean k_in is
    already word-aligned (no pad rows outside the last group)."""
    G = qt.alphas.shape[-3]
    if G == 1:
        return True
    return qt.k_in % G == 0 and (qt.k_in // G) % WORD == 0


def _active_codes(qt):
    """Code planes the tensor's scales actually weight. Draft views keep
    the full stored planes (they alias the target's packed words) but
    carry fewer alphas; the slice happens here, at trace time, so the
    smaller plane stack never persists in HBM."""
    if qt.bits == qt.stored_bits:
        return qt.codes
    return qt.codes[..., : qt.bits, :, :]


def bcq_apply(x, qt):
    """x (..., k_in) @ QuantizedTensor -> (..., n_out)."""
    codes = _active_codes(qt)
    lead = codes.shape[:-3]
    if lead:                      # expert/group stacks
        if (len(lead) == 1 and x.ndim == 3 and x.shape[0] == lead[0]
                and _use_pallas() and _kernel_groups_ok(qt)):
            interpret = jax.default_backend() != "tpu"
            kp = codes.shape[-2] * WORD
            xm = x
            if kp != qt.k_in:
                xm = jnp.pad(xm, ((0, 0), (0, 0), (0, kp - qt.k_in)))
            return bcq_expert_matmul(xm, codes, qt.alphas, qt.betas,
                                     interpret=interpret)
        w = _dequant_nd(qt, x.dtype)
        if len(lead) == 1 and x.ndim == 3 and x.shape[0] == lead[0]:
            # batched expert matmul: (E, C, k) @ (E, k, n) -> (E, C, n)
            return jnp.einsum("eck,ekn->ecn", x, w)
        return jnp.einsum("...k,...kn->...n", x, w)
    if not _use_pallas() or not _kernel_groups_ok(qt):
        w = ref.dequant_ref(codes, qt.alphas, qt.betas, qt.k_in,
                            dtype=x.dtype)
        return jnp.einsum("...k,kn->...n", x, w)

    interpret = jax.default_backend() != "tpu"
    xm = x.reshape(-1, qt.k_in)
    kp = codes.shape[-2] * WORD
    if kp != qt.k_in:
        xm = jnp.pad(xm, ((0, 0), (0, kp - qt.k_in)))
    fn = bcq_gemv if xm.shape[0] <= 8 else bcq_matmul
    y = fn(xm, codes, qt.alphas, qt.betas, interpret=interpret)
    return y.reshape(*x.shape[:-1], qt.n_out)


def _dequant_nd(qt, dtype):
    """Dequantize with arbitrary leading dims (expert/group stacks)."""
    acodes = _active_codes(qt)
    lead = acodes.shape[:-3]
    codes = acodes.reshape(-1, *acodes.shape[-3:])
    alphas = qt.alphas.reshape(-1, *qt.alphas.shape[-3:])
    betas = qt.betas.reshape(-1, *qt.betas.shape[-2:])
    ws = jax.vmap(lambda c, a, b: ref.dequant_ref(c, a, b, qt.k_in, dtype))(
        codes, alphas, betas)
    return ws.reshape(*lead, qt.k_in, qt.n_out)
