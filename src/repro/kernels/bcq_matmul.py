"""Pallas TPU kernel: dequant-fused binary-coded GEMM with group-wise
scales.

Computes y = x @ W where
    W[k, n] = sum_i alphas[g(k), n, i] * s_i[k, n] + betas[g(k), n],
g(k) = k // group_size, and the sign bitplanes s_i are packed 32-per-
uint32 along K. The packed codes (bits/16 of the bf16 bytes at 3-bit)
stream HBM->VMEM tile by tile; each tile is expanded to a dense
(BK, BN) weight tile *in VMEM* and fed to the MXU as one bf16 GEMM —
the TPU-native replacement for GPU LUT-GEMM (DESIGN.md §2).
Accumulation over the K grid axis happens in an fp32 VMEM scratch
accumulator.

Group-wise alphas stay a single fused expand: the K-tile's slice of the
(G, N, bits) alpha array is selected by the BlockSpec index map from
the K grid index, so the kernel body only broadcasts each group's
scales over its rows before the one MXU dot — no extra passes, no
gather. Tiling constraint: BK must be a multiple of group_size (several
groups per K-tile) or group_size a multiple of BK (one group spanning
several tiles); `bcq_matmul` adjusts block_k automatically (round down
to a group multiple, or shrink to gcd(group_size, block_k) for odd
spanning sizes), so any group_size that is a multiple of the 32-bit
pack word works.

Layout notes (TPU-friendly):
  x       (M, K)            -> blocks (BM, BK)
  codes   (bits, K/32, N)   -> blocks (bits, BK/32, BN); K is the
                               second-minor dim so unpacking expands
                               sublanes, keeping N on the 128-wide lane dim
  alphas  (G, N, bits)      -> (BG, BN, bits), BG = groups per K-tile
  betas   (G, N)            -> (BG, BN)
All MXU dims (BM, BN, BK) default to multiples of 128.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.hw import SUBLANE, WORD

# default tile sizes (repro-lint R004: named, and multiples of the
# SUBLANE/LANE/WORD family — callers override per shape, the kernel
# re-derives legal BK from group_size below)
BLOCK_M = 128
BLOCK_N = 256
BLOCK_K = 256
# decode-shaped (gemv) defaults: wider N/K tiles, 8-row M tile
GEMV_BLOCK_N = 512
GEMV_BLOCK_K = 512

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _expand_w(codes, alphas, betas, *, bits: int, bg: int):
    """Expand one VMEM tile of packed codes + group scales into a dense
    (BK, BN) fp32 weight tile: shift-unpack the sign bitplanes, then
    broadcast each group's scales over its rows. Shared by the single-
    matrix and batched-expert kernel bodies."""
    bk32, bn = codes.shape[1], codes.shape[2]
    bk = bk32 * WORD
    shifts = jax.lax.broadcasted_iota(
        jnp.uint32, (1, 1, WORD, 1), 2)                  # (1,1,32,1)
    planes = (codes[:, :, None, :] >> shifts) & jnp.uint32(1)
    planes = planes.reshape(bits, bk, bn).astype(jnp.float32)
    signs = 2.0 * planes - 1.0                           # (bits, BK, BN)

    # expand group scales over their rows: group g covers rows
    # [g*sub, (g+1)*sub) of this K-tile (sub = BK // BG)
    sub = bk // bg
    signs = signs.reshape(bits, bg, sub, bn)
    # scales may arrive bf16 (packed artifacts keep them bf16 in
    # memory); expand in fp32 so accumulation matches fp32-scale runs
    w = jnp.broadcast_to(
        betas[:, None, :], (bg, sub, bn)).astype(jnp.float32)
    for i in range(bits):                                # static unroll
        a_i = alphas[:, :, i].astype(jnp.float32)
        w = w + a_i[:, None, :] * signs[i]
    return w.reshape(bk, bn)


def _kernel(x_ref, codes_ref, alpha_ref, beta_ref, o_ref, acc_ref, *,
            bits: int, nk: int, bg: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _expand_w(codes_ref[...], alpha_ref[...], beta_ref[...],
                  bits=bits, bg=bg)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w.astype(x_ref.dtype),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _expert_kernel(x_ref, codes_ref, alpha_ref, beta_ref, o_ref, acc_ref, *,
                   bits: int, nk: int, bg: int):
    """Batched-expert body: identical math, one extra leading grid axis
    selecting the expert. Every operand block carries a singleton expert
    dim (BlockSpec block size 1 on E) that the body squeezes away."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _expand_w(codes_ref[0], alpha_ref[0], beta_ref[0], bits=bits, bg=bg)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], w.astype(x_ref.dtype),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _group_geometry(K: int, G: int, block_k: int):
    """Legalize BK against the scale grouping. Returns
    (gs, block_k, bg, gtile) where gs is the group size (0 for
    per-channel), bg the groups per K-tile, and gtile maps the K grid
    index to the alpha/beta tile index along G. Shared by the single-
    matrix and batched-expert entries so both legalize identically."""
    if G == 1:
        return 0, block_k, 1, lambda k: 0
    if K % G:
        raise ValueError(f"G={G} scale groups must divide K={K}")
    gs = K // G
    if gs % WORD:
        raise ValueError(
            f"group_size={gs} must be a multiple of {WORD} for the "
            f"packed kernel (use the jnp reference path otherwise)")
    if gs < block_k:
        # several whole groups per K-tile: round BK down to a group
        # multiple (stays >= gs >= 32)
        block_k = block_k - block_k % gs
    elif gs % block_k:
        # group spans tiles but doesn't divide evenly: shrink BK to
        # the largest common divisor (a multiple of 32, since both
        # are) so every K-tile stays inside one group
        block_k = math.gcd(gs, block_k)
    if gs <= block_k:
        return gs, block_k, block_k // gs, lambda k: k
    tiles_per_group = gs // block_k
    return gs, block_k, 1, lambda k: k // tiles_per_group


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def bcq_matmul(x, codes, alphas, betas, *, block_m=BLOCK_M, block_n=BLOCK_N,
               block_k=BLOCK_K, interpret=False):
    """x (M, K) with K % 32 == 0; codes (bits, K/32, N); alphas
    (G, N, bits); betas (G, N) with G == 1 (per-channel) or G dividing K
    into contiguous groups whose size is a multiple of 32. Returns
    (M, N) in x.dtype. Pads M/N/K to block multiples.
    """
    M, K = x.shape
    bits, KW, N = codes.shape
    G = alphas.shape[0]
    assert KW * WORD == K, (K, KW)
    assert alphas.shape == (G, N, bits), alphas.shape
    assert betas.shape == (G, N), betas.shape

    gs, block_k, bg, gtile = _group_geometry(K, G, block_k)

    # block height must stay a multiple of the 8-sublane tile: round the
    # small-M shortcut up (e.g. M=100 -> bm=104, not 100)
    bm = min(block_m, -(-max(SUBLANE, M) // SUBLANE) * SUBLANE)
    Mp = -(-M // bm) * bm
    Np = -(-N // block_n) * block_n
    Kp = -(-K // block_k) * block_k
    if Mp != M or Kp != K:
        x = jnp.pad(x, ((0, Mp - M), (0, Kp - K)))
    if Np != N or Kp != K:
        codes = jnp.pad(codes, ((0, 0), (0, (Kp - K) // WORD), (0, Np - N)))
        Gp = Kp // gs if gs else 1
        alphas = jnp.pad(alphas, ((0, Gp - G), (0, Np - N), (0, 0)))
        betas = jnp.pad(betas, ((0, Gp - G), (0, Np - N)))

    nk = Kp // block_k
    grid = (Mp // bm, Np // block_n, nk)

    a_index = lambda i, j, k: (gtile(k), j, 0)           # K-tile -> groups
    b_index = lambda i, j, k: (gtile(k), j)              # [k*bg, (k+1)*bg)

    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits, nk=nk, bg=bg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((bits, block_k // WORD, block_n),
                         lambda i, j, k: (0, k, j)),
            pl.BlockSpec((bg, block_n, bits), a_index),
            pl.BlockSpec((bg, block_n), b_index),
        ],
        out_specs=pl.BlockSpec((bm, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, codes, alphas, betas)
    return out[:M, :N]


def bcq_gemv(x, codes, alphas, betas, *, block_n=GEMV_BLOCK_N,
             block_k=GEMV_BLOCK_K, interpret=False):
    """Decode-shaped variant: tiny M (1..8 rows). Pads M to the 8-sublane
    tile and uses wider N/K blocks (the op is bandwidth-bound: the packed
    codes dominate bytes; x and y are negligible)."""
    return bcq_matmul(x, codes, alphas, betas, block_m=SUBLANE,
                      block_n=block_n, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def bcq_expert_matmul(x, codes, alphas, betas, *, block_m=BLOCK_M,
                      block_n=BLOCK_N, block_k=BLOCK_K, interpret=False):
    """Batched-expert GEMM: one launch covers an MoE layer's whole
    expert stack instead of E separate dispatches (or a full dequant of
    every expert's W). x (E, M, K); codes (E, bits, K/32, N); alphas
    (E, G, N, bits); betas (E, G, N). Returns (E, M, N) in x.dtype.

    The expert axis becomes a leading parallel grid dimension with block
    size 1: each (e, i, j, k) step streams expert e's packed K-tile into
    VMEM and runs the same expand-then-one-GEMM body as `bcq_matmul`
    (the kernel squeezes the singleton expert dim). Group legalization,
    padding and the fp32 accumulator are shared with the single-matrix
    entry, so the two stay numerically identical per expert.
    """
    E, M, K = x.shape
    bits, KW, N = codes.shape[-3:]
    G = alphas.shape[1]
    assert KW * WORD == K, (K, KW)
    assert codes.shape == (E, bits, KW, N), codes.shape
    assert alphas.shape == (E, G, N, bits), alphas.shape
    assert betas.shape == (E, G, N), betas.shape

    gs, block_k, bg, gtile = _group_geometry(K, G, block_k)

    bm = min(block_m, -(-max(SUBLANE, M) // SUBLANE) * SUBLANE)
    Mp = -(-M // bm) * bm
    Np = -(-N // block_n) * block_n
    Kp = -(-K // block_k) * block_k
    if Mp != M or Kp != K:
        x = jnp.pad(x, ((0, 0), (0, Mp - M), (0, Kp - K)))
    if Np != N or Kp != K:
        codes = jnp.pad(
            codes, ((0, 0), (0, 0), (0, (Kp - K) // WORD), (0, Np - N)))
        Gp = Kp // gs if gs else 1
        alphas = jnp.pad(alphas, ((0, 0), (0, Gp - G), (0, Np - N), (0, 0)))
        betas = jnp.pad(betas, ((0, 0), (0, Gp - G), (0, Np - N)))

    nk = Kp // block_k
    grid = (E, Mp // bm, Np // block_n, nk)

    out = pl.pallas_call(
        functools.partial(_expert_kernel, bits=bits, nk=nk, bg=bg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, block_k), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bits, block_k // WORD, block_n),
                         lambda e, i, j, k: (e, 0, k, j)),
            pl.BlockSpec((1, bg, block_n, bits),
                         lambda e, i, j, k: (e, gtile(k), j, 0)),
            pl.BlockSpec((1, bg, block_n),
                         lambda e, i, j, k: (e, gtile(k), j)),
        ],
        out_specs=pl.BlockSpec((1, bm, block_n),
                               lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, codes, alphas, betas)
    return out[:, :M, :N]
