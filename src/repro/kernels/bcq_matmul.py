"""Pallas TPU kernel: dequant-fused binary-coded GEMM.

Computes y = x @ W where W[k, n] = sum_i alphas[n, i] * s_i[k, n] + betas[n]
and the sign bitplanes s_i are packed 32-per-uint32 along K. The packed
codes (bits/16 of the bf16 bytes at 3-bit) stream HBM->VMEM tile by tile;
each tile is expanded to a dense (BK, BN) weight tile *in VMEM* and fed to
the MXU as one bf16 GEMM — the TPU-native replacement for GPU LUT-GEMM
(DESIGN.md §2). Accumulation over the K grid axis happens in an fp32 VMEM
scratch accumulator.

Layout notes (TPU-friendly):
  x       (M, K)            -> blocks (BM, BK)
  codes   (bits, K/32, N)   -> blocks (bits, BK/32, BN); K is the
                               second-minor dim so unpacking expands
                               sublanes, keeping N on the 128-wide lane dim
  alphas  (1, N, bits)      -> (1, BN, bits)  [per-output-channel, G=1]
  betas   (1, N)            -> (1, BN)
All MXU dims (BM, BN, BK) default to multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

WORD = 32

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _kernel(x_ref, codes_ref, alpha_ref, beta_ref, o_ref, acc_ref, *,
            bits: int, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = codes_ref[...]                               # (bits, BK/32, BN)
    bk32, bn = codes.shape[1], codes.shape[2]
    shifts = jax.lax.broadcasted_iota(
        jnp.uint32, (1, 1, WORD, 1), 2)                  # (1,1,32,1)
    planes = (codes[:, :, None, :] >> shifts) & jnp.uint32(1)
    planes = planes.reshape(bits, bk32 * WORD, bn).astype(jnp.float32)
    signs = 2.0 * planes - 1.0                           # (bits, BK, BN)

    w = jnp.broadcast_to(beta_ref[0][None, :], signs.shape[1:]).astype(jnp.float32)
    for i in range(bits):                                # static unroll
        w = w + alpha_ref[0, :, i][None, :] * signs[i]

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w.astype(x_ref.dtype),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def bcq_matmul(x, codes, alphas, betas, *, block_m=128, block_n=256,
               block_k=256, interpret=False):
    """x (M, K) with K % 32 == 0; codes (bits, K/32, N); alphas (1, N, bits);
    betas (1, N). Returns (M, N) in x.dtype. Pads M/N/K to block multiples.
    """
    M, K = x.shape
    bits, KW, N = codes.shape
    assert KW * WORD == K, (K, KW)
    assert alphas.shape == (1, N, bits), alphas.shape
    assert betas.shape == (1, N), betas.shape

    # block height must stay a multiple of the 8-sublane tile: round the
    # small-M shortcut up (e.g. M=100 -> bm=104, not 100)
    bm = min(block_m, -(-max(8, M) // 8) * 8)
    Mp = -(-M // bm) * bm
    Np = -(-N // block_n) * block_n
    Kp = -(-K // block_k) * block_k
    if Mp != M or Kp != K:
        x = jnp.pad(x, ((0, Mp - M), (0, Kp - K)))
    if Np != N or Kp != K:
        codes = jnp.pad(codes, ((0, 0), (0, (Kp - K) // WORD), (0, Np - N)))
        alphas = jnp.pad(alphas, ((0, 0), (0, Np - N), (0, 0)))
        betas = jnp.pad(betas, ((0, 0), (0, Np - N)))

    nk = Kp // block_k
    grid = (Mp // bm, Np // block_n, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((bits, block_k // WORD, block_n),
                         lambda i, j, k: (0, k, j)),
            pl.BlockSpec((1, block_n, bits), lambda i, j, k: (0, j, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, codes, alphas, betas)
    return out[:M, :N]


def bcq_gemv(x, codes, alphas, betas, *, block_n=512, block_k=512,
             interpret=False):
    """Decode-shaped variant: tiny M (1..8 rows). Pads M to the 8-sublane
    tile and uses wider N/K blocks (the op is bandwidth-bound: the packed
    codes dominate bytes; x and y are negligible)."""
    return bcq_matmul(x, codes, alphas, betas, block_m=8,
                      block_n=block_n, block_k=block_k, interpret=interpret)
