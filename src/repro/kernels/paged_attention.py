"""Pallas TPU kernel: batched single-token paged-attention decode.

K/V live in a global page pool `(n_pages, page_size, Hkv, hd)` shared by
every sequence; each sequence owns a row of a block table `(B, T)` of
page ids (see serve/kv_cache.py). The grid is (batch, pages-per-seq):
for each sequence the kernel streams its pages HBM->VMEM one per grid
step — the page id comes from the *scalar-prefetched* block table, so
the DMA address is known before the body runs — and folds each page
into an online-softmax (flash) accumulator held in VMEM scratch. One
grid row therefore reads exactly ctx_len tokens of K/V instead of a
dense max_len slab, which is what makes decode bandwidth scale with the
*live* tokens (the same argument as the BCQ weight kernel: decode is
bandwidth-bound, so bytes moved == time).

Unused block-table slots MUST hold a valid page id (the allocator keeps
them 0 and reserves page 0 as a never-allocated null page); the kernel
masks their contribution by token index, not by page id.

Off-TPU the public entry runs `interpret=True` (CPU CI); `ref.py` holds
the pure-jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.hw import WORD

NEG_INF = -1e30

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _fold(t, ctx, q, k, v, m_ref, l_ref, acc_ref, *, page_size, scale,
          window, cap):
    """Fold one page of fp32 K/V into the flash accumulator scratch.
    q (Hkv, rep, hd); k/v (page, Hkv, hd)."""
    logits = jnp.einsum("hrd,phd->hrp", q, k,
                        preferred_element_type=jnp.float32) * scale
    if cap is not None:
        logits = cap * jnp.tanh(logits / cap)
    j = t * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, page_size), 2)
    ok = j < ctx
    if window is not None:
        ok &= (ctx - 1 - j) < window
    logits = jnp.where(ok, logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    r = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[..., None])
    l_ref[...] = l_ref[...] * r + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * r[..., None] + jnp.einsum(
        "hrp,phd->hrd", p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _kernel(bt_ref, cl_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, page_size: int, pages_per_seq: int, scale: float,
            window, cap):
    b = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ctx = cl_ref[b]

    @pl.when(t * page_size < ctx)
    def _fold_page():
        _fold(t, ctx, q_ref[0].astype(jnp.float32),
              k_ref[0].astype(jnp.float32), v_ref[0].astype(jnp.float32),
              m_ref, l_ref, acc_ref, page_size=page_size, scale=scale,
              window=window, cap=cap)

    @pl.when(t == pages_per_seq - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _expand_page(codes, alphas, betas, hd: int):
    """VMEM dequant of one binary-coded page (the bcq_matmul expand,
    re-oriented for the KV layout): codes (page, Hkv, bits, hd/32) u32,
    alphas (page, Hkv, G, bits), betas (page, Hkv, G) -> fp32
    (page, Hkv, hd). Shift-unpack the sign bitplanes, then a statically
    unrolled per-bit multiply-add over the group-broadcast alphas."""
    page, Hkv, bits, hdw = codes.shape
    G = betas.shape[-1]
    gs = hd // G
    shifts = jax.lax.broadcasted_iota(jnp.uint32,
                                      (1, 1, 1, 1, WORD), 4)
    planes = (codes[..., None] >> shifts) & jnp.uint32(1)
    signs = (2.0 * planes.astype(jnp.float32) - 1.0).reshape(
        page, Hkv, bits, G, gs)
    acc = jnp.broadcast_to(betas[..., None].astype(jnp.float32),
                           (page, Hkv, G, gs))
    for i in range(bits):
        acc = acc + alphas[..., i, None].astype(jnp.float32) * \
            signs[:, :, i]
    return acc.reshape(page, Hkv, hd)


def _kernel_quant(bt_ref, cl_ref, q_ref, kc_ref, ka_ref, kb_ref, vc_ref,
                  va_ref, vb_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  page_size: int, pages_per_seq: int, scale: float,
                  window, cap, hd: int):
    b = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ctx = cl_ref[b]

    @pl.when(t * page_size < ctx)
    def _fold_page():
        k = _expand_page(kc_ref[0], ka_ref[0], kb_ref[0], hd)
        v = _expand_page(vc_ref[0], va_ref[0], vb_ref[0], hd)
        _fold(t, ctx, q_ref[0].astype(jnp.float32), k, v,
              m_ref, l_ref, acc_ref, page_size=page_size, scale=scale,
              window=window, cap=cap)

    @pl.when(t == pages_per_seq - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "cap", "interpret"))
def paged_attention(q, k_pages, v_pages, block_tables, ctx_lens, *,
                    window=None, cap=None, interpret=False):
    """q (B, Hkv, rep, hd); k_pages/v_pages (P, page_size, Hkv, hd);
    block_tables (B, T) int32 page ids; ctx_lens (B,) int32 live tokens
    per sequence (including the token just written). Returns
    (B, Hkv, rep, hd) in q.dtype."""
    B, Hkv, rep, hd = q.shape
    _, page_size, _, _ = k_pages.shape
    T = block_tables.shape[1]
    scale = hd ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, T),
        in_specs=[
            pl.BlockSpec((1, Hkv, rep, hd),
                         lambda b, t, bt, cl: (b, 0, 0, 0)),
            pl.BlockSpec((1, page_size, Hkv, hd),
                         lambda b, t, bt, cl: (bt[b, t], 0, 0, 0)),
            pl.BlockSpec((1, page_size, Hkv, hd),
                         lambda b, t, bt, cl: (bt[b, t], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hkv, rep, hd),
                               lambda b, t, bt, cl: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, rep), jnp.float32),       # running max
            pltpu.VMEM((Hkv, rep), jnp.float32),       # running denom
            pltpu.VMEM((Hkv, rep, hd), jnp.float32),   # weighted acc
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, page_size=page_size, pages_per_seq=T,
                          scale=scale, window=window, cap=cap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(block_tables, ctx_lens, q, k_pages, v_pages)


@functools.partial(jax.jit,
                   static_argnames=("window", "cap", "interpret"))
def paged_attention_quant(q, k_codes, k_alphas, k_betas, v_codes,
                          v_alphas, v_betas, block_tables, ctx_lens, *,
                          window=None, cap=None, interpret=False):
    """Fused-dequant paged decode over a binary-coded page pool
    (quant/kv.py layout): q (B, Hkv, rep, hd); codes
    (P, page, Hkv, bits, hd/32) u32; alphas (P, page, Hkv, G, bits);
    betas (P, page, Hkv, G); block_tables (B, T); ctx_lens (B,).

    Same grid/flash structure as `paged_attention`, but each grid step
    streams a page's *codes + scales* HBM->VMEM (bits/8 + scale bytes
    per entry instead of 2-4) and expands them to fp32 inside the
    accumulator loop — the bcq_matmul fusion argument applied to the KV
    pool: decode is bandwidth-bound, so shrinking the pages shrinks the
    time. Returns (B, Hkv, rep, hd) in q.dtype."""
    B, Hkv, rep, hd = q.shape
    _, page_size, _, bits, hdw = k_codes.shape
    G = k_betas.shape[-1]
    T = block_tables.shape[1]
    scale = hd ** -0.5

    def page_spec(shape):
        return pl.BlockSpec((1,) + shape,
                            lambda b, t, bt, cl:
                            (bt[b, t],) + (0,) * len(shape))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, T),
        in_specs=[
            pl.BlockSpec((1, Hkv, rep, hd),
                         lambda b, t, bt, cl: (b, 0, 0, 0)),
            page_spec((page_size, Hkv, bits, hdw)),   # k codes
            page_spec((page_size, Hkv, G, bits)),     # k alphas
            page_spec((page_size, Hkv, G)),           # k betas
            page_spec((page_size, Hkv, bits, hdw)),   # v codes
            page_spec((page_size, Hkv, G, bits)),     # v alphas
            page_spec((page_size, Hkv, G)),           # v betas
        ],
        out_specs=pl.BlockSpec((1, Hkv, rep, hd),
                               lambda b, t, bt, cl: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, rep), jnp.float32),       # running max
            pltpu.VMEM((Hkv, rep), jnp.float32),       # running denom
            pltpu.VMEM((Hkv, rep, hd), jnp.float32),   # weighted acc
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel_quant, page_size=page_size,
                          pages_per_seq=T, scale=scale, window=window,
                          cap=cap, hd=hd),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(block_tables, ctx_lens, q, k_codes, k_alphas, k_betas,
      v_codes, v_alphas, v_betas)
