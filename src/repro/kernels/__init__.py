"""Pallas TPU kernels for the perf-critical hot spots: binary-coded GEMM
(bcq_matmul / bcq_gemv) with ops.py dispatch, paged-attention decode
(paged_attention), and ref.py oracles."""
from repro.kernels import ops, ref
from repro.kernels.bcq_matmul import bcq_gemv, bcq_matmul
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_quant)

__all__ = ["ops", "ref", "bcq_matmul", "bcq_gemv", "paged_attention",
           "paged_attention_quant"]
