"""Pallas TPU kernels for the perf-critical hot spot: binary-coded GEMM
(bcq_matmul / bcq_gemv) with ops.py dispatch and ref.py oracles."""
from repro.kernels import ops, ref
from repro.kernels.bcq_matmul import bcq_gemv, bcq_matmul

__all__ = ["ops", "ref", "bcq_matmul", "bcq_gemv"]
