"""Pure-jnp oracles for the binary-coded GEMM kernels.

`bcq_matmul_ref` is the correctness reference (dequantize, then matmul).
`bcq_matmul_bitplane_ref` is the GPU-LUT-GEMM-style reassociation
    y = sum_i alpha_i * (x @ S_i) + (sum_k x) * beta
— mathematically identical, but it costs `bits` MXU passes instead of
one; we keep it to *demonstrate* why the TPU adaptation fuses dequant
into a single GEMM instead (see DESIGN.md §2 and benchmarks/table4).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.quant.packing import unpack_signs


def dequant_ref(codes, alphas, betas, k_in: int, dtype=jnp.float32):
    """codes (bits, K/32, N) u32; alphas (G, N, bits); betas (G, N)
    -> W (k_in, N)."""
    signs = unpack_signs(codes, k_in)                    # (bits, K, N)
    G = alphas.shape[0]
    glen = -(-k_in // G)
    a = jnp.repeat(alphas, glen, axis=0)[:k_in]          # (K, N, bits)
    b = jnp.repeat(betas, glen, axis=0)[:k_in]           # (K, N)
    w = jnp.einsum("ikn,kni->kn", signs, a) + b
    return w.astype(dtype)


def bcq_matmul_ref(x, codes, alphas, betas, k_in: int):
    """x (..., k_in) -> (..., N)."""
    w = dequant_ref(codes, alphas, betas, k_in, dtype=jnp.float32)
    return jnp.einsum("...k,kn->...n", x.astype(jnp.float32), w).astype(x.dtype)


def bcq_matmul_bitplane_ref(x, codes, alphas, betas, k_in: int):
    """Per-bitplane reassociation (G=1 only)."""
    assert alphas.shape[0] == 1
    signs = unpack_signs(codes, k_in)                    # (bits, K, N)
    xf = x.astype(jnp.float32)
    acc = jnp.zeros((*x.shape[:-1], codes.shape[-1]), jnp.float32)
    for i in range(codes.shape[0]):
        acc = acc + alphas[0, :, i] * jnp.einsum("...k,kn->...n", xf, signs[i])
    acc = acc + jnp.sum(xf, axis=-1, keepdims=True) * betas[0]
    return acc.astype(x.dtype)
