"""Pure-jnp oracles for the Pallas kernels.

`bcq_matmul_ref` is the correctness reference (dequantize, then matmul).
`bcq_matmul_bitplane_ref` is the GPU-LUT-GEMM-style reassociation
    y = sum_i alpha_i * (x @ S_i) + (sum_k x) * beta
— mathematically identical, but it costs `bits` MXU passes instead of
one; we keep it to *demonstrate* why the TPU adaptation fuses dequant
into a single GEMM instead (see DESIGN.md §2 and benchmarks/table4).

`paged_attention_ref` is the oracle for kernels/paged_attention.py and
also the non-TPU execution path for paged decode: it gathers each
sequence's pages through the block table and runs the same masked
softmax the dense `attn_decode` uses, so CPU tests can compare paged vs
dense decode token-for-token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.packing import unpack_signs

NEG_INF = -1e30


def dequant_ref(codes, alphas, betas, k_in: int, dtype=jnp.float32):
    """codes (bits, K/32, N) u32; alphas (G, N, bits); betas (G, N)
    -> W (k_in, N). Group g's scales cover K rows [g*ceil(k_in/G),
    ...): exact contiguous groups when G divides k_in (the
    QuantizedTensor invariant), ragged-tail semantics otherwise."""
    signs = unpack_signs(codes, k_in)                    # (bits, K, N)
    G = alphas.shape[0]
    glen = -(-k_in // G)
    # scales may be bf16 in memory (packed artifacts); expand in fp32
    a = jnp.repeat(alphas.astype(jnp.float32),
                   glen, axis=0)[:k_in]                  # (K, N, bits)
    b = jnp.repeat(betas.astype(jnp.float32),
                   glen, axis=0)[:k_in]                  # (K, N)
    w = jnp.einsum("ikn,kni->kn", signs, a) + b
    return w.astype(dtype)


def bcq_matmul_ref(x, codes, alphas, betas, k_in: int):
    """x (..., k_in) -> (..., N)."""
    w = dequant_ref(codes, alphas, betas, k_in, dtype=jnp.float32)
    return jnp.einsum("...k,kn->...n", x.astype(jnp.float32), w).astype(x.dtype)


def bcq_gemv_ref(x, codes, alphas, betas, k_in: int):
    """Oracle for the decode-shaped kernel entry: same math as the GEMM
    (the gemv only retiles), so the reference is shared."""
    return bcq_matmul_ref(x, codes, alphas, betas, k_in)


def bcq_expert_matmul_ref(x, codes, alphas, betas, k_in: int):
    """Oracle for the batched-expert kernel: x (E, M, k_in); codes
    (E, bits, K/32, N); alphas (E, G, N, bits); betas (E, G, N)
    -> (E, M, N). Dequantize every expert (vmapped single-expert
    oracle), then one batched matmul."""
    w = jax.vmap(
        lambda c, a, b: dequant_ref(c, a, b, k_in, dtype=jnp.float32))(
        codes, alphas, betas)                            # (E, k_in, N)
    return jnp.einsum("emk,ekn->emn", x.astype(jnp.float32),
                      w).astype(x.dtype)


def _paged_attend(q, k, v, ctx_lens, *, window, cap):
    """Decode-time masked softmax over already-gathered K/V:
    q (B, Hkv, rep, hd); k/v (B, Hkv, K, hd); ctx_lens (B,)."""
    hd = q.shape[-1]
    logits = jnp.einsum("bhrd,bhkd->bhrk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    if cap is not None:
        logits = cap * jnp.tanh(logits / cap)
    j = jnp.arange(k.shape[2])[None, :]
    ok = j < ctx_lens[:, None]
    if window is not None:
        ok &= (ctx_lens[:, None] - 1 - j) < window
    logits = jnp.where(ok[:, None, None, :], logits, NEG_INF)
    w = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    out = jnp.einsum("bhrk,bhkd->bhrd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, ctx_lens, *,
                        window=None, cap=None):
    """q (B, Hkv, rep, hd); k_pages/v_pages (P, page, Hkv, hd);
    block_tables (B, T); ctx_lens (B,). Returns (B, Hkv, rep, hd)."""
    B, Hkv, rep, hd = q.shape
    page = k_pages.shape[1]
    T = block_tables.shape[1]
    # gather: (B, T, page, Hkv, hd) -> (B, Hkv, T*page, hd)
    k = k_pages[block_tables].reshape(B, T * page, Hkv, hd)
    v = v_pages[block_tables].reshape(B, T * page, Hkv, hd)
    return _paged_attend(q, k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), ctx_lens,
                         window=window, cap=cap)


def paged_attention_quant_ref(q, k_codes, k_alphas, k_betas, v_codes,
                              v_alphas, v_betas, block_tables, ctx_lens,
                              *, window=None, cap=None):
    """Oracle for the fused-dequant kernel, and the non-TPU execution
    path for quantized paged decode: gather each sequence's binary-coded
    pages through the block table, expand codes -> fp32 K/V
    (quant/kv.py layout: codes (P, page, Hkv, bits, hd/32) u32, alphas
    (P, page, Hkv, G, bits), betas (P, page, Hkv, G)), then the same
    masked softmax as paged_attention_ref."""
    from repro.quant.kv import kv_dequantize

    B, Hkv, rep, hd = q.shape
    page = k_codes.shape[1]
    T = block_tables.shape[1]
    k = kv_dequantize(k_codes[block_tables], k_alphas[block_tables],
                      k_betas[block_tables])       # (B, T, page, Hkv, hd)
    v = kv_dequantize(v_codes[block_tables], v_alphas[block_tables],
                      v_betas[block_tables])
    k = k.reshape(B, T * page, Hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, T * page, Hkv, hd).transpose(0, 2, 1, 3)
    return _paged_attend(q, k, v, ctx_lens, window=window, cap=cap)


def bcq_matmul_bitplane_ref(x, codes, alphas, betas, k_in: int):
    """Per-bitplane reassociation (G=1 only)."""
    assert alphas.shape[0] == 1
    signs = unpack_signs(codes, k_in)                    # (bits, K, N)
    xf = x.astype(jnp.float32)
    acc = jnp.zeros((*x.shape[:-1], codes.shape[-1]), jnp.float32)
    for i in range(codes.shape[0]):
        acc = acc + alphas[0, :, i] * jnp.einsum("...k,kn->...n", xf, signs[i])
    acc = acc + jnp.sum(xf, axis=-1, keepdims=True) * betas[0]
    return acc.astype(x.dtype)
