"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production mesh, record memory/cost/collective
analysis for the roofline report.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all
  python -m repro.launch.dryrun --arch jamba-1.5-large-398b --shape decode_32k --quant 3

Artifacts: artifacts/dryrun/{arch}__{shape}__{mesh}[__w{bits}].json

NOTE: the XLA_FLAGS assignment below MUST run before any jax import —
jax locks the device count on first initialization.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import functools
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, ASSIGNED, get_config, runnable_shapes
from repro.dist.context import mesh_context
from repro.dist.sharding import (cache_shardings, inputs_shardings,
                                 last_logits_sharding, opt_state_shardings,
                                 params_shardings, batch_pspec)
from repro.launch.mesh import make_production_mesh
from repro.models.model import decode_step, init_cache, init_params, prefill
from repro.quant.abstract import packed_param_bytes, quantize_params_abstract
from repro.roofline.analysis import (model_flops, parse_collectives,
                                     roofline_terms)
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _cost_dict(compiled):
    """compiled.cost_analysis() returns a per-computation list on older
    jax (<=0.4.x) and a flat dict on newer; normalize to the dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


class CollStub:
    """CollectiveStats-shaped container for extrapolated probe results."""

    def __init__(self, wire_bytes, by_op, count, top=None):
        self.total_wire_bytes = wire_bytes
        self.by_op = by_op
        self.count = count
        self.top = top or []


# --------------------------------------------------------------------------
# abstract inputs
# --------------------------------------------------------------------------

def input_specs(cfg, shape_spec):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape_spec.global_batch, shape_spec.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.embed_input == "tokens":
        inputs = sds((B, S), jnp.int32)
    else:
        inputs = sds((B, S, cfg.d_model), jnp.bfloat16)
    if shape_spec.kind == "train":
        return {"inputs": inputs, "labels": sds((B, S), jnp.int32)}
    if shape_spec.kind == "prefill":
        return {"inputs": inputs}
    # decode: one new token against a cache of length S
    return {"tokens": sds((B, 1), jnp.int32), "pos": sds((B,), jnp.int32)}


def abstract_params(cfg):
    return jax.eval_shape(
        functools.partial(init_params, cfg, dtype=jnp.bfloat16),
        jax.random.PRNGKey(0))


def abstract_cache(cfg, B, S):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, B, S, dtype=jnp.bfloat16))


# --------------------------------------------------------------------------
# cell lowering
# --------------------------------------------------------------------------

def lower_cell(cfg, shape_spec, mesh, quant_bits=None, microbatches=1,
               remat=None, fsdp=True):
    """Returns (lowered, meta). Never allocates device memory for the
    full model (ShapeDtypeStruct only)."""
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    p_abs = abstract_params(cfg)
    meta = {"params_bytes_bf16": packed_param_bytes(p_abs)}
    specs = input_specs(cfg, shape_spec)

    if shape_spec.kind == "train":
        p_sh = params_shardings(cfg, p_abs, mesh, fsdp=fsdp)
        opt_abs = jax.eval_shape(
            functools.partial(init_train_state, cfg,
                              opt_cfg=AdamWConfig()), p_abs)
        o_sh = opt_state_shardings(cfg, opt_abs, mesh, fsdp=fsdp)
        in_sh = inputs_shardings(cfg, mesh, shape_spec)
        step = make_train_step(cfg, AdamWConfig(), microbatches=microbatches)
        fn = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, in_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1))
        lowered = fn.lower(p_abs, opt_abs, specs)
        meta["state_bytes"] = packed_param_bytes(opt_abs)
        return lowered, meta

    # inference: optionally quantized weights
    if quant_bits:
        p_abs = quantize_params_abstract(cfg, p_abs, quant_bits)
        meta["params_bytes_packed"] = packed_param_bytes(p_abs)
    p_sh = params_shardings(cfg, p_abs, mesh, fsdp=fsdp)

    if shape_spec.kind == "prefill":
        in_sh = inputs_shardings(cfg, mesh, shape_spec)["inputs"]
        c_abs = abstract_cache(cfg, shape_spec.global_batch,
                               shape_spec.seq_len)
        c_sh = cache_shardings(cfg, c_abs, mesh)
        lg_sh = last_logits_sharding(cfg, mesh, shape_spec.global_batch)
        fn = jax.jit(
            functools.partial(prefill, cfg, max_len=shape_spec.seq_len),
            in_shardings=(p_sh, in_sh),
            out_shardings=(lg_sh, c_sh))
        lowered = fn.lower(p_abs, specs["inputs"])
        meta["cache_bytes"] = packed_param_bytes(c_abs)
        return lowered, meta

    # decode
    B, S = shape_spec.global_batch, shape_spec.seq_len
    c_abs = abstract_cache(cfg, B, S)
    c_sh = cache_shardings(cfg, c_abs, mesh)
    tok_sh = jax.NamedSharding(mesh, batch_pspec(mesh, B))
    pos_sh = jax.NamedSharding(mesh, batch_pspec(mesh, B, ()))
    lg_sh = last_logits_sharding(cfg, mesh, B)
    fn = jax.jit(
        functools.partial(decode_step, cfg),
        in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
        out_shardings=(lg_sh, c_sh),
        donate_argnums=(1,))
    lowered = fn.lower(p_abs, c_abs, specs["tokens"], specs["pos"])
    meta["cache_bytes"] = packed_param_bytes(c_abs)
    return lowered, meta


def _probe_costs(cfg, shape_spec, mesh, groups, **kw):
    """Compile an unrolled `groups`-group model and return flat metrics.
    XLA cost analysis counts while-loop bodies once, so probes unroll
    EVERY scan: the over-groups scan (n_layers = groups * pattern),
    the attention kv-chunk scan and the mamba chunk scan (with coarser
    chunks so the unroll stays compilable); per-step cost is then
    base + n_groups * delta over the 2-/3-group probes."""
    import dataclasses as _dc

    from repro.models import attention as _attn
    from repro.models import mamba as _mam

    pcfg = cfg.replace(n_layers=groups * len(cfg.pattern), scan_unroll=True)
    S = shape_spec.seq_len
    if pcfg.mamba is not None and shape_spec.kind != "decode":
        pcfg = pcfg.replace(mamba=_dc.replace(pcfg.mamba,
                                              chunk=max(256, S // 8)))
    old_kv, old_au, old_mu = _attn.KV_CHUNK, _attn.FORCE_UNROLL, _mam.FORCE_UNROLL
    _attn.KV_CHUNK = max(1024, S // 8)
    _attn.FORCE_UNROLL = True
    _mam.FORCE_UNROLL = True
    try:
        lowered, _ = lower_cell(pcfg, shape_spec, mesh, **kw)
        compiled = lowered.compile()
    finally:
        _attn.KV_CHUNK, _attn.FORCE_UNROLL = old_kv, old_au
        _mam.FORCE_UNROLL = old_mu
    cost = _cost_dict(compiled)
    coll = parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire_bytes": coll.total_wire_bytes,
        "coll_count": coll.count,
        "by_op": coll.by_op,
        "top": coll.top,
    }


def _extrapolate(c2, c3, n_groups):
    """Probes at 2 and 3 groups (the 1-group point sits outside the
    linear region: the partitioner makes different global choices there).
    delta = c3 - c2; base = c2 - 2*delta; total = base + n_groups*delta."""
    out = {}
    for k in ("flops", "bytes", "wire_bytes"):
        delta = max(c3[k] - c2[k], 0.0)
        base = max(c2[k] - 2.0 * delta, 0.0)
        out[k] = base + n_groups * delta
    out["coll_count_per_group"] = max(c3["coll_count"] - c2["coll_count"], 0)
    return out


def run_cell(arch, shape_name, *, multi_pod=False, quant_bits=None,
             microbatches=1, remat=None, fsdp=True, save=True, tag="",
             probe=True):
    cfg = get_config(arch)
    shape_spec = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_dev = 512 if multi_pod else 256
    t0 = time.time()
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "quant_bits": quant_bits, "microbatches": microbatches,
        "n_devices": n_dev, "ok": False,
    }
    kw = dict(quant_bits=quant_bits, microbatches=microbatches,
              remat=remat, fsdp=fsdp)
    try:
        with mesh_context(mesh):
            # (a) full-depth scanned model: the compile-validation +
            # memory-analysis artifact.
            lowered, meta = lower_cell(cfg, shape_spec, mesh, **kw)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = _cost_dict(compiled)
            coll = parse_collectives(compiled.as_text())
            # (b, c) unrolled probes for trip-count-correct costs
            if probe:
                c2 = _probe_costs(cfg, shape_spec, mesh, 2, **kw)
                c3 = _probe_costs(cfg, shape_spec, mesh, 3, **kw)
                ex = _extrapolate(c2, c3, cfg.n_groups)
                cost = {"flops": ex["flops"], "bytes accessed": ex["bytes"]}
                coll = CollStub(ex["wire_bytes"],
                                {"probe_2g": c2["by_op"],
                                 "probe_3g": c3["by_op"]},
                                c3["coll_count"], top=c3.get("top"))
        result.update(meta)
        result.update({
            "ok": True,
            "t_lower_s": round(t_lower, 2),
            "t_compile_s": round(t_compile, 2),
            "probe": probe,
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_live_bytes_est": (mem.argument_size_in_bytes
                                        + mem.output_size_in_bytes
                                        + mem.temp_size_in_bytes
                                        - mem.alias_size_in_bytes),
            },
            "collectives": {"wire_bytes": coll.total_wire_bytes,
                            "count": coll.count, "by_op": coll.by_op,
                            "top": [(f"{b:.3e}", op, ln)
                                    for b, op, ln in coll.top]},
            "roofline": roofline_terms(cost or {}, coll),
            "model_flops_global": model_flops(cfg, shape_spec),
        })
        r = result["roofline"]
        mf_dev = result["model_flops_global"] / n_dev
        r["model_flops_per_device"] = mf_dev
        r["useful_flops_ratio"] = (mf_dev / r["flops_per_device"]
                                   if r["flops_per_device"] else 0.0)
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    if save:
        ARTIFACTS.mkdir(parents=True, exist_ok=True)
        q = f"__w{quant_bits}" if quant_bits else ""
        tg = f"__{tag}" if tag else ""
        out = ARTIFACTS / f"{arch}__{shape_name}__{mesh_name}{q}{tg}.json"
        out.write_text(json.dumps(result, indent=1, default=str))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--quant", type=int, default=None,
                    help="GPTQT weight bits for inference cells")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the cost-extrapolation probes (compile "
                         "validation only; multipod sweeps use this: the "
                         "roofline table is single-pod per the spec)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch, cfg in ASSIGNED.items():
            for s in runnable_shapes(cfg):
                cells.append((arch, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_ok = 0
    for arch, shape in cells:
        r = run_cell(arch, shape, multi_pod=args.multipod,
                     quant_bits=args.quant, microbatches=args.microbatches,
                     remat=args.remat, fsdp=not args.no_fsdp, tag=args.tag,
                     probe=not args.no_probe)
        status = "OK " if r["ok"] else "FAIL"
        extra = ""
        if r["ok"]:
            rf = r["roofline"]
            extra = (f"bound={rf['bound']:10s} "
                     f"tC={rf['t_compute_s']:.3e} tM={rf['t_memory_s']:.3e} "
                     f"tX={rf['t_collective_s']:.3e} "
                     f"compile={r['t_compile_s']:.1f}s")
            n_ok += 1
        else:
            extra = r["error"][:160]
        print(f"[{status}] {arch:26s} {shape:12s} {r['mesh']:8s} {extra}",
              flush=True)
    print(f"{n_ok}/{len(cells)} cells OK")
    return 0 if n_ok == len(cells) else 1


if __name__ == "__main__":
    raise SystemExit(main())
