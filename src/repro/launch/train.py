"""Production training launcher: mesh-aware pjit train loop with
checkpoint/auto-resume. On a real TPU slice this is launched once per
host (jax.distributed initializes from the TPU environment); in this
container it runs on the 1-device host mesh with the same code path.

  PYTHONPATH=src python -m repro.launch.train --arch tiny-lm --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.ckpt import CheckpointManager
from repro.data import batches, token_stream
from repro.dist.sharding import (inputs_shardings, opt_state_shardings,
                                 params_shardings)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import init_params
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-lm")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", choices=["host", "pod", "multipod"],
                    default="host")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--ckpt-dir", default="artifacts/launch_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args()

    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")

    cfg = get_config(args.arch).replace(dtype=args.dtype)
    opt_cfg = AdamWConfig(lr=1e-3, master_fp32=args.dtype == "bfloat16")
    toks = token_stream("wiki", 400_000)
    data = batches(toks, args.batch, args.seq, seed=0)

    with mesh:
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        opt_state = init_train_state(cfg, params, opt_cfg)
        p_sh = params_shardings(cfg, params, mesh)
        o_sh = opt_state_shardings(cfg, opt_state, mesh)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)

        ckpt = CheckpointManager(args.ckpt_dir)
        start = 0
        if ckpt.latest_step() is not None:
            state, meta = ckpt.restore({"params": params, "opt": opt_state})
            params = jax.device_put(state["params"], p_sh)
            opt_state = jax.device_put(state["opt"], o_sh)
            start = meta["step"]
            print(f"resumed from step {start}")

        step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, microbatches=args.microbatches,
                            grad_compress=args.grad_compress,
                            total_steps=args.steps),
            in_shardings=(p_sh, o_sh, None),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1))

        for step in range(start, args.steps):
            batch = next(data)
            t0 = time.time()
            params, opt_state, m = step_fn(params, opt_state, batch)
            loss = float(m["loss"])
            if (step + 1) % 10 == 0:
                print(f"step {step+1:5d} loss {loss:.4f} "
                      f"({time.time()-t0:.2f}s)", flush=True)
            if (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state})
        ckpt.save(args.steps, {"params": params, "opt": opt_state},
                  block=True)
    print("training complete")


if __name__ == "__main__":
    main()
