"""Serving launcher: loads (or trains) a model, optionally GPTQT-quantizes
it, and serves a demo request batch through the continuous-batching
engine.

  PYTHONPATH=src python -m repro.launch.serve --quant 3 --requests 6
"""
from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-lm")
    ap.add_argument("--quant", type=int, default=0,
                    help="GPTQT bits (0 = dense)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch-size", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    from benchmarks.common import calib_batches_for
    from repro.core import quantize_model
    from repro.data import ByteTokenizer
    from repro.data.pretrained import get_trained_lm
    from repro.serve import Request, ServeEngine

    cfg, params = get_trained_lm(args.arch)
    tok = ByteTokenizer()
    if args.quant:
        print(f"quantizing with GPTQT to {args.quant} bits (packed) ...")
        params, _ = quantize_model(
            cfg, params, calib_batches_for("wiki"), method="gptqt",
            qcfg=cfg.quant.__class__(bits=args.quant), mode="packed")

    eng = ServeEngine(cfg, params, batch_size=args.batch_size,
                      max_len=160, dtype="float32")
    seeds = ["the ancient city", "a famous museum", "this railway",
             "the council", "another region", "the early dynasty"]
    reqs = [Request(prompt=tok.encode(seeds[i % len(seeds)]),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    eng.run(reqs)
    tput = eng.stats["tokens"] / max(eng.stats["decode_s"], 1e-9)
    print(f"served {len(reqs)} requests, {eng.stats['tokens']} tokens, "
          f"decode throughput {tput:.1f} tok/s (CPU)")
    for r in reqs[:3]:
        print(" ", repr(tok.decode(r.out)))


if __name__ == "__main__":
    main()
