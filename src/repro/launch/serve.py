"""Serving launcher: loads (or trains) a model, optionally GPTQT-quantizes
it, and serves a demo request batch through the continuous-batching
engine. Quantized models persist as packed artifacts (repro.ckpt.packed)
so a relaunch boots without re-running calibration or the GPTQ solves:

  # quantize once, save the packed artifact, serve
  PYTHONPATH=src python -m repro.launch.serve --quant 3 \\
      --save-quantized artifacts/packed/tiny-w3 --requests 6

  # every later launch: skip calibration/GPTQ entirely
  PYTHONPATH=src python -m repro.launch.serve \\
      --load-quantized artifacts/packed/tiny-w3 --requests 6
"""
from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-lm")
    ap.add_argument("--quant", type=int, default=0,
                    help="quantization bits (0 = dense)")
    ap.add_argument("--method", default="gptqt",
                    help="registered quantizer name (see docs/QUANT.md)")
    ap.add_argument("--save-quantized", default=None, metavar="DIR",
                    help="write the packed model artifact after quantizing")
    ap.add_argument("--load-quantized", default=None, metavar="DIR",
                    help="boot from a packed artifact (skips training, "
                         "calibration and quantization)")
    ap.add_argument("--train-steps", type=int, default=300,
                    help="tiny-LM pretraining steps (ignored with "
                         "--load-quantized)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch-size", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data import ByteTokenizer
    from repro.serve import Request, ServeEngine

    tok = ByteTokenizer()
    if args.load_quantized:
        if args.quant or args.save_quantized:
            ap.error("--load-quantized boots the artifact as-is; it is "
                     "incompatible with --quant/--save-quantized")
        from repro.ckpt.packed import load_packed
        params, spec, meta = load_packed(args.load_quantized)
        arch = meta.get("arch", args.arch)
        # mirror get_trained_lm's config construction; all weights come
        # from the artifact, so no training or calibration happens here
        cfg = get_config(arch).replace(dtype="float32", remat="none")
        desc = (f"{spec.method} w{spec.bits}" if spec is not None
                else "unknown spec")
        print(f"loaded packed model '{arch}' ({desc}) from "
              f"{args.load_quantized} — calibration/GPTQ skipped")
    else:
        from benchmarks.common import calib_batches_for
        from repro.core import quantize_model
        from repro.data.pretrained import get_trained_lm
        from repro.quant import QuantSpec

        cfg, params = get_trained_lm(args.arch, steps=args.train_steps)
        if args.quant:
            spec = QuantSpec.from_config(
                cfg.quant, method=args.method, mode="packed",
                bits=args.quant)
            print(f"quantizing with {spec.method} to {spec.bits} bits "
                  f"(packed) ...")
            params, _ = quantize_model(cfg, params,
                                       calib_batches_for("wiki"), spec=spec)
            if args.save_quantized:
                from repro.ckpt.packed import save_packed
                out = save_packed(args.save_quantized, params, spec=spec,
                                  meta={"arch": args.arch})
                print(f"saved packed artifact to {out}")
        elif args.save_quantized:
            ap.error("--save-quantized requires --quant")

    eng = ServeEngine(cfg, params, batch_size=args.batch_size,
                      max_len=160, dtype="float32")
    seeds = ["the ancient city", "a famous museum", "this railway",
             "the council", "another region", "the early dynasty"]
    reqs = [Request(prompt=tok.encode(seeds[i % len(seeds)]),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    eng.run(reqs)
    tput = eng.stats["tokens"] / max(eng.stats["decode_s"], 1e-9)
    print(f"served {len(reqs)} requests, {eng.stats['tokens']} tokens, "
          f"decode throughput {tput:.1f} tok/s (CPU)")
    for r in reqs[:3]:
        print(" ", repr(tok.decode(r.out)))


if __name__ == "__main__":
    main()
