"""Serving launcher: loads (or trains) a model, optionally GPTQT-quantizes
it, and serves a demo request batch through the continuous-batching
engine. Quantized models persist as packed artifacts (repro.ckpt.packed)
so a relaunch boots without re-running calibration or the GPTQ solves:

  # quantize once, save the packed artifact, serve
  PYTHONPATH=src python -m repro.launch.serve --quant 3 \\
      --save-quantized artifacts/packed/tiny-w3 --requests 6

  # every later launch: skip calibration/GPTQ entirely
  PYTHONPATH=src python -m repro.launch.serve \\
      --load-quantized artifacts/packed/tiny-w3 --requests 6

  # sharded serving: the packed artifact loads straight onto a 2-way
  # data mesh (per-leaf PartitionSpecs from the v3 manifest) and the
  # paged page pool is partitioned over the same axis
  PYTHONPATH=src python -m repro.launch.serve --devices 2 --mesh 2,1 \\
      --load-quantized artifacts/packed/tiny-w3 --requests 6
"""
from __future__ import annotations

import argparse
import os

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-lm")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host (CPU) devices via XLA_FLAGS — a "
                         "laptop-scale stand-in for a real multi-chip "
                         "mesh (must be set before jax initializes, so "
                         "it is a launcher flag)")
    ap.add_argument("--mesh", default=None, metavar="D,M",
                    help="serve over a (data, model) mesh, e.g. 2,1: "
                         "the paged KV pool shards its pages over the "
                         "data axis and --load-quantized places packed "
                         "leaves onto the mesh directly")
    ap.add_argument("--quant", type=int, default=0,
                    help="quantization bits (0 = dense)")
    ap.add_argument("--method", default=None,
                    help="registered quantizer name (default gptqt; see "
                         "docs/QUANT.md)")
    ap.add_argument("--group-size", type=int, default=0,
                    help="K entries per scale group (0 = per-channel); "
                         "must divide every quantized leaf's K_in")
    ap.add_argument("--suggest-overrides", action="store_true",
                    help="run the FineQuant-style sensitivity sweep and "
                         "print a paste-ready OverrideRule tuple instead "
                         "of serving")
    ap.add_argument("--bytes-budget", type=int, default=None,
                    metavar="BYTES",
                    help="with --suggest-overrides: spend this many extra "
                         "checkpoint bytes greedily by error reduction "
                         "per byte (default: bump the top-quantile "
                         "sensitive leaves regardless of size)")
    ap.add_argument("--save-quantized", default=None, metavar="DIR",
                    help="write the packed model artifact after quantizing")
    ap.add_argument("--load-quantized", default=None, metavar="DIR",
                    help="boot from a packed artifact (skips training, "
                         "calibration and quantization)")
    ap.add_argument("--train-steps", type=int, default=300,
                    help="tiny-LM pretraining steps (ignored with "
                         "--load-quantized)")
    ap.add_argument("--cache", default="auto",
                    choices=("auto", "dense", "paged"),
                    help="cache backend: auto picks paged when a mesh, "
                         "kv-bits, speculation, or a non-attention block "
                         "pattern (MLA latents, Mamba state slabs) asks "
                         "for it; paged forces the paged stack and "
                         "prints its capacity banner")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch-size", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--kv-bits", type=int, default=0,
                    help="binary-code the KV page pool at this many bits "
                         "per coefficient (0 = raw fp pages); implies "
                         "the paged cache backend")
    ap.add_argument("--kv-group-size", type=int, default=0,
                    help="head_dim entries per KV scale group (0 = one "
                         "group per head vector); must divide head_dim")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="self-speculative decoding: a low-bit draft "
                         "view of the SAME packed weights proposes K "
                         "tokens per tick, one batched target pass "
                         "verifies them (greedy acceptance); implies "
                         "the paged cache backend; needs quantized "
                         "params (--quant or --load-quantized)")
    ap.add_argument("--draft-bits", type=int, default=2,
                    help="code planes the speculative draft keeps "
                         "(< the target's bits); draft scales come "
                         "from the artifact's v4 draft block when "
                         "present, else an on-the-fly LS re-fit")
    args = ap.parse_args()

    if args.devices:
        # must land before the first jax import anywhere below
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()

    from repro.configs import get_config
    from repro.data import ByteTokenizer
    from repro.serve import Request, ServeEngine

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serve_mesh
        d, m = (int(x) for x in args.mesh.replace("x", ",").split(","))
        mesh = make_serve_mesh(data=d, model=m)
        print(f"serving over mesh data={d} model={m}")

    tok = ByteTokenizer()
    if args.suggest_overrides:
        from benchmarks.common import calib_batches_for
        from repro.data.pretrained import get_trained_lm
        from repro.quant import (QuantSpec, format_overrides, format_report,
                                 sensitivity_sweep, suggest_overrides)

        cfg, params = get_trained_lm(args.arch, steps=args.train_steps)
        spec = QuantSpec.from_config(
            cfg.quant, method=args.method or "gptqt",
            bits=args.quant or cfg.quant.bits,
            group_size=args.group_size)
        scores = sensitivity_sweep(cfg, params, calib_batches_for("wiki"),
                                   spec=spec)
        print(format_report(scores))
        rules = suggest_overrides(scores, base_bits=spec.bits,
                                  bytes_budget=args.bytes_budget)
        if args.bytes_budget is not None:
            from repro.quant.search import bump_cost_bytes
            spent = sum(bump_cost_bytes(s, spec.bits, spec.bits + 1)
                        for s in scores
                        if any(r.pattern == s.path for r in rules))
            print(f"\n# bytes budget {args.bytes_budget}: bumped "
                  f"{len(rules)}/{len(scores)} leaves from w{spec.bits} "
                  f"to w{spec.bits + 1} ({spent} bytes spent); paste "
                  f"into QuantSpec(..., overrides=...):")
        else:
            print(f"\n# most sensitive {len(rules)}/{len(scores)} leaves "
                  f"bumped from w{spec.bits} to w{spec.bits + 1}; paste "
                  f"into QuantSpec(..., overrides=...):")
        print(format_overrides(rules))
        return

    if args.load_quantized:
        if (args.quant or args.save_quantized or args.group_size
                or args.method):
            ap.error("--load-quantized boots the artifact as-is; it is "
                     "incompatible with --quant/--save-quantized/"
                     "--group-size/--method (re-quantize and re-save to "
                     "change them)")
        from repro.ckpt.packed import load_packed
        params, spec, meta = load_packed(args.load_quantized, mesh=mesh)
        arch = meta.get("arch", args.arch)
        # mirror get_trained_lm's config construction; all weights come
        # from the artifact, so no training or calibration happens here
        cfg = get_config(arch).replace(dtype="float32", remat="none")
        desc = (f"{spec.method} w{spec.bits}" if spec is not None
                else "unknown spec")
        print(f"loaded packed model '{arch}' ({desc}) from "
              f"{args.load_quantized} — calibration/GPTQ skipped")
    else:
        from benchmarks.common import calib_batches_for
        from repro.core import quantize_model
        from repro.data.pretrained import get_trained_lm
        from repro.quant import QuantSpec

        cfg, params = get_trained_lm(args.arch, steps=args.train_steps)
        if args.quant:
            spec = QuantSpec.from_config(
                cfg.quant, method=args.method or "gptqt", mode="packed",
                bits=args.quant, group_size=args.group_size)
            gdesc = (f", group_size={spec.group_size}" if spec.group_size
                     else "")
            print(f"quantizing with {spec.method} to {spec.bits} bits "
                  f"(packed{gdesc}) ...")
            params, _ = quantize_model(cfg, params,
                                       calib_batches_for("wiki"), spec=spec)
            if args.save_quantized:
                from repro.ckpt.packed import save_packed
                # store the draft block whenever a draft is expressible:
                # the re-fit scales are tiny and let any later
                # `--speculate` boot skip the on-the-fly refit
                d_bits = (args.draft_bits
                          if 0 < args.draft_bits < args.quant else None)
                out = save_packed(args.save_quantized, params, spec=spec,
                                  meta={"arch": args.arch},
                                  draft_bits=d_bits)
                print(f"saved packed artifact to {out}"
                      + (f" (w{d_bits} draft scales included)"
                         if d_bits else ""))
        elif args.save_quantized:
            ap.error("--save-quantized requires --quant")

    batch = args.batch_size
    if mesh is not None:
        # every page-pool shard serves an equal slice of the batch
        from repro.dist.sharding import mesh_axis_sizes
        d = int(mesh_axis_sizes(mesh).get("data", 1))
        if batch % d:
            batch = -(-batch // d) * d
            print(f"batch_size rounded {args.batch_size} -> {batch} "
                  f"(must split over {d} data shards)")
    draft_params = None
    if args.speculate:
        from repro.quant.draft import make_draft_params
        scales_tree = None
        if args.load_quantized:
            from repro.ckpt.packed import load_draft_scales
            scales_tree = load_draft_scales(args.load_quantized)
            print("draft scales: "
                  + ("manifest v4 draft block" if scales_tree is not None
                     else "on-the-fly LS re-fit (no v4 draft block)"))
        draft_params = make_draft_params(params, args.draft_bits,
                                         scales_tree)
    paged = mesh is not None or args.kv_bits > 0 or args.speculate > 0
    if args.cache == "paged":
        paged = True
    elif args.cache == "dense":
        if paged:
            ap.error("--cache dense conflicts with --mesh/--kv-bits/"
                     "--speculate (each requires the paged backend)")
    eng = ServeEngine(cfg, params, batch_size=batch,
                      max_len=160, dtype="float32",
                      cache_kind="paged" if paged else "dense",
                      mesh=mesh, kv_bits=args.kv_bits,
                      kv_group_size=args.kv_group_size,
                      speculate=args.speculate,
                      draft_bits=args.draft_bits,
                      draft_params=draft_params)
    if paged:
        kv = eng.kv
        kind = "latent" if cfg.mla is not None else "kv"
        print(f"paged {kind} cache: {kv.n_pages} pages x "
              f"{kv.page_size} tok, {kv.bytes_per_page()} B/page")
        if eng.slab is not None:
            sl = eng.slab
            print(f"state slab pool: {sl.usable_slabs} usable slabs "
                  f"({sl.n_shards} reserve), {sl.bytes_per_slab()} B/slab")
    if args.kv_bits:
        kv = eng.kv
        raw = kv.__class__(cfg, n_pages=kv.n_pages,
                           page_size=kv.page_size, max_seqs=kv.max_seqs,
                           dtype="float32",
                           create_pool=False).bytes_per_page()
        print(f"quantized KV cache: {args.kv_bits}-bit binary-coded "
              f"pages, {kv.bytes_per_page()} B/page vs {raw} B/page raw "
              f"({raw / kv.bytes_per_page():.1f}x capacity)")
    if mesh is not None:
        kv = eng.kv
        print(f"sharded page pool: {kv.n_shards} shards x "
              f"{kv.pages_per_shard} pages "
              f"({kv.usable_in_shard(0)} usable + 1 reserve each, "
              f"page_size={kv.page_size})")
    seeds = ["the ancient city", "a famous museum", "this railway",
             "the council", "another region", "the early dynasty"]
    reqs = [Request(prompt=tok.encode(seeds[i % len(seeds)]),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    eng.run(reqs)
    tput = eng.stats["tokens"] / max(eng.stats["decode_s"], 1e-9)
    print(f"served {len(reqs)} requests, {eng.stats['tokens']} tokens, "
          f"decode throughput {tput:.1f} tok/s (CPU)")
    for r in reqs[:3]:
        print(" ", repr(tok.decode(r.out)))


if __name__ == "__main__":
    main()
