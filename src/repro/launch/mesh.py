"""Production mesh construction. A FUNCTION (not module-level state) so
importing this module never touches jax device initialization."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.
    Axes: data = FSDP/ZeRO + batch, model = TP/EP, pod = pure DP."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-process CPU mesh for tests/examples (1 device)."""
    return jax.make_mesh((1, 1), ("data", "model"))
