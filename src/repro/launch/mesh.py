"""Production mesh construction. A FUNCTION (not module-level state) so
importing this module never touches jax device initialization."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.
    Axes: data = FSDP/ZeRO + batch, model = TP/EP, pod = pure DP."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-process CPU mesh for tests/examples (1 device)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_serve_mesh(*, data: int | None = None, model: int = 1):
    """Serving mesh over the visible devices: `data` page-pool shards
    (each holding an equal block of the paged-KV pool and an equal
    slice of the batch) x `model` tensor-parallel ways. Defaults to all
    devices on the data axis. Pair with `XLA_FLAGS=
    --xla_force_host_platform_device_count=N` (or `launch.serve
    --devices N`) to rehearse multi-device serving on CPU."""
    n = len(jax.devices())
    if data is None:
        data = max(n // model, 1)
    if data * model > n:
        raise ValueError(f"mesh {data}x{model} needs {data * model} "
                         f"devices, only {n} visible")
    return jax.make_mesh((data, model), ("data", "model"))
