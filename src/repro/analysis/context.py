"""AnalysisContext: the parsed view of one tree that every rule reads.

Rules never open files themselves — they ask the context for file
lists, source text and ASTs (all cached, each file parsed at most once
per run no matter how many rules look at it). Rooting the context at an
arbitrary directory is what makes rules testable: tests/test_lint.py
builds throwaway mini-trees with one bad snippet and runs a single rule
against them.

A file that fails to parse yields a single file-level parse-error
finding (via `parse_failures`) instead of crashing the run — the lint
must keep reporting the rest of the tree while someone is mid-edit.
"""
from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.finding import Finding

# directories that never contain repo code
_SKIP_DIRS = {"__pycache__", ".git", ".github", "artifacts", ".claude"}


class AnalysisContext:
    def __init__(self, root):
        self.root = Path(root).resolve()
        self._trees: dict = {}
        self._texts: dict = {}
        self._parse_failures: dict = {}

    # -- file discovery ---------------------------------------------------

    def rel(self, path) -> str:
        return Path(path).resolve().relative_to(self.root).as_posix()

    def exists(self, rel: str) -> bool:
        return (self.root / rel).exists()

    def py_files(self, *subdirs) -> list:
        """Sorted .py files under the given repo-relative subdirs (repo
        root when none given); missing subdirs contribute nothing."""
        out = []
        for sub in subdirs or ("",):
            base = self.root / sub
            if not base.is_dir():
                continue
            for p in sorted(base.rglob("*.py")):
                if not _SKIP_DIRS.intersection(p.parts):
                    out.append(p)
        return out

    def md_files(self, *subdirs) -> list:
        out = []
        for sub in subdirs or ("",):
            base = self.root / sub
            if not base.is_dir():
                continue
            glob = base.glob("*.md") if sub == "" else base.rglob("*.md")
            out.extend(sorted(glob))
        return out

    # -- cached parsing ---------------------------------------------------

    def text(self, path) -> str:
        key = self.rel(path)
        if key not in self._texts:
            self._texts[key] = (self.root / key).read_text()
        return self._texts[key]

    def tree(self, path):
        """Parsed AST for one file, or None if it does not parse (the
        failure is recorded and surfaced once via `parse_failures`)."""
        key = self.rel(path)
        if key not in self._trees:
            try:
                self._trees[key] = ast.parse(self.text(path), filename=key)
            except SyntaxError as e:
                self._trees[key] = None
                self._parse_failures[key] = Finding(
                    rule_id="R000", file=key, line=int(e.lineno or 0),
                    message=f"does not parse: {e.msg}")
        return self._trees[key]

    def parse_failures(self) -> list:
        return [self._parse_failures[k] for k in sorted(self._parse_failures)]
