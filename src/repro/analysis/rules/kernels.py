"""Kernel-facing rules: oracle parity (R001), tracer hygiene (R003)
and tiling contracts (R004).

These are the contracts that fail *silently* when broken: a kernel
without a jnp oracle has no off-TPU execution path and no independent
ground truth; a Python `if` on a traced value either crashes at trace
time or — worse — bakes one branch into the compiled program; a tile
size that is not a sublane/lane/pack-word multiple quietly falls off
the fast path (or corrupts the packed layout) on real hardware.
"""
from __future__ import annotations

import ast

from repro.analysis.astutil import (CallRefs, dotted, func_name, is_literal,
                                    identifiers, module_functions)
from repro.analysis.finding import Finding
from repro.analysis.registry import register_rule
from repro.hw import LANE, SUBLANE, WORD

KERNELS_DIR = "src/repro/kernels"
ORACLE_FILE = "src/repro/kernels/ref.py"
TESTS_DIR = "tests"
# modules in kernels/ that are not kernel entry points: the oracles
# themselves and the dispatch layer (whose contract is "calls a kernel
# or its oracle", covered by the kernels it routes to)
NON_KERNEL_MODULES = {"__init__.py", "ref.py", "ops.py"}
# kw-only params that tune execution rather than change the math — the
# oracle intentionally does not take them
TUNING_PARAM_PREFIXES = ("block_",)
TUNING_PARAMS = {"interpret"}


# --------------------------------------------------------------------------
# R001 — kernel/oracle parity
# --------------------------------------------------------------------------

@register_rule(
    "R001", title="every public kernel has a matching ref.py oracle and a "
    "kernel-vs-oracle test",
    rationale="ref.py is the only off-TPU execution path and the only "
    "independent ground truth; a kernel without an oracle (or without a "
    "test comparing the two) can drift numerically with no signal")
def kernel_oracle_parity(ctx):
    findings = []
    ref_path = ctx.root / ORACLE_FILE
    ref_tree = ctx.tree(ref_path) if ref_path.exists() else None
    oracles = module_functions(ref_tree) if ref_tree else {}

    test_idents = {}
    for tf in ctx.py_files(TESTS_DIR):
        tt = ctx.tree(tf)
        if tt is not None:
            test_idents[ctx.rel(tf)] = identifiers(tt)

    for path in ctx.py_files(KERNELS_DIR):
        if path.name in NON_KERNEL_MODULES:
            continue
        tree = ctx.tree(path)
        if tree is None:
            continue
        rel = ctx.rel(path)
        for name, fn in module_functions(tree).items():
            if name.startswith("_"):
                continue
            oname = f"{name}_ref"
            if ref_tree is None:
                findings.append(Finding(
                    "R001", rel, fn.lineno,
                    f"public kernel `{name}` has no oracle module "
                    f"({ORACLE_FILE} missing)"))
                continue
            oracle = oracles.get(oname)
            if oracle is None:
                findings.append(Finding(
                    "R001", rel, fn.lineno,
                    f"public kernel `{name}` has no `{oname}` oracle in "
                    f"{ORACLE_FILE}"))
                continue
            findings.extend(_signature_findings(rel, name, fn, oracle))
            if not any(name in ids and oname in ids
                       for ids in test_idents.values()):
                findings.append(Finding(
                    "R001", rel, fn.lineno,
                    f"no test module references both `{name}` and "
                    f"`{oname}` (kernel-vs-oracle test missing)"))
    return findings


def _signature_findings(rel, name, fn, oracle):
    kpos = [a.arg for a in fn.args.args]
    opos = [a.arg for a in oracle.args.args]
    out = []
    if opos[:len(kpos)] != kpos:
        out.append(Finding(
            "R001", rel, fn.lineno,
            f"kernel `{name}` positional args {kpos} are not a prefix of "
            f"oracle `{oracle.name}` args {opos}"))
    tune = lambda p: p in TUNING_PARAMS or \
        p.startswith(TUNING_PARAM_PREFIXES)
    kkw = {a.arg for a in fn.args.kwonlyargs if not tune(a.arg)}
    okw = {a.arg for a in oracle.args.kwonlyargs}
    missing = sorted(kkw - okw)
    if missing:
        out.append(Finding(
            "R001", rel, fn.lineno,
            f"kernel `{name}` kw-only args {missing} missing from oracle "
            f"`{oracle.name}` (tuning params block_*/interpret exempt)"))
    return out


# --------------------------------------------------------------------------
# R003 — tracer hygiene
# --------------------------------------------------------------------------

# attribute reads that are static under tracing (shape metadata)
_BARRIER_ATTRS = {"shape", "dtype", "ndim", "size", "sharding", "aval",
                  "weak_type"}
# calls whose result is static even on traced inputs
_BARRIER_CALLS = {"len", "range", "isinstance", "type", "hasattr",
                  "getattr"}
_BARRIER_DOTTED = {"pl.program_id", "pl.num_programs"}
# calls that force a concrete value out of a tracer
_FORCING_CALLS = {"int", "bool", "float"}


@register_rule(
    "R003", title="no Python control flow or int()/bool()/.item() on "
    "values derived from traced kernel parameters",
    rationale="inside jit or a pallas_call body, a Python `if`/`while` "
    "on a tracer either raises ConcretizationError at trace time or "
    "silently bakes one branch into the compiled program; shape/dtype "
    "metadata is static and exempt")
def tracer_hygiene(ctx):
    findings = []
    for path in ctx.py_files("src"):
        tree = ctx.tree(path)
        if tree is None:
            continue
        rel = ctx.rel(path)
        for fn, traced in _traced_functions(tree):
            findings.extend(_taint_check(rel, fn, traced))
    return findings


def _traced_functions(tree):
    """Yield (FunctionDef, traced_param_names) for module-level functions
    that are jitted (decorator `jax.jit` / `functools.partial(jax.jit,
    ...)`) or passed to `pl.pallas_call` (directly or via
    functools.partial). Static argnums/argnames and partial-bound
    keywords are excluded from the traced set; pallas kw-only params are
    compile-time config by convention and also excluded."""
    refs = CallRefs(tree)
    funcs = module_functions(tree)
    out = []

    for fn in funcs.values():
        for dec in fn.decorator_list:
            jit_call = _as_jit_call(dec, refs)
            if jit_call is not None or refs.is_ref(dec, "jax", "jit"):
                statics = _static_params(fn, jit_call)
                pos = [a.arg for a in fn.args.args]
                kw = [a.arg for a in fn.args.kwonlyargs]
                traced = [p for p in pos + kw if p not in statics]
                out.append((fn, set(traced)))

    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and dotted(node.func).endswith("pallas_call")
                and node.args):
            continue
        target, bound = node.args[0], set()
        if isinstance(target, ast.Call) \
                and func_name(target) == "partial" and target.args:
            bound = {k.arg for k in target.keywords if k.arg}
            target = target.args[0]
        if isinstance(target, ast.Name) and target.id in funcs:
            fn = funcs[target.id]
            kw = {a.arg for a in fn.args.kwonlyargs}
            traced = {a.arg for a in fn.args.args} - bound - kw
            out.append((fn, traced))
    return out


def _as_jit_call(dec, refs):
    """The jax.jit Call node behind a decorator, or None: matches
    `@jax.jit(...)` and `@functools.partial(jax.jit, ...)`."""
    if not isinstance(dec, ast.Call):
        return None
    if refs.is_ref(dec.func, "jax", "jit"):
        return dec
    if func_name(dec) == "partial" and dec.args \
            and refs.is_ref(dec.args[0], "jax", "jit"):
        return dec
    return None


def _static_params(fn, jit_call):
    statics = set()
    if jit_call is None:
        return statics
    for k in jit_call.keywords:
        if k.arg == "static_argnames" and is_literal(k.value):
            v = ast.literal_eval(k.value)
            statics.update([v] if isinstance(v, str) else v)
        elif k.arg == "static_argnums" and is_literal(k.value):
            v = ast.literal_eval(k.value)
            pos = [a.arg for a in fn.args.args]
            for i in ([v] if isinstance(v, int) else v):
                if 0 <= i < len(pos):
                    statics.add(pos[i])
    return statics


def _expr_tainted(node, tainted) -> bool:
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Attribute):
        if node.attr in _BARRIER_ATTRS:
            return False
        return _expr_tainted(node.value, tainted)
    if isinstance(node, ast.Call):
        fname = func_name(node)
        if fname in _BARRIER_CALLS or dotted(node.func) in _BARRIER_DOTTED:
            return False
        return any(_expr_tainted(a, tainted) for a in node.args) \
            or any(_expr_tainted(k.value, tainted) for k in node.keywords) \
            or _expr_tainted(node.func, tainted)
    if isinstance(node, ast.Constant):
        return False
    return any(_expr_tainted(c, tainted) for c in ast.iter_child_nodes(node))


def _target_names(target):
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        return [n for e in target.elts for n in _target_names(e)]
    if isinstance(target, ast.Starred):
        return _target_names(target.value)
    return []


def _taint_check(rel, fn, traced):
    """One forward dataflow pass (iterated to fixpoint) over fn's body:
    start from the traced params, propagate through assignments, flag
    Python control flow / value-forcing calls on tainted expressions."""
    tainted = set(traced)
    findings = []

    def flag(line, msg):
        findings.append(Finding("R003", rel, line,
                                f"in `{fn.name}`: {msg}"))

    def visit_block(stmts, tainted):
        for s in stmts:
            if isinstance(s, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                value = s.value
                if value is not None and _expr_tainted(value, tainted):
                    targets = s.targets if isinstance(s, ast.Assign) \
                        else [s.target]
                    for t in targets:
                        tainted.update(_target_names(t))
            elif isinstance(s, ast.If):
                if _expr_tainted(s.test, tainted):
                    flag(s.lineno, "Python `if` on a value derived from a "
                         "traced parameter")
                visit_block(s.body, tainted)
                visit_block(s.orelse, tainted)
            elif isinstance(s, ast.While):
                if _expr_tainted(s.test, tainted):
                    flag(s.lineno, "Python `while` on a value derived "
                         "from a traced parameter")
                visit_block(s.body, tainted)
                visit_block(s.orelse, tainted)
            elif isinstance(s, ast.For):
                if _expr_tainted(s.iter, tainted):
                    flag(s.lineno, "Python `for` iterates a value derived "
                         "from a traced parameter")
                    tainted.update(_target_names(s.target))
                visit_block(s.body, tainted)
                visit_block(s.orelse, tainted)
            elif isinstance(s, (ast.With, ast.Try)):
                for blk in (getattr(s, "body", []),
                            getattr(s, "finalbody", []),
                            getattr(s, "orelse", [])):
                    visit_block(blk, tainted)
            elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # nested def: params shadow the outer taint
                inner = tainted - {a.arg for a in
                                   s.args.args + s.args.kwonlyargs}
                visit_block(s.body, inner)
            elif isinstance(s, ast.Return) and s.value is not None:
                pass

    # fixpoint: later statements can taint names used earlier in loops
    for _ in range(4):
        before = set(tainted)
        findings.clear()
        visit_block(fn.body, tainted)
        if tainted == before:
            break

    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        fname = func_name(node)
        if isinstance(node.func, ast.Name) and fname in _FORCING_CALLS \
                and any(_expr_tainted(a, tainted) for a in node.args):
            findings.append(Finding(
                "R003", rel, node.lineno,
                f"in `{fn.name}`: {fname}() forces a concrete value out "
                f"of a traced parameter"))
        elif isinstance(node.func, ast.Attribute) and fname == "item" \
                and _expr_tainted(node.func.value, tainted):
            findings.append(Finding(
                "R003", rel, node.lineno,
                f"in `{fn.name}`: .item() forces a concrete value out "
                f"of a traced parameter"))
    return findings


# --------------------------------------------------------------------------
# R004 — tiling contracts
# --------------------------------------------------------------------------

TILING_DIRS = ("src/repro/kernels", "src/repro/quant")
SIZE_PARAMS = {"block_m", "block_n", "block_k", "group_size",
               "kv_group_size", "page_size"}
# sentinel values that mean "disabled/auto", not a tile size
_SENTINELS = {None, 0, 1, -1}
LAYOUT_CONSTANTS = {"WORD", "SUBLANE", "LANE"}
HW_MODULE = "src/repro/hw.py"


@register_rule(
    "R004", title="tile/group sizes in kernels/ and quant/ are named "
    "constants satisfying the sublane/lane/pack-word multiples",
    rationale="a magic 256 in a BlockSpec works until someone edits it "
    "to 250; naming the constant and checking the gs%32 / bm%8 / bn%128 "
    "family statically keeps the packed layout and the MXU tiling legal "
    "without waiting for a TPU run to fail")
def tiling_contracts(ctx):
    findings = []
    for path in ctx.py_files(*TILING_DIRS):
        tree = ctx.tree(path)
        if tree is None:
            continue
        rel = ctx.rel(path)
        findings.extend(_literal_size_findings(rel, tree))
        findings.extend(_constant_value_findings(rel, tree))
        if rel != HW_MODULE:
            findings.extend(_layout_redefinition_findings(rel, tree))
    return findings


def _bad_size_literal(node) -> bool:
    return isinstance(node, ast.Constant) \
        and isinstance(node.value, int) \
        and not isinstance(node.value, bool) \
        and node.value not in _SENTINELS


def _literal_size_findings(rel, tree):
    out = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = node.args
            pairs = list(zip(args.args[len(args.args)
                                       - len(args.defaults):],
                             args.defaults))
            pairs += [(a, d) for a, d in zip(args.kwonlyargs,
                                             args.kw_defaults)
                      if d is not None]
            for a, d in pairs:
                if a.arg in SIZE_PARAMS and _bad_size_literal(d):
                    out.append(Finding(
                        "R004", rel, node.lineno,
                        f"magic literal {d.value} as default of "
                        f"`{a.arg}` in `{node.name}` (promote to a "
                        f"named module constant)"))
        elif isinstance(node, ast.Call):
            for k in node.keywords:
                if k.arg in SIZE_PARAMS and _bad_size_literal(k.value):
                    out.append(Finding(
                        "R004", rel, node.lineno,
                        f"magic literal {k.value.value} passed as "
                        f"`{k.arg}` (use a named constant)"))
    return out


def _constant_value_findings(rel, tree):
    """Named tile constants must satisfy the hardware multiples."""
    out = []
    checks = (("_M", SUBLANE, "SUBLANE"), ("_N", LANE, "LANE"),
              ("_K", WORD, "WORD"))
    for node in tree.body:
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            continue
        name = node.targets[0].id
        if not (name.isupper() and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, int)):
            continue
        val = node.value.value
        if "GROUP_SIZE" in name and val % WORD:
            out.append(Finding(
                "R004", rel, node.lineno,
                f"{name} = {val} is not a multiple of the {WORD}-bit "
                f"pack word"))
            continue
        for suffix, mult, mname in checks:
            if (name.endswith(suffix) or f"{suffix}_" in name) \
                    and val % mult:
                out.append(Finding(
                    "R004", rel, node.lineno,
                    f"{name} = {val} is not a {mname} ({mult}) multiple"))
    return out


def _layout_redefinition_findings(rel, tree):
    out = []
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id in LAYOUT_CONSTANTS \
                and isinstance(node.value, ast.Constant):
            out.append(Finding(
                "R004", rel, node.lineno,
                f"redefines layout constant {node.targets[0].id}; import "
                f"it from repro.hw (the single source the lint checks)"))
    return out
