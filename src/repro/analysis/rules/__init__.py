"""Built-in repro-lint rules. Importing this package registers them
(registry._ensure_builtins does so lazily); rule catalog in
docs/ANALYSIS.md.

  kernels.py       R001 kernel/oracle parity
                   R003 tracer hygiene
                   R004 tiling contracts
  jit.py           R002 jit ownership
  completeness.py  R005 registry/docs + EngineStats completeness
                   R006 sharding coverage
                   R008 no test shims
  docs.py          R007 docs link integrity
"""
from repro.analysis.rules import completeness, docs, jit, kernels  # noqa: F401
