"""Completeness rules: the cross-file contracts that drift silently.

R005 — every registered quantizer/scenario name is documented, and every
EngineStats field is populated by the snapshot path. R006 — every param/
cache leaf models/ constructs resolves to a placement decision in
dist/sharding.py. R008 — no import-substitution shims in tests/.

These are exactly the invariants a reviewer cannot check from a diff:
adding `@register_quantizer("foo")` touches one file, the docs table
lives in another, and nothing fails when they disagree — until a reader
follows the docs.
"""
from __future__ import annotations

import ast

from repro.analysis.astutil import func_name, identifier_strings
from repro.analysis.finding import Finding
from repro.analysis.registry import register_rule

# registry decorator -> the doc page that must table the name
REGISTRY_DOCS = {
    "register_quantizer": "docs/QUANT.md",
    "register_scenario": "docs/BENCHMARKS.md",
}
REGISTRY_SCAN_DIRS = ("src", "benchmarks")
STATS_FILE = "src/repro/serve/stats.py"


@register_rule(
    "R005", title="every registered quantizer/scenario is documented and "
    "every EngineStats field is populated by the snapshot",
    rationale="the registries are the public surface of the repro — an "
    "undocumented name is invisible to users, and an EngineStats field "
    "capture() never sets is a permanently-zero counter that benchmarks "
    "will happily record as truth")
def registry_docs_completeness(ctx):
    findings = []
    doc_texts = {doc: (ctx.text(ctx.root / doc) if ctx.exists(doc) else None)
                 for doc in set(REGISTRY_DOCS.values())}

    for path in ctx.py_files(*REGISTRY_SCAN_DIRS):
        tree = ctx.tree(path)
        if tree is None:
            continue
        rel = ctx.rel(path)
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef)):
                continue
            for dec in node.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                deco = func_name(dec)
                if deco not in REGISTRY_DOCS or not dec.args:
                    continue
                name_node = dec.args[0]
                if not (isinstance(name_node, ast.Constant)
                        and isinstance(name_node.value, str)):
                    continue  # dynamic names can't be checked statically
                name, doc = name_node.value, REGISTRY_DOCS[deco]
                text = doc_texts[doc]
                if text is None:
                    findings.append(Finding(
                        "R005", rel, dec.lineno,
                        f"`{name}` registered but {doc} does not exist"))
                elif f"`{name}`" not in text:
                    findings.append(Finding(
                        "R005", rel, dec.lineno,
                        f"registered name `{name}` not documented in "
                        f"{doc} (add a table row)"))
    findings.extend(_stats_findings(ctx))
    return findings


def _stats_findings(ctx):
    """Every EngineStats dataclass field must appear as a string key in
    `capture()` — the only constructor `stats_snapshot` uses."""
    path = ctx.root / STATS_FILE
    if not path.exists():
        return []
    tree = ctx.tree(path)
    if tree is None:
        return []
    cls = next((n for n in tree.body if isinstance(n, ast.ClassDef)
                and n.name == "EngineStats"), None)
    if cls is None:
        return []
    fields = {(n.target.id, n.lineno) for n in cls.body
              if isinstance(n, ast.AnnAssign)
              and isinstance(n.target, ast.Name)}
    capture = next((n for n in cls.body
                    if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and n.name == "capture"), None)
    if capture is None:
        return [Finding("R005", STATS_FILE, cls.lineno,
                        "EngineStats has no capture() classmethod")]
    keys = set()
    for n in ast.walk(capture):
        if isinstance(n, ast.Dict):
            keys.update(k.value for k in n.keys
                        if isinstance(k, ast.Constant)
                        and isinstance(k.value, str))
        elif isinstance(n, ast.Call):
            keys.update(k.arg for k in n.keywords if k.arg)
    return [Finding("R005", STATS_FILE, line,
                    f"EngineStats.{name} is never populated by capture() "
                    f"(would read as a constant default)")
            for name, line in sorted(fields) if name not in keys]


# --------------------------------------------------------------------------
# R006 — sharding coverage
# --------------------------------------------------------------------------

MODELS_DIR = "src/repro/models"
SHARDING_FILE = "src/repro/dist/sharding.py"
_INIT_PREFIXES = ("init_", "_init_", "abstract_", "_abstract_")
# leaf initializers: a call to one of these *is* a leaf value; a call to
# any other init_* returns a subtree whose own keys are checked where
# it is defined
_LEAF_INITS = {"init_linear"}


@register_rule(
    "R006", title="every param/cache leaf name constructed in models/ "
    "resolves to a rule in dist/sharding.py",
    rationale="the sharding rules are total functions with a replicate "
    "fallback, so an unknown leaf silently replicates onto every device "
    "— correct but quadratically expensive; forcing the name into "
    "sharding.py (a rule or REPLICATED_LEAVES) makes placement a "
    "reviewed decision")
def sharding_coverage(ctx):
    spath = ctx.root / SHARDING_FILE
    if not spath.exists():
        return [Finding("R006", SHARDING_FILE, 0,
                        "sharding rule module missing")]
    stree = ctx.tree(spath)
    if stree is None:
        return []
    known = {s for s, _ in identifier_strings(stree)}

    findings = []
    for path in ctx.py_files(MODELS_DIR):
        tree = ctx.tree(path)
        if tree is None:
            continue
        rel = ctx.rel(path)
        for fn in ast.walk(tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and fn.name.startswith(_INIT_PREFIXES):
                for name, line in _leaf_names(fn):
                    if not (name.startswith("w") or name in known):
                        findings.append(Finding(
                            "R006", rel, line,
                            f"leaf `{name}` (built by `{fn.name}`) has no "
                            f"rule in dist/sharding.py — add a placement "
                            f"rule or declare it in REPLICATED_LEAVES"))
    return findings


def _is_subtree(value) -> bool:
    """Values that are containers (their own keys are checked where they
    are built) or unresolvable names — not leaf arrays."""
    if isinstance(value, (ast.Dict, ast.DictComp, ast.ListComp,
                          ast.SetComp, ast.Name)):
        return True
    if isinstance(value, ast.Call):
        fname = func_name(value)
        if fname.startswith(("init_", "abstract_")) \
                and fname not in _LEAF_INITS:
            return True
        if not fname:       # e.g. jax.vmap(init_one)(...) — nested call
            return True
    return False


def _leaf_names(fn):
    """(name, lineno) for statically-known leaf keys built inside fn:
    string keys of dict literals and `tree["name"] = value` subscript
    assignments, excluding subtree values and dynamic (f-string) keys."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Dict):
            for k, v in zip(node.keys, node.values):
                if isinstance(k, ast.Constant) and isinstance(k.value, str) \
                        and not _is_subtree(v):
                    yield k.value, k.lineno
        elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Subscript):
            sl = node.targets[0].slice
            if isinstance(sl, ast.Constant) and isinstance(sl.value, str) \
                    and not _is_subtree(node.value):
                yield sl.value, node.lineno


# --------------------------------------------------------------------------
# R008 — no import-substitution shims in tests/
# --------------------------------------------------------------------------

SHIM_MODULE = "_hypothesis_fallback"


@register_rule(
    "R008", title="tests/ contains no import-substitution shims "
    "(fallback modules, sys.modules patching)",
    rationale="a stand-in module that satisfies imports makes property "
    "tests silently degrade to single-example smoke tests; the honest "
    "pattern is `except ImportError: given = None` with the tests "
    "skipped visibly and CI running the real dependency under "
    "REQUIRE_HYPOTHESIS=1")
def no_test_shims(ctx):
    findings = []
    for path in ctx.py_files("tests"):
        rel = ctx.rel(path)
        if "fallback" in path.stem or "_shim" in path.stem:
            findings.append(Finding(
                "R008", rel, 1,
                "fallback/shim module in tests/ (import-substitution "
                "stand-ins are banned; gate on ImportError instead)"))
            continue
        tree = ctx.tree(path)
        if tree is None:
            continue
        for node in ast.walk(tree):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    if isinstance(t, ast.Subscript) \
                            and _dotted_is(t.value, "sys.modules"):
                        findings.append(Finding(
                            "R008", rel, node.lineno,
                            "assigns into sys.modules (import "
                            "substitution) in tests/"))
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                mods = [a.name for a in node.names]
                if isinstance(node, ast.ImportFrom) and node.module:
                    mods.append(node.module)
                if any(SHIM_MODULE in (m or "") for m in mods):
                    findings.append(Finding(
                        "R008", rel, node.lineno,
                        f"imports the removed {SHIM_MODULE} shim"))
    return findings


def _dotted_is(node, dotted_name: str) -> bool:
    from repro.analysis.astutil import dotted
    return dotted(node) == dotted_name
