"""R007 — docs link integrity (absorbs tools/check_doc_links.py).

Every relative markdown link and every slash-containing backticked file
reference in docs/*.md and the root *.md must resolve to a real file.
Previously a standalone CI step; folding it into repro-lint means one
framework, one suppression baseline and one CI gate for every repo
invariant.
"""
from __future__ import annotations

import re

from repro.analysis.finding import Finding
from repro.analysis.registry import register_rule

# [text](relative/target.md#anchor) — external schemes are skipped
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `path/with/slash.ext` possibly followed by ":symbol" or " --flags"
CODE_REF = re.compile(r"`([A-Za-z0-9_.\-]+(?:/[A-Za-z0-9_.\-]+)+"
                      r"\.(?:py|md|yml|yaml|json|txt))[:\s`]")
_SCHEME = re.compile(r"^[a-z][a-z0-9+.-]*:")
# a backticked path resolves against these bases (first hit wins);
# refs without a "/" (artifact members like `manifest.json`) are not
# checked at all
SEARCH_ROOTS = ("", "src", "src/repro", "docs")


@register_rule(
    "R007", title="markdown links and backticked file references in "
    "docs/ and root *.md resolve to real files",
    rationale="docs rot silently when the tree is refactored; a "
    "dangling `serve/engine.py` reference costs every future reader a "
    "search for a file that moved")
def doc_links(ctx):
    findings = []
    for doc in ctx.md_files("", "docs"):
        text = ctx.text(doc)
        rel = ctx.rel(doc)

        def lineno(pos):
            return text.count("\n", 0, pos) + 1

        for m in MD_LINK.finditer(text):
            target = m.group(1)
            if _SCHEME.match(target) or target.startswith("#"):
                continue                      # external / in-page
            path = (doc.parent / target.split("#", 1)[0]).resolve()
            if not path.exists():
                findings.append(Finding(
                    "R007", rel, lineno(m.start()),
                    f"dangling link ({target})"))
        for m in CODE_REF.finditer(text):
            ref = m.group(1)
            if not any((ctx.root / base / ref).exists()
                       for base in SEARCH_ROOTS):
                findings.append(Finding(
                    "R007", rel, lineno(m.start()),
                    f"stale file reference `{ref}`"))
    return findings
