"""R002 — jit ownership.

PR 5 made `serve/compile_cache.py` the process-wide owner of serving
jit closures: executables are keyed by (kind, cfg, mesh fingerprint) so
two engines with the same config share one XLA compilation. A stray
`jax.jit` anywhere else silently re-grows the compile count the cache
exists to bound — and never shows up in `compile_cache.stats()`, so the
regression is invisible to the bench counters too.

A small allowlist names the sites that legitimately own their own jit
(module-level kernel entries, the offline GPTQ solver, training steps,
lowering probes) with a one-line justification each. Everything else
must go through `compile_cache.get(...)`.
"""
from __future__ import annotations

import ast

from repro.analysis.astutil import CallRefs, func_name, is_literal
from repro.analysis.finding import Finding
from repro.analysis.registry import register_rule

JIT_OWNER = "src/repro/serve/compile_cache.py"

# file -> why it may call jax.jit directly
JIT_ALLOWLIST = {
    "src/repro/kernels/bcq_matmul.py":
        "module-level kernel entry: one jit per (shape, block config), "
        "process-wide by construction",
    "src/repro/kernels/paged_attention.py":
        "module-level kernel entry: same module-level-closure ownership "
        "as bcq_matmul",
    "src/repro/core/gptq.py":
        "offline quantization solver, never on the serving path the "
        "compile cache manages",
    "src/repro/train/trainer.py":
        "QAT training step: per-Trainer donated buffers, not a shared "
        "serving closure",
    "src/repro/launch/train.py":
        "sharded train step jitted once per launch with in_shardings "
        "baked in",
    "src/repro/launch/dryrun.py":
        "AOT lowering probes: jit is the product (inspecting HLO), "
        "nothing is executed or cached",
}


@register_rule(
    "R002", title="jax.jit appears only in serve/compile_cache.py or an "
    "allowlisted module; static_argnums/static_argnames are literals",
    rationale="the compile cache is the single owner of serving "
    "executables; a stray jit re-duplicates XLA compilations invisibly, "
    "and a computed static_argnums defeats static review of what is "
    "traced vs baked in")
def jit_ownership(ctx):
    findings = []
    for path in ctx.py_files("src"):
        tree = ctx.tree(path)
        if tree is None:
            continue
        rel = ctx.rel(path)
        refs = CallRefs(tree)
        jit_nodes = [n for n in ast.walk(tree)
                     if refs.is_ref(n, "jax", "jit")]
        if jit_nodes and rel != JIT_OWNER and rel not in JIT_ALLOWLIST:
            findings.append(Finding(
                "R002", rel, min(n.lineno for n in jit_nodes),
                f"references jax.jit outside {JIT_OWNER}; route through "
                f"compile_cache.get(...) or allowlist with justification"))
        findings.extend(_static_arg_findings(rel, tree, refs))
    return findings


def _static_arg_findings(rel, tree, refs):
    """static_argnums/static_argnames must be literal tuples/strings —
    applies everywhere, including the owner and allowlisted files."""
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        is_jit = refs.is_ref(node.func, "jax", "jit")
        is_partial_jit = func_name(node) == "partial" and node.args \
            and refs.is_ref(node.args[0], "jax", "jit")
        if not (is_jit or is_partial_jit):
            continue
        for k in node.keywords:
            if k.arg in ("static_argnums", "static_argnames") \
                    and not is_literal(k.value):
                out.append(Finding(
                    "R002", rel, node.lineno,
                    f"{k.arg} is not a literal (computed static args "
                    f"hide what gets baked into the executable)"))
    return out
