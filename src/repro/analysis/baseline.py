"""The suppression baseline: grandfathered findings, committed to git.

Each line is one suppressed finding key (rule id, file, message — tab
separated, exactly `Finding.key()`), optionally followed by a fourth
tab-separated field: the one-line justification for why the finding is
allowed to stand. Blank lines and `#` comment lines are ignored.

The contract the CLI enforces (and tests/test_lint.py pins):

  - a finding NOT in the baseline fails the run (new violation);
  - a baseline entry that no longer fires ALSO fails the run (stale
    suppression — run `--update-baseline` and commit the shrink);
  - `--update-baseline` output is deterministic (sorted by key) and
    preserves justifications of entries that survive, so the diff of a
    baseline update is reviewable line by line.

The goal state is an *empty* baseline: suppressions exist so the lint
can land while real fixes are split out, not as a place for findings
to retire.
"""
from __future__ import annotations

from pathlib import Path

from repro.analysis.finding import sort_findings

_HEADER = """\
# repro-lint suppression baseline (tools/repro_lint.py --update-baseline)
# one grandfathered finding per line: rule<TAB>file<TAB>message[<TAB>why]
# every entry should carry a one-line justification as its final field
"""


def load_baseline(path) -> dict:
    """path -> {finding_key: justification} (empty when file missing)."""
    p = Path(path)
    if not p.exists():
        return {}
    out = {}
    for raw in p.read_text().splitlines():
        line = raw.rstrip()
        if not line or line.lstrip().startswith("#"):
            continue
        parts = line.split("\t")
        if len(parts) < 3:
            raise ValueError(
                f"{p}: malformed baseline line (need rule<TAB>file<TAB>"
                f"message): {raw!r}")
        key = "\t".join(parts[:3])
        out[key] = parts[3] if len(parts) > 3 else ""
    return out


def render_baseline(findings, old: dict | None = None) -> str:
    """Deterministic baseline text for the given findings, carrying
    forward justifications from `old` for keys that survive."""
    old = old or {}
    lines = [_HEADER]
    seen = set()
    for f in sort_findings(findings):
        key = f.key()
        if key in seen:
            continue
        seen.add(key)
        just = old.get(key, "")
        lines.append(key + ("\t" + just if just else ""))
    return "\n".join(lines) + "\n"


def partition(findings, baseline: dict):
    """Split a run's findings against the baseline.

    Returns (new, suppressed, stale_keys): findings whose key is not
    baselined, findings that are, and baseline keys that no longer
    fire (sorted)."""
    keys = {f.key() for f in findings}
    new = [f for f in findings if f.key() not in baseline]
    suppressed = [f for f in findings if f.key() in baseline]
    stale = sorted(k for k in baseline if k not in keys)
    return new, suppressed, stale
