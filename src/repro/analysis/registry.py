"""Rule registry: rule ids -> checker implementations.

The same open-registration pattern as the quantizer registry
(quant/registry.py) and the bench scenario registry (bench/registry.py):
every rule registers itself under its id with `@register_rule("R001",
title=...)`, the runner dispatches through `get_rule`/`run_rules`, and
there is no rule list hard-coded anywhere. The built-in rules live in
repro/analysis/rules/; importing that package (which `run_rules` does
lazily) is what populates the registry, so this module stays
import-light.

A rule is a callable ``fn(ctx) -> Iterable[Finding]`` where ctx is a
context.AnalysisContext rooted at the tree under analysis — rules never
touch the filesystem directly, which is what makes them testable on
synthetic fixture trees (tests/test_lint.py).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Callable

from repro.analysis.finding import Finding, sort_findings

_REGISTRY: dict = {}
_BUILTINS_LOADED = False
_RULE_ID = re.compile(r"^R\d{3}$")


@dataclass(frozen=True)
class Rule:
    """One registered invariant check.

    title: one line, what the invariant is (docs/ANALYSIS.md catalog).
    rationale: why violating it hurts (shown by `--list-rules`).
    """
    rule_id: str
    title: str
    rationale: str
    fn: Callable

    def run(self, ctx) -> list:
        out = []
        for f in self.fn(ctx):
            if f.rule_id != self.rule_id:
                raise ValueError(
                    f"{self.rule_id} emitted a finding tagged {f.rule_id}")
            out.append(f)
        return sort_findings(out)


def register_rule(rule_id: str, *, title: str, rationale: str = ""):
    """Function decorator: `@register_rule("R001", title=...)`. Later
    registrations override (downstream trees may re-register a rule
    with a stricter implementation)."""
    if not _RULE_ID.match(rule_id):
        raise ValueError(f"rule id must look like R001, got {rule_id!r}")

    def deco(fn):
        _REGISTRY[rule_id] = Rule(rule_id=rule_id, title=title,
                                  rationale=rationale, fn=fn)
        return fn
    return deco


def _ensure_builtins():
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        import repro.analysis.rules  # noqa: F401  (registers built-ins)
        _BUILTINS_LOADED = True      # only after a successful import


def get_rule(rule_id: str) -> Rule:
    _ensure_builtins()
    try:
        return _REGISTRY[rule_id]
    except KeyError:
        raise KeyError(f"unknown rule {rule_id!r}; registered: "
                       f"{', '.join(available_rules())}") from None


def available_rules() -> list:
    _ensure_builtins()
    return sorted(_REGISTRY)


def run_rules(ctx, rule_ids=None) -> list:
    """Run the selected rules (all by default) over one context and
    return the merged, deterministically ordered finding list."""
    _ensure_builtins()
    ids = list(rule_ids) if rule_ids else available_rules()
    findings: list = []
    for rid in ids:
        findings.extend(get_rule(rid).run(ctx))
    return sort_findings(findings)
