"""repro-lint: repo-specific static analysis for the invariants the
test suite can't see (see docs/ANALYSIS.md for the rule catalog).

  finding.py   — the Finding record and its deterministic ordering
  registry.py  — @register_rule / run_rules (same open-registration
                 pattern as the quantizer and bench registries)
  context.py   — AnalysisContext: cached file lists / texts / ASTs
  baseline.py  — committed suppression baseline (load/render/partition)
  astutil.py   — shared AST pattern-matching helpers
  rules/       — the built-in rules (R001..R008)

Entry point: tools/repro_lint.py (CI-gated; exits non-zero on any
finding not in the committed baseline, and on stale baseline entries).
"""
from repro.analysis.context import AnalysisContext  # noqa: F401
from repro.analysis.finding import Finding, sort_findings  # noqa: F401
from repro.analysis.registry import (available_rules, get_rule,  # noqa: F401
                                     register_rule, run_rules)
