"""Small AST helpers shared by the repro-lint rules.

Everything here is resolution-free and syntactic: dotted-name
rendering, alias tracking for `jax.jit`-style references, and literal
classification. Rules stay readable because the fiddly pattern matching
lives in one place.
"""
from __future__ import annotations

import ast


def dotted(node) -> str:
    """Render a Name/Attribute chain as 'a.b.c' ('' when not a chain)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def func_name(call: ast.Call) -> str:
    """Last component of a call's function ('init_mla' for
    `mod.init_mla(...)`, 'jnp.zeros' -> 'zeros')."""
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def module_functions(tree) -> dict:
    """Module-level FunctionDefs by name (no nested defs)."""
    return {n.name: n for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def is_literal(node) -> bool:
    """Constant, or a tuple/list of (nested) literals — what a
    static_argnums/static_argnames value is allowed to be."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(is_literal(e) for e in node.elts)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return is_literal(node.operand)
    return False


def identifiers(tree) -> set:
    """Every Name id and Attribute attr in a tree — the cheap 'does this
    module mention X' test R001 uses on test files."""
    out = set()
    for n in ast.walk(tree):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
        elif isinstance(n, (ast.Import, ast.ImportFrom)):
            out.update(a.name for a in n.names)
    return out


def identifier_strings(tree):
    """(string, lineno) for every identifier-like string constant —
    how R006 reads the leaf names dist/sharding.py knows about.
    Docstrings and prose don't match (they contain spaces)."""
    for n in ast.walk(tree):
        if isinstance(n, ast.Constant) and isinstance(n.value, str):
            s = n.value.lstrip(".")
            if s.isidentifier():
                yield s, n.lineno


class CallRefs:
    """Alias-aware reference finder for `<module>.<attr>` call targets.

    Tracks `import jax`, `import jax as j`, and `from jax import jit
    [as J]`, then classifies expression nodes: `refs.is_ref(node,
    "jax", "jit")` is True for `jax.jit`, `j.jit` and bare `J`/`jit`.
    """

    def __init__(self, tree):
        self._mod_aliases: dict = {}     # alias -> real module name
        self._attr_aliases: dict = {}    # alias -> (module, attr)
        for n in ast.walk(tree):
            if isinstance(n, ast.Import):
                for a in n.names:
                    self._mod_aliases[a.asname or a.name] = a.name
            elif isinstance(n, ast.ImportFrom) and n.module:
                for a in n.names:
                    self._attr_aliases[a.asname or a.name] = (n.module,
                                                              a.name)

    def is_ref(self, node, module: str, attr: str) -> bool:
        if isinstance(node, ast.Attribute) and node.attr == attr \
                and isinstance(node.value, ast.Name):
            return self._mod_aliases.get(node.value.id) == module
        if isinstance(node, ast.Name):
            return self._attr_aliases.get(node.id) == (module, attr)
        return False
