"""The unit of repro-lint output: one `Finding` per violated invariant.

A finding is keyed for the suppression baseline by (rule_id, file,
message) — deliberately *without* the line number, so unrelated edits
that shift a grandfathered finding up or down the file do not churn the
baseline. The line still prints, for jumping to the site.
"""
from __future__ import annotations

from dataclasses import dataclass

SEVERITIES = ("error", "warning")


@dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at one site."""
    rule_id: str
    file: str          # repo-relative, "/"-separated
    line: int          # 1-based; 0 when the finding is file-level
    message: str
    severity: str = "error"

    def key(self) -> str:
        """Baseline identity: stable across line churn. Tabs separate
        the parts (messages never contain tabs — `validate` enforces)."""
        return f"{self.rule_id}\t{self.file}\t{self.message}"

    def render(self) -> str:
        loc = f"{self.file}:{self.line}" if self.line else self.file
        return f"{self.rule_id} {loc}: {self.message}"

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"bad severity {self.severity!r}")
        if "\t" in self.message or "\n" in self.message:
            raise ValueError("finding messages must be tab/newline-free "
                             "(they key the baseline)")


def sort_findings(findings) -> list:
    """Deterministic report/baseline order: rule, file, line, message."""
    return sorted(findings,
                  key=lambda f: (f.rule_id, f.file, f.line, f.message))
