"""Block-table paged KV cache: host-side page allocator over the device
page pool built by models.model.init_paged_cache.

Layout:
  - device pool, per attention layer: k/v pages (G, n_pages, page_size,
    Hkv, hd). Page 0 is the *null page* — never allocated; inactive
    batch rows and masked prefill padding write there so the scatter in
    the decode step needs no branch.
  - block table: (max_seqs, max_pages_per_seq) int32, row = sequence
    slot, entry = page id (0 for unused slots, which is always a valid
    DMA target for the Pallas kernel).

Sharding (`n_shards > 1`): the pool's page axis is partitioned into
`n_shards` equal contiguous blocks matching the GSPMD layout of the
device pool under `dist.sharding.cache_pspec` (pages on the "data"
axis shard the leading page blocks onto consecutive devices), and the
sequence slots are partitioned the same way (slot s lives on shard
`s // (max_seqs / n_shards)`, matching the batch-on-data layout of the
decode step's inputs). Every page a sequence ever touches — growth,
COW forks, shared prefixes — comes from its own shard's block, so the
decode gather and the prefill scatter stay device-local. The first
page of each shard's block (`null_page_of_shard`) is a per-shard
*reserve* page, never allocated: masked rows of that shard write there
(the engine routes inactive rows via a per-slot null-page row instead
of the constant 0). All allocator invariants below hold *per shard*;
with `n_shards == 1` the layout degenerates to the original global
pool (reserve page == null page 0).

Pages are *refcounted* so completed prefill pages can be shared between
sequences through the radix prefix index (serve/prefix_cache.py): a page
may appear in several block-table rows and/or be retained by the index.
A shared page is immutable — any writer must fork it first
(`cow_for_write`, copy-on-write), which preserves the invariant that a
page is only ever written while its refcount is exactly 1.

The allocator is plain numpy/python — allocation decisions are host-side
scheduler work (microseconds) while the pool itself stays on device and
is functionally updated (donated) by decode/prefill steps. COW forks
return (src, dst) page-id pairs; the engine applies them on device via
models.model.copy_pages before the write lands.

Invariants (asserted in tests/test_paged_kv.py, per shard in
tests/test_sharded_serve.py, and the property suite
tests/test_alloc_property.py):
  - refcount conservation: free_pages + live_pages == usable_pages
    (n_pages minus one reserve page per shard), where a live page
    (refcount > 0) counts once no matter how many rows or index nodes
    reference it; the same identity holds within each shard;
  - refcount[p] == (# slots whose block table holds p) + (1 if the
    prefix index retains p else 0);
  - no page is written while refcount > 1 (cow_for_write forks first);
  - reserve pages (the null page 0 and each shard's first page) are
    never allocated, shared, or forked;
  - every page owned by slot s belongs to shard_of_slot(s)'s block;
  - block-table entries beyond a sequence's page count are 0.
"""
from __future__ import annotations

import numpy as np

from repro.models.model import init_paged_cache, is_page_leaf


class OutOfPages(Exception):
    """Raised when an allocation cannot be satisfied; the scheduler
    responds by preempting a sequence (eviction) and retrying. The
    allocator first tries to reclaim unreferenced prefix-index pages."""


class PagedKVCache:
    def __init__(self, cfg, *, n_pages, page_size, max_seqs,
                 max_pages_per_seq=None, dtype=None, create_pool=True,
                 n_shards=1, kv_bits=0, kv_group_size=0):
        assert n_pages >= 2, "need at least the null page + one real page"
        assert n_shards >= 1
        assert n_pages % n_shards == 0, \
            f"n_pages={n_pages} must split evenly over {n_shards} shards"
        assert max_seqs % n_shards == 0, \
            f"max_seqs={max_seqs} must split evenly over {n_shards} shards"
        assert n_pages // n_shards >= 2, \
            "each shard needs its reserve page + one usable page"
        self.cfg = cfg
        self.page_size = int(page_size)
        self.n_pages = int(n_pages)
        self.max_seqs = int(max_seqs)
        self.n_shards = int(n_shards)
        self.pages_per_shard = self.n_pages // self.n_shards
        self.seqs_per_shard = self.max_seqs // self.n_shards
        if max_pages_per_seq is None:
            self.max_pages_per_seq = self.pages_per_shard - 1
        else:
            # explicit `is None` test: a falsy 0 must not silently fall
            # back to the pool-wide default (a sequence that may own
            # zero pages is a config bug, not a "use the default" ask)
            self.max_pages_per_seq = int(max_pages_per_seq)
            if self.max_pages_per_seq < 1:
                raise ValueError(
                    f"max_pages_per_seq={max_pages_per_seq!r}: must be "
                    ">= 1 (omit it or pass None for the per-shard "
                    "default)")
        self.kv_bits = int(kv_bits)
        self.kv_group_size = int(kv_group_size)
        self._dtype = dtype
        # the property-based allocator tests exercise the accounting
        # without paying for a device pool
        self.pool = (init_paged_cache(cfg, n_pages, page_size, max_seqs,
                                      dtype, kv_bits=self.kv_bits,
                                      kv_group_size=self.kv_group_size)
                     if create_pool else None)
        self._created_pool = bool(create_pool)
        self._pool_taken = False
        self.block_tables = np.zeros((max_seqs, self.max_pages_per_seq),
                                     np.int32)
        # monotone per-row versions: bumped on every block-table mutation
        # so the engine can mirror rows to a device-resident copy
        # incrementally instead of re-uploading the whole table per tick
        self.bt_version = np.zeros((max_seqs,), np.int64)
        # per-shard free lists; each shard's first page (page 0 for
        # shard 0 — the global null page) is the reserve page and never
        # enters a free list
        self._free_by_shard: list[list[int]] = [
            list(range((s + 1) * self.pages_per_shard - 1,
                       s * self.pages_per_shard, -1))
            for s in range(self.n_shards)]
        self._owned: list[list[int]] = [[] for _ in range(max_seqs)]
        self._active = np.zeros((max_seqs,), bool)
        self._refcount = np.zeros((n_pages,), np.int32)
        self.prefix_index = None          # set by RadixPrefixCache
        self.high_water = 0
        self.cow_forks = 0
        self.pages_allocated = 0

    # ---------------- shard geometry ----------------
    def shard_of_page(self, pid: int) -> int:
        return pid // self.pages_per_shard

    def shard_of_slot(self, slot: int) -> int:
        return slot // self.seqs_per_shard

    def null_page_of_shard(self, shard: int) -> int:
        """The shard's reserve page: masked/inactive rows of that shard
        write there (page 0 for shard 0 and for unsharded pools)."""
        return shard * self.pages_per_shard

    def is_reserve_page(self, pid: int) -> bool:
        """True for every shard's reserve page — page 0 and each
        shard block's first page. These are never allocated, so they
        must never gain references; `pid != 0` alone misses the
        shard > 0 reserves."""
        return pid % self.pages_per_shard == 0

    def bytes_per_page(self) -> int:
        """Device bytes one page id costs across all attention layers
        (K + V, codes + scales when binary-coded). Host-side math — no
        pool needed."""
        from repro.models.attention import paged_kv_page_bytes
        return paged_kv_page_bytes(
            self.cfg, self.page_size, self._dtype,
            kv_bits=self.kv_bits, kv_group_size=self.kv_group_size)

    def pool_bytes(self) -> int:
        return self.bytes_per_page() * self.n_pages

    def take_pool(self):
        """Hand the device pool to the caller (the engine functionally
        updates + donates it; keeping a reference here would defeat
        donation). compact() then takes the pool as an argument."""
        pool, self.pool = self.pool, None
        self._pool_taken = True
        return pool

    # ---------------- accounting ----------------
    @property
    def _free(self) -> list[int]:
        """Flat view of every free page (shard 0 first). Read-only:
        allocation pops from the per-shard lists."""
        if self.n_shards == 1:
            return self._free_by_shard[0]
        return [p for fl in self._free_by_shard for p in fl]

    @property
    def usable_pages(self) -> int:
        return self.n_pages - self.n_shards

    def usable_in_shard(self, shard: int = 0) -> int:
        # shards are equal-sized today; validate anyway so a bogus
        # shard id fails here, not as a plausible page count downstream
        assert 0 <= shard < self.n_shards, shard
        return self.pages_per_shard - 1

    @property
    def free_page_count(self) -> int:
        return sum(len(fl) for fl in self._free_by_shard)

    def free_in_shard(self, shard: int) -> int:
        return len(self._free_by_shard[shard])

    @property
    def used_pages(self) -> int:
        return self.usable_pages - self.free_page_count

    @property
    def live_pages(self) -> int:
        """Distinct pages with refcount > 0 (each counted once)."""
        return int((self._refcount > 0).sum())

    def live_in_shard(self, shard: int) -> int:
        lo = shard * self.pages_per_shard
        return int((self._refcount[lo:lo + self.pages_per_shard] > 0).sum())

    def refcount(self, pid: int) -> int:
        return int(self._refcount[pid])

    def utilization(self) -> float:
        return self.used_pages / max(self.usable_pages, 1)

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 1) // self.page_size)

    def active_slots(self):
        return [i for i in range(self.max_seqs) if self._active[i]]

    # ---------------- slot lifecycle ----------------
    def pick_shard(self) -> int | None:
        """Admission policy hook: the shard with the most free pages
        among shards that still have a free sequence slot (ties to the
        lowest shard id; None when every slot is taken). Trivially 0
        for unsharded pools with a free slot."""
        best, best_free = None, -1
        for s in range(self.n_shards):
            lo = s * self.seqs_per_shard
            if self._active[lo:lo + self.seqs_per_shard].all():
                continue
            if len(self._free_by_shard[s]) > best_free:
                best, best_free = s, len(self._free_by_shard[s])
        return best

    def alloc_slot(self, shard: int | None = None) -> int | None:
        """Claim the first free slot (within `shard`'s slot block when
        given)."""
        lo, hi = 0, self.max_seqs
        if shard is not None:
            lo = shard * self.seqs_per_shard
            hi = lo + self.seqs_per_shard
        for i in range(lo, hi):
            if not self._active[i]:
                self._active[i] = True
                return i
        return None

    def _reclaim(self, shortfall: int, shard: int) -> int:
        """Ask the prefix index to drop its least-recently-used
        unreferenced pages *in this shard*. Returns how many pages were
        freed."""
        if shortfall <= 0 or self.prefix_index is None:
            return 0
        return self.prefix_index.evict(shortfall, shard=shard)

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow slot's page list to cover n_tokens, allocating from the
        slot's shard; raises OutOfPages (allocating nothing) when that
        shard can't satisfy the growth, after reclaiming unreferenced
        prefix-index pages of the same shard."""
        assert self._active[slot], slot
        need = self.pages_for(n_tokens) - len(self._owned[slot])
        if need <= 0:
            return
        if self.pages_for(n_tokens) > self.max_pages_per_seq:
            raise OutOfPages(f"slot {slot}: {n_tokens} tokens exceed "
                             f"max_pages_per_seq={self.max_pages_per_seq}")
        shard = self.shard_of_slot(slot)
        free = self._free_by_shard[shard]
        if need > len(free):
            self._reclaim(need - len(free), shard)
        if need > len(free):
            raise OutOfPages(f"slot {slot}: need {need} pages, "
                             f"{len(free)} free in shard {shard}")
        for _ in range(need):
            pid = free.pop()
            idx = len(self._owned[slot])
            self._owned[slot].append(pid)
            self.block_tables[slot, idx] = pid
            self._refcount[pid] = 1
        self.bt_version[slot] += 1
        self.pages_allocated += need
        self.high_water = max(self.high_water, self.used_pages)

    def share(self, slot: int, page_ids) -> None:
        """Attach already-live pages (a matched prefix) to a fresh slot:
        the pages become the slot's leading block-table entries and gain
        one reference each. Must precede any ensure() growth so page
        index i keeps covering tokens [i*page_size, (i+1)*page_size).
        Shared pages must live in the slot's shard — cross-shard
        attachment would break page locality."""
        assert self._active[slot], slot
        assert not self._owned[slot], "share() must precede suffix alloc"
        assert len(page_ids) <= self.max_pages_per_seq
        shard = self.shard_of_slot(slot)
        for idx, pid in enumerate(page_ids):
            assert not self.is_reserve_page(int(pid)) \
                and self._refcount[pid] > 0, pid
            assert self.shard_of_page(int(pid)) == shard, \
                (slot, pid, "cross-shard prefix attach")
            self._owned[slot].append(int(pid))
            self.block_tables[slot, idx] = pid
            self._refcount[pid] += 1
        if page_ids:
            self.bt_version[slot] += 1

    def cow_for_write(self, slot: int, start_tok: int, end_tok: int):
        """Copy-on-write: the slot is about to write token positions
        [start_tok, end_tok). Any of its pages in that range with
        refcount > 1 is forked onto a fresh page (the shared original
        keeps its other references). Returns the [(src, dst), ...]
        page copies the caller must apply to the device pool BEFORE the
        write. Raises OutOfPages (forking nothing) when the pool cannot
        supply the fork pages."""
        if end_tok <= start_tok:
            return []
        owned = self._owned[slot]
        p0, p1 = start_tok // self.page_size, (end_tok - 1) // self.page_size
        assert p1 < len(owned), (slot, start_tok, end_tok, len(owned))
        shared = [i for i in range(p0, p1 + 1)
                  if self._refcount[owned[i]] > 1]
        if not shared:
            return []
        sh = self.shard_of_slot(slot)
        free = self._free_by_shard[sh]
        if len(shared) > len(free):
            self._reclaim(len(shared) - len(free), sh)
        if len(shared) > len(free):
            raise OutOfPages(f"slot {slot}: {len(shared)} COW forks, "
                             f"{len(free)} free in shard {sh}")
        copies = []
        for i in shared:
            old = owned[i]
            new = free.pop()
            self._refcount[old] -= 1          # was > 1, never hits 0
            self._refcount[new] = 1
            owned[i] = new
            self.block_tables[slot, i] = new
            copies.append((old, new))
        self.bt_version[slot] += 1
        self.cow_forks += len(copies)
        self.pages_allocated += len(copies)
        self.high_water = max(self.high_water, self.used_pages)
        return copies

    # ---------------- prefix-index references ----------------
    def ref(self, pid: int) -> None:
        """Take a prefix-index reference on a live page."""
        assert not self.is_reserve_page(int(pid)) \
            and self._refcount[pid] > 0, pid
        self._refcount[pid] += 1

    def unref(self, pid: int) -> None:
        """Drop a reference; a page reaching refcount 0 returns to its
        home shard's free list (contents are reused by overwrite)."""
        assert self._refcount[pid] > 0, pid
        self._refcount[pid] -= 1
        if self._refcount[pid] == 0:
            self._free_by_shard[self.shard_of_page(pid)].append(pid)

    def release(self, slot: int) -> None:
        """Drop a sequence's references (completion or preemption).
        Pages still referenced elsewhere (shared prefixes, the radix
        index) stay live; the rest return to the free list."""
        for pid in self._owned[slot]:
            self.unref(pid)
        self._owned[slot] = []
        self.block_tables[slot, :] = 0
        self.bt_version[slot] += 1
        self._active[slot] = False

    def truncate(self, slot: int, n_tokens: int) -> int:
        """Speculative rollback: drop the slot's trailing pages so it
        owns exactly `pages_for(n_tokens)` — rejected draft tokens past
        a page boundary release their pages (unref: a page shared via
        the prefix index stays live for its other readers). Rejected
        tokens WITHIN the last kept page need no work: the engine
        truncates `pos`, attention masks by context length, and the
        next write overwrites the stale tail — identical to how partial
        tail pages always behave. Returns the number of pages freed."""
        keep = self.pages_for(n_tokens)
        owned = self._owned[slot]
        assert keep <= len(owned), (slot, n_tokens, len(owned))
        dropped = owned[keep:]
        self.block_tables[slot, keep:keep + len(dropped)] = 0
        del owned[keep:]
        for pid in dropped:
            self.unref(pid)
        if dropped:
            self.bt_version[slot] += 1
        return len(dropped)

    def owned_pages(self, slot: int):
        return list(self._owned[slot])

    # ---------------- defrag ----------------
    def compact(self, pool=None):
        """Remap live pages onto the lowest page ids *of their shard*
        (gather on device, rewrite block tables + prefix index) and
        return the compacted pool. Paging has no *internal*
        fragmentation to fix — this exists so long-lived engines can
        shrink the pool's high-water footprint (e.g. before
        snapshotting a pool slice). Pages never cross shards, so the
        gather permutation is block-diagonal over the page axis and the
        device move stays shard-local under the GSPMD layout. Pass the
        pool explicitly when the engine took ownership via
        take_pool()."""
        import jax
        import jax.numpy as jnp

        if pool is None:
            assert not (self._created_pool and self._pool_taken), \
                "pool was taken; pass it in"
            pool = self.pool

        mapping: dict[int, int] = {}
        next_in_shard = [s * self.pages_per_shard + 1
                         for s in range(self.n_shards)]

        def remap(pid: int) -> int:
            if pid not in mapping:
                sh = self.shard_of_page(pid)
                mapping[pid] = next_in_shard[sh]
                next_in_shard[sh] += 1
            return mapping[pid]

        for slot in range(self.max_seqs):
            for j, pid in enumerate(self._owned[slot]):
                new = remap(pid)
                self._owned[slot][j] = new
                self.block_tables[slot, j] = new
            self.bt_version[slot] += 1
        if self.prefix_index is not None:
            self.prefix_index.remap(remap)
        # any remaining live page (shouldn't exist outside slots/index,
        # but keep the permutation total over live pages regardless)
        for pid in np.flatnonzero(self._refcount[1:] > 0) + 1:
            remap(int(pid))

        src = np.arange(self.n_pages, dtype=np.int32)
        new_rc = np.zeros_like(self._refcount)
        for old, new in mapping.items():
            src[new] = old
            new_rc[new] = self._refcount[old]
        self._refcount = new_rc

        if pool is not None:
            def move(leaf):
                # page pools have the page axis at dim 1 (after the group
                # stack); per-slot state (mamba) is left alone. On a
                # binary-coded pool this moves codes AND scale leaves.
                if is_page_leaf(leaf, self.n_pages):
                    return leaf[:, jnp.asarray(src)]
                return leaf

            pool = jax.tree.map(move, pool)
        self._free_by_shard = [
            list(range((s + 1) * self.pages_per_shard - 1,
                       next_in_shard[s] - 1, -1))
            for s in range(self.n_shards)]
        if not self._pool_taken:
            self.pool = pool
        return pool
