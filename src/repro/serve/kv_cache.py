"""Block-table paged KV cache: host-side page allocator over the device
page pool built by models.model.init_paged_cache.

Layout:
  - device pool, per attention layer: k/v pages (G, n_pages, page_size,
    Hkv, hd). Page 0 is the *null page* — never allocated; inactive
    batch rows and masked prefill padding write there so the scatter in
    the decode step needs no branch.
  - block table: (max_seqs, max_pages_per_seq) int32, row = sequence
    slot, entry = page id (0 for unused slots, which is always a valid
    DMA target for the Pallas kernel).

Pages are *refcounted* so completed prefill pages can be shared between
sequences through the radix prefix index (serve/prefix_cache.py): a page
may appear in several block-table rows and/or be retained by the index.
A shared page is immutable — any writer must fork it first
(`cow_for_write`, copy-on-write), which preserves the invariant that a
page is only ever written while its refcount is exactly 1.

The allocator is plain numpy/python — allocation decisions are host-side
scheduler work (microseconds) while the pool itself stays on device and
is functionally updated (donated) by decode/prefill steps. COW forks
return (src, dst) page-id pairs; the engine applies them on device via
models.model.copy_pages before the write lands.

Invariants (asserted in tests/test_paged_kv.py and the property suite
tests/test_alloc_property.py):
  - refcount conservation: free_pages + live_pages == n_pages - 1, where
    a live page (refcount > 0) counts once no matter how many rows or
    index nodes reference it;
  - refcount[p] == (# slots whose block table holds p) + (1 if the
    prefix index retains p else 0);
  - no page is written while refcount > 1 (cow_for_write forks first);
  - the null page 0 is never allocated, shared, or forked;
  - block-table entries beyond a sequence's page count are 0.
"""
from __future__ import annotations

import numpy as np

from repro.models.model import init_paged_cache


class OutOfPages(Exception):
    """Raised when an allocation cannot be satisfied; the scheduler
    responds by preempting a sequence (eviction) and retrying. The
    allocator first tries to reclaim unreferenced prefix-index pages."""


class PagedKVCache:
    def __init__(self, cfg, *, n_pages, page_size, max_seqs,
                 max_pages_per_seq=None, dtype=None, create_pool=True):
        assert n_pages >= 2, "need at least the null page + one real page"
        self.cfg = cfg
        self.page_size = int(page_size)
        self.n_pages = int(n_pages)
        self.max_seqs = int(max_seqs)
        self.max_pages_per_seq = (int(max_pages_per_seq)
                                  if max_pages_per_seq else n_pages - 1)
        # the property-based allocator tests exercise the accounting
        # without paying for a device pool
        self.pool = (init_paged_cache(cfg, n_pages, page_size, max_seqs,
                                      dtype) if create_pool else None)
        self._created_pool = bool(create_pool)
        self._pool_taken = False
        self.block_tables = np.zeros((max_seqs, self.max_pages_per_seq),
                                     np.int32)
        # monotone per-row versions: bumped on every block-table mutation
        # so the engine can mirror rows to a device-resident copy
        # incrementally instead of re-uploading the whole table per tick
        self.bt_version = np.zeros((max_seqs,), np.int64)
        # page 0 reserved as the null page
        self._free = list(range(n_pages - 1, 0, -1))
        self._owned: list[list[int]] = [[] for _ in range(max_seqs)]
        self._active = np.zeros((max_seqs,), bool)
        self._refcount = np.zeros((n_pages,), np.int32)
        self.prefix_index = None          # set by RadixPrefixCache
        self.high_water = 0
        self.cow_forks = 0
        self.pages_allocated = 0

    def take_pool(self):
        """Hand the device pool to the caller (the engine functionally
        updates + donates it; keeping a reference here would defeat
        donation). compact() then takes the pool as an argument."""
        pool, self.pool = self.pool, None
        self._pool_taken = True
        return pool

    # ---------------- accounting ----------------
    @property
    def usable_pages(self) -> int:
        return self.n_pages - 1

    @property
    def free_page_count(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.usable_pages - len(self._free)

    @property
    def live_pages(self) -> int:
        """Distinct pages with refcount > 0 (each counted once)."""
        return int((self._refcount > 0).sum())

    def refcount(self, pid: int) -> int:
        return int(self._refcount[pid])

    def utilization(self) -> float:
        return self.used_pages / max(self.usable_pages, 1)

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 1) // self.page_size)

    def active_slots(self):
        return [i for i in range(self.max_seqs) if self._active[i]]

    # ---------------- slot lifecycle ----------------
    def alloc_slot(self) -> int | None:
        for i in range(self.max_seqs):
            if not self._active[i]:
                self._active[i] = True
                return i
        return None

    def _reclaim(self, shortfall: int) -> int:
        """Ask the prefix index to drop its least-recently-used
        unreferenced pages. Returns how many pages were freed."""
        if shortfall <= 0 or self.prefix_index is None:
            return 0
        return self.prefix_index.evict(shortfall)

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow slot's page list to cover n_tokens; raises OutOfPages
        (allocating nothing) when the pool can't satisfy the growth,
        after reclaiming unreferenced prefix-index pages."""
        assert self._active[slot], slot
        need = self.pages_for(n_tokens) - len(self._owned[slot])
        if need <= 0:
            return
        if self.pages_for(n_tokens) > self.max_pages_per_seq:
            raise OutOfPages(f"slot {slot}: {n_tokens} tokens exceed "
                             f"max_pages_per_seq={self.max_pages_per_seq}")
        if need > len(self._free):
            self._reclaim(need - len(self._free))
        if need > len(self._free):
            raise OutOfPages(f"slot {slot}: need {need} pages, "
                             f"{len(self._free)} free")
        for _ in range(need):
            pid = self._free.pop()
            idx = len(self._owned[slot])
            self._owned[slot].append(pid)
            self.block_tables[slot, idx] = pid
            self._refcount[pid] = 1
        self.bt_version[slot] += 1
        self.pages_allocated += need
        self.high_water = max(self.high_water, self.used_pages)

    def share(self, slot: int, page_ids) -> None:
        """Attach already-live pages (a matched prefix) to a fresh slot:
        the pages become the slot's leading block-table entries and gain
        one reference each. Must precede any ensure() growth so page
        index i keeps covering tokens [i*page_size, (i+1)*page_size)."""
        assert self._active[slot], slot
        assert not self._owned[slot], "share() must precede suffix alloc"
        assert len(page_ids) <= self.max_pages_per_seq
        for idx, pid in enumerate(page_ids):
            assert pid != 0 and self._refcount[pid] > 0, pid
            self._owned[slot].append(int(pid))
            self.block_tables[slot, idx] = pid
            self._refcount[pid] += 1
        if page_ids:
            self.bt_version[slot] += 1

    def cow_for_write(self, slot: int, start_tok: int, end_tok: int):
        """Copy-on-write: the slot is about to write token positions
        [start_tok, end_tok). Any of its pages in that range with
        refcount > 1 is forked onto a fresh page (the shared original
        keeps its other references). Returns the [(src, dst), ...]
        page copies the caller must apply to the device pool BEFORE the
        write. Raises OutOfPages (forking nothing) when the pool cannot
        supply the fork pages."""
        if end_tok <= start_tok:
            return []
        owned = self._owned[slot]
        p0, p1 = start_tok // self.page_size, (end_tok - 1) // self.page_size
        assert p1 < len(owned), (slot, start_tok, end_tok, len(owned))
        shared = [i for i in range(p0, p1 + 1)
                  if self._refcount[owned[i]] > 1]
        if not shared:
            return []
        if len(shared) > len(self._free):
            self._reclaim(len(shared) - len(self._free))
        if len(shared) > len(self._free):
            raise OutOfPages(f"slot {slot}: {len(shared)} COW forks, "
                             f"{len(self._free)} free")
        copies = []
        for i in shared:
            old = owned[i]
            new = self._free.pop()
            self._refcount[old] -= 1          # was > 1, never hits 0
            self._refcount[new] = 1
            owned[i] = new
            self.block_tables[slot, i] = new
            copies.append((old, new))
        self.bt_version[slot] += 1
        self.cow_forks += len(copies)
        self.pages_allocated += len(copies)
        self.high_water = max(self.high_water, self.used_pages)
        return copies

    # ---------------- prefix-index references ----------------
    def ref(self, pid: int) -> None:
        """Take a prefix-index reference on a live page."""
        assert pid != 0 and self._refcount[pid] > 0, pid
        self._refcount[pid] += 1

    def unref(self, pid: int) -> None:
        """Drop a reference; a page reaching refcount 0 returns to the
        free list (contents are reused by overwrite)."""
        assert self._refcount[pid] > 0, pid
        self._refcount[pid] -= 1
        if self._refcount[pid] == 0:
            self._free.append(pid)

    def release(self, slot: int) -> None:
        """Drop a sequence's references (completion or preemption).
        Pages still referenced elsewhere (shared prefixes, the radix
        index) stay live; the rest return to the free list."""
        for pid in self._owned[slot]:
            self.unref(pid)
        self._owned[slot] = []
        self.block_tables[slot, :] = 0
        self.bt_version[slot] += 1
        self._active[slot] = False

    def owned_pages(self, slot: int):
        return list(self._owned[slot])

    # ---------------- defrag ----------------
    def compact(self, pool=None):
        """Remap live pages onto the lowest page ids (gather on device,
        rewrite block tables + prefix index) and return the compacted
        pool. Paging has no *internal* fragmentation to fix — this
        exists so long-lived engines can shrink the pool's high-water
        footprint (e.g. before snapshotting a pool slice). Pass the pool
        explicitly when the engine took ownership via take_pool()."""
        import jax
        import jax.numpy as jnp

        if pool is None:
            assert not (self._created_pool and self._pool_taken), \
                "pool was taken; pass it in"
            pool = self.pool

        mapping: dict[int, int] = {}

        def remap(pid: int) -> int:
            if pid not in mapping:
                mapping[pid] = len(mapping) + 1
            return mapping[pid]

        for slot in range(self.max_seqs):
            for j, pid in enumerate(self._owned[slot]):
                new = remap(pid)
                self._owned[slot][j] = new
                self.block_tables[slot, j] = new
            self.bt_version[slot] += 1
        if self.prefix_index is not None:
            self.prefix_index.remap(remap)
        # any remaining live page (shouldn't exist outside slots/index,
        # but keep the permutation total over live pages regardless)
        for pid in np.flatnonzero(self._refcount[1:] > 0) + 1:
            remap(int(pid))

        src = np.arange(self.n_pages, dtype=np.int32)
        new_rc = np.zeros_like(self._refcount)
        for old, new in mapping.items():
            src[new] = old
            new_rc[new] = self._refcount[old]
        self._refcount = new_rc
        nxt = len(mapping) + 1

        if pool is not None:
            def move(leaf):
                # page pools have the page axis at dim 1 (after the group
                # stack); per-slot state (mamba) is left alone
                if leaf.ndim == 5 and leaf.shape[1] == self.n_pages:
                    return leaf[:, jnp.asarray(src)]
                return leaf

            pool = jax.tree.map(move, pool)
        self._free = list(range(self.n_pages - 1, nxt - 1, -1))
        if not self._pool_taken:
            self.pool = pool
        return pool
