"""Block-table paged KV cache: host-side page allocator over the device
page pool built by models.model.init_paged_cache.

Layout:
  - device pool, per attention layer: k/v pages (G, n_pages, page_size,
    Hkv, hd). Page 0 is the *null page* — never allocated; inactive
    batch rows and masked prefill padding write there so the scatter in
    the decode step needs no branch.
  - block table: (max_seqs, max_pages_per_seq) int32, row = sequence
    slot, entry = page id (0 for unused slots, which is always a valid
    DMA target for the Pallas kernel).

The allocator is plain numpy/python — allocation decisions are host-side
scheduler work (microseconds) while the pool itself stays on device and
is functionally updated (donated) by decode/prefill steps.

Invariants (asserted in tests/test_paged_kv.py):
  - a page is owned by at most one sequence;
  - free_pages + sum(owned) == n_pages - 1 (null page excluded);
  - block-table entries beyond a sequence's page count are 0.
"""
from __future__ import annotations

import numpy as np

from repro.models.model import init_paged_cache


class OutOfPages(Exception):
    """Raised when an allocation cannot be satisfied; the scheduler
    responds by preempting a sequence (eviction) and retrying."""


class PagedKVCache:
    def __init__(self, cfg, *, n_pages, page_size, max_seqs,
                 max_pages_per_seq=None, dtype=None):
        assert n_pages >= 2, "need at least the null page + one real page"
        self.cfg = cfg
        self.page_size = int(page_size)
        self.n_pages = int(n_pages)
        self.max_seqs = int(max_seqs)
        self.max_pages_per_seq = (int(max_pages_per_seq)
                                  if max_pages_per_seq else n_pages - 1)
        self.pool = init_paged_cache(cfg, n_pages, page_size, max_seqs,
                                     dtype)
        self._pool_taken = False
        self.block_tables = np.zeros((max_seqs, self.max_pages_per_seq),
                                     np.int32)
        # page 0 reserved as the null page
        self._free = list(range(n_pages - 1, 0, -1))
        self._owned: list[list[int]] = [[] for _ in range(max_seqs)]
        self._active = np.zeros((max_seqs,), bool)
        self.high_water = 0

    def take_pool(self):
        """Hand the device pool to the caller (the engine functionally
        updates + donates it; keeping a reference here would defeat
        donation). compact() then takes the pool as an argument."""
        pool, self.pool = self.pool, None
        self._pool_taken = True
        return pool

    # ---------------- accounting ----------------
    @property
    def usable_pages(self) -> int:
        return self.n_pages - 1

    @property
    def free_page_count(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.usable_pages - len(self._free)

    def utilization(self) -> float:
        return self.used_pages / max(self.usable_pages, 1)

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 1) // self.page_size)

    def active_slots(self):
        return [i for i in range(self.max_seqs) if self._active[i]]

    # ---------------- slot lifecycle ----------------
    def alloc_slot(self) -> int | None:
        for i in range(self.max_seqs):
            if not self._active[i]:
                self._active[i] = True
                return i
        return None

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow slot's page list to cover n_tokens; raises OutOfPages
        (allocating nothing) when the pool can't satisfy the growth."""
        assert self._active[slot], slot
        need = self.pages_for(n_tokens) - len(self._owned[slot])
        if need <= 0:
            return
        if self.pages_for(n_tokens) > self.max_pages_per_seq:
            raise OutOfPages(f"slot {slot}: {n_tokens} tokens exceed "
                             f"max_pages_per_seq={self.max_pages_per_seq}")
        if need > len(self._free):
            raise OutOfPages(f"slot {slot}: need {need} pages, "
                             f"{len(self._free)} free")
        for _ in range(need):
            pid = self._free.pop()
            idx = len(self._owned[slot])
            self._owned[slot].append(pid)
            self.block_tables[slot, idx] = pid
        self.high_water = max(self.high_water, self.used_pages)

    def release(self, slot: int) -> None:
        """Free a sequence's pages (completion or preemption). The pool
        contents are left as-is — pages are reused by overwrite."""
        self._free.extend(reversed(self._owned[slot]))
        self._owned[slot] = []
        self.block_tables[slot, :] = 0
        self._active[slot] = False

    def owned_pages(self, slot: int):
        return list(self._owned[slot])

    # ---------------- defrag ----------------
    def compact(self, pool=None):
        """Remap live pages onto the lowest page ids (gather on device,
        rewrite block tables) and return the compacted pool. Paging has
        no *internal* fragmentation to fix — this exists so long-lived
        engines can shrink the pool's high-water footprint (e.g. before
        snapshotting a pool slice). Pass the pool explicitly when the
        engine took ownership via take_pool()."""
        import jax
        import jax.numpy as jnp

        if pool is None:
            assert not self._pool_taken, "pool was taken; pass it in"
            pool = self.pool

        src = np.arange(self.n_pages, dtype=np.int32)
        nxt = 1
        for slot in range(self.max_seqs):
            for j, pid in enumerate(self._owned[slot]):
                src[nxt] = pid
                self._owned[slot][j] = nxt
                self.block_tables[slot, j] = nxt
                nxt += 1

        def move(leaf):
            # page pools have the page axis at dim 1 (after the group
            # stack); per-slot state (mamba) is left alone
            if leaf.ndim == 5 and leaf.shape[1] == self.n_pages:
                return leaf[:, jnp.asarray(src)]
            return leaf

        pool = jax.tree.map(move, pool)
        self._free = list(range(self.n_pages - 1, nxt - 1, -1))
        if not self._pool_taken:
            self.pool = pool
        return pool
