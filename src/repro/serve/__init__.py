from repro.serve.engine import DenseSlotPool, Request, ServeEngine
from repro.serve.kv_cache import OutOfPages, PagedKVCache
from repro.serve.prefix_cache import RadixPrefixCache
from repro.serve.scheduler import RequestMetrics, Scheduler
from repro.serve.stats import EngineStats

__all__ = ["ServeEngine", "Request", "PagedKVCache", "OutOfPages",
           "Scheduler", "RequestMetrics", "DenseSlotPool",
           "RadixPrefixCache", "EngineStats"]
