"""Pooled fixed-size state slabs for recurrent (Mamba/SSM) layers.

Recurrent state is O(1) per sequence — one (d_inner, d_state) SSM
carry plus a (d_conv-1, d_inner) conv window per layer — so it doesn't
page like O(T) attention K/V. It still needs pooled admission control:
"can this sequence get state storage" is the same capacity question as
"can this sequence get pages", and a serving engine that admits on KV
pages alone would oversubscribe the state rows. StateSlabPool answers
it with one fixed-size *slab* per admitted sequence, under the same
allocator invariants as PagedKVCache (see serve/kv_cache.py):

  - per-shard slab blocks matching the batch-on-data GSPMD layout
    (slot s draws from shard s // seqs_per_shard's block);
  - each shard's first slab is a *reserve* slab, never allocated
    (conservation arithmetic mirrors the pool's reserve pages);
  - refcounted slabs with conservation:
    live_slabs + free_slab_count == usable_slabs (= n_slabs - n_shards).
    Recurrent state is write-per-step, so a slab's refcount is only
    ever 0 or 1 — there is no COW analogue — but the accounting is kept
    identical so the property suite (tests/test_alloc_property.py) runs
    the same conservation checks against both allocators;
  - failed allocations raise the same OutOfPages the page pool raises,
    allocating nothing: the scheduler treats slab exhaustion exactly
    like page exhaustion (decline admission / preempt);
  - compact() remaps live slabs onto the lowest ids of their shard,
    like PagePool.compact's block-diagonal page remap.

The device state rows themselves live in the paged cache pytree
(init_paged_cache gives mamba layers (G, max_seqs, ...) per-slot rows
indexed directly by slot); the slab pool is the host-side capacity and
lifecycle layer, deciding *whether* a slot may hold state at all.
"""
from __future__ import annotations

import numpy as np

from repro.serve.kv_cache import OutOfPages


class StateSlabPool:
    def __init__(self, cfg, *, n_slabs, max_seqs, n_shards=1, dtype=None):
        assert n_slabs >= 2, "need at least the reserve slab + one usable"
        assert n_shards >= 1
        assert n_slabs % n_shards == 0, \
            f"n_slabs={n_slabs} must split evenly over {n_shards} shards"
        assert max_seqs % n_shards == 0, \
            f"max_seqs={max_seqs} must split evenly over {n_shards} shards"
        assert n_slabs // n_shards >= 2, \
            "each shard needs its reserve slab + one usable slab"
        self.cfg = cfg
        self.n_slabs = int(n_slabs)
        self.max_seqs = int(max_seqs)
        self.n_shards = int(n_shards)
        self.slabs_per_shard = self.n_slabs // self.n_shards
        self.seqs_per_shard = self.max_seqs // self.n_shards
        self._dtype = dtype
        # per-shard free lists; each shard's first slab is the reserve
        self._free_by_shard: list[list[int]] = [
            list(range((s + 1) * self.slabs_per_shard - 1,
                       s * self.slabs_per_shard, -1))
            for s in range(self.n_shards)]
        self._refcount = np.zeros((n_slabs,), np.int32)
        self._slab_of_slot = np.full((max_seqs,), -1, np.int32)
        self.high_water = 0
        self.slabs_allocated = 0

    # ---------------- shard geometry ----------------
    def shard_of_slab(self, sid: int) -> int:
        return sid // self.slabs_per_shard

    def shard_of_slot(self, slot: int) -> int:
        return slot // self.seqs_per_shard

    def is_reserve_slab(self, sid: int) -> bool:
        return sid % self.slabs_per_shard == 0

    # ---------------- accounting ----------------
    @property
    def usable_slabs(self) -> int:
        return self.n_slabs - self.n_shards

    def usable_in_shard(self, shard: int = 0) -> int:
        assert 0 <= shard < self.n_shards, shard
        return self.slabs_per_shard - 1

    @property
    def free_slab_count(self) -> int:
        return sum(len(fl) for fl in self._free_by_shard)

    def free_in_shard(self, shard: int) -> int:
        return len(self._free_by_shard[shard])

    @property
    def used_slabs(self) -> int:
        return self.usable_slabs - self.free_slab_count

    @property
    def live_slabs(self) -> int:
        """Distinct slabs with refcount > 0 (each counted once)."""
        return int((self._refcount > 0).sum())

    def live_in_shard(self, shard: int) -> int:
        lo = shard * self.slabs_per_shard
        return int((self._refcount[lo:lo + self.slabs_per_shard] > 0).sum())

    def refcount(self, sid: int) -> int:
        return int(self._refcount[sid])

    def slab_of(self, slot: int) -> int | None:
        sid = int(self._slab_of_slot[slot])
        return None if sid < 0 else sid

    def bytes_per_slab(self) -> int:
        """Device bytes one slab holds across all recurrent layers: the
        fp32 SSM carry plus the conv window, per mamba pattern position
        x the n_groups scan stack. Host-side math — the single owner of
        the state-capacity arithmetic (EngineStats, the capacity banner
        and SERVING.md's formula all read it)."""
        import jax.numpy as jnp
        mc = self.cfg.mamba
        if mc is None:
            return 0
        di = self.cfg.d_inner
        itemsize = jnp.dtype(self._dtype or self.cfg.dtype).itemsize
        ssm = di * mc.d_state * 4                       # carried in fp32
        conv = (mc.d_conv - 1) * di * itemsize
        n_mamba = sum(1 for s in self.cfg.pattern
                      if s.kind != "attn") * self.cfg.n_groups
        return (ssm + conv) * n_mamba

    def pool_bytes(self) -> int:
        return self.bytes_per_slab() * self.n_slabs

    # ---------------- lifecycle ----------------
    def alloc(self, slot: int) -> int:
        """Claim one slab for `slot` from its shard's block; raises
        OutOfPages (allocating nothing) when the shard is dry. A slot
        holds at most one slab — recurrent state never grows."""
        assert self._slab_of_slot[slot] < 0, (slot, "already holds a slab")
        shard = self.shard_of_slot(slot)
        free = self._free_by_shard[shard]
        if not free:
            raise OutOfPages(
                f"slot {slot}: no free state slab in shard {shard}")
        sid = free.pop()
        self._refcount[sid] = 1
        self._slab_of_slot[slot] = sid
        self.slabs_allocated += 1
        self.high_water = max(self.high_water, self.used_slabs)
        return sid

    def release(self, slot: int) -> None:
        """Return `slot`'s slab (completion or preemption). Idempotent
        for slots that hold none — the scheduler releases every slot
        uniformly, attention-only sequences included."""
        sid = int(self._slab_of_slot[slot])
        if sid < 0:
            return
        assert self._refcount[sid] == 1, (slot, sid)
        self._refcount[sid] = 0
        self._free_by_shard[self.shard_of_slab(sid)].append(sid)
        self._slab_of_slot[slot] = -1

    # ---------------- defrag ----------------
    def compact(self) -> dict[int, int]:
        """Remap live slabs onto the lowest ids of their shard and
        return the {old: new} mapping (host-side only: the device state
        rows are indexed by slot, not slab id, so no device move is
        needed — parity with PagePool.compact's contract is what the
        invariant suite checks)."""
        mapping: dict[int, int] = {}
        next_in_shard = [s * self.slabs_per_shard + 1
                         for s in range(self.n_shards)]
        for slot in range(self.max_seqs):
            sid = int(self._slab_of_slot[slot])
            if sid < 0:
                continue
            sh = self.shard_of_slab(sid)
            mapping[sid] = next_in_shard[sh]
            next_in_shard[sh] += 1
            self._slab_of_slot[slot] = mapping[sid]
        new_rc = np.zeros_like(self._refcount)
        for old, new in mapping.items():
            new_rc[new] = self._refcount[old]
        self._refcount = new_rc
        self._free_by_shard = [
            list(range((s + 1) * self.slabs_per_shard - 1,
                       next_in_shard[s] - 1, -1))
            for s in range(self.n_shards)]
        return mapping
