"""Radix prefix index: maps token prefixes to completed, immutable KV
pages so a request whose prompt shares an N-token prefix with earlier
traffic skips N tokens of prefill and allocates only its suffix pages.

Structure
  The tree is page-granular: every node covers the tokens of exactly one
  physical page. Full nodes (page_size tokens) are keyed by their token
  tuple in the parent's `children` dict — lookup of a full page is one
  hash probe. Partial *tail* nodes (< page_size tokens, the unaligned
  end of an inserted sequence) live in the parent's `tails` list; only
  full nodes may have descendants, so every root-to-node path spells a
  page-aligned token prefix.

Sharing & COW
  A lookup may end inside a node: the longest common prefix of the
  remaining prompt and a child's key is still shareable, because the
  borrowing sequence forks that page (copy-on-write, kv_cache.py)
  before its own suffix tokens are written into it. Whole-page matches
  are shared with no copy at all.

Refcounts & eviction
  Every node holds exactly one reference on its page (PagedKVCache.ref),
  taken at insert and dropped at evict. Eviction is leaf-first and
  *hit-rate-aware*: among nodes whose page has refcount 1 (index-only —
  no running sequence is using them), cold leaves (fewest lookup hits)
  go first, least-recently-used within the same hit count — a prefix
  that keeps earning hits (a hot system prompt) outlives one-shot
  prompts that merely happen to be recent. A node whose page is
  referenced by any sequence is pinned, and so are its ancestors,
  because sequences attach matched chains from the root. The allocator
  calls `evict` automatically when an allocation would otherwise fail,
  so cached prefixes are always sacrificed before any running sequence
  is preempted.

Sharded pools
  Over a sharded PagedKVCache the index is shard-local: a chain's shard
  is the shard of its pages (one insert always comes from one slot, so
  a chain never mixes shards), child nodes are keyed by (shard, token
  tuple), and `lookup(..., shard=s)` only matches chains whose pages a
  shard-s slot can attach. The same token prefix may therefore be
  cached once per shard — that is the cost of keeping every gather
  device-local. Eviction accepts the same shard filter so allocator
  pressure in one shard never drains another shard's cached prefixes.
"""
from __future__ import annotations

import numpy as np

# cap on distinct partial tails cached under one parent: tails are
# matched by linear scan, and a hot parent (e.g. a system prompt) could
# otherwise accumulate one tail per distinct first-suffix-page
MAX_TAILS = 8


class _Node:
    __slots__ = ("key", "page", "n_tokens", "children", "tails", "parent",
                 "last_used", "shard", "hits")

    def __init__(self, key, page, n_tokens, parent, shard=0):
        self.key = key                  # tuple of tokens this page holds
        self.page = page                # physical page id
        self.n_tokens = n_tokens        # valid tokens in the page
        self.children = {}              # full nodes, (shard, key) -> _Node
        self.tails = []                 # partial-page nodes
        self.parent = parent
        self.last_used = 0
        self.shard = shard              # home shard of self.page
        self.hits = 0                   # lookup matches (eviction warmth)

    def is_leaf(self):
        return not self.children and not self.tails


def _lcp(key, toks) -> int:
    n = 0
    for a, b in zip(key, toks):
        if a != b:
            break
        n += 1
    return n


class RadixPrefixCache:
    """Token-prefix -> page-chain index over a PagedKVCache.

    `max_cached_pages` caps how many pages the index may retain: a
    long-running engine otherwise lets every finished request park its
    pages here until the index pins the whole pool and every admission
    pays a reclaim walk. The default leaves at least one page of
    headroom per sequence slot. Enforcement is best-effort LRU at
    insert time — pages also referenced by a running sequence are
    pinned and never count against a *running* workload's correctness.
    """

    def __init__(self, kv, max_cached_pages: int | None = None):
        self.kv = kv
        self.page = kv.page_size
        self.root = _Node((), 0, 0, None)
        self.max_cached_pages = (
            int(max_cached_pages) if max_cached_pages is not None
            else max(kv.usable_pages - kv.max_seqs, 1))
        self._pages = 0           # retained-page count (== node count)
        self._tick = 0
        self.hits = 0             # admissions served from the index
        self.lookups = 0          # lookup() calls (hit-rate denominator)
        self.tokens_saved = 0
        self.evictions = 0
        kv.prefix_index = self

    @property
    def hit_rate(self) -> float:
        """Fraction of lookups that ended in an attached prefix (the
        scheduler counts one hit per admission it serves from the
        index); exported through the bench counters as
        `prefix_hit_rate`."""
        return self.hits / max(self.lookups, 1)

    def _touch(self, node: _Node, *, hit: bool = False) -> None:
        self._tick += 1
        node.last_used = self._tick
        if hit:
            node.hits += 1

    # ---------------- lookup ----------------
    def lookup(self, tokens, *, max_tokens=None, shard=None, count=True):
        """Longest cached prefix of `tokens`, capped at max_tokens.
        Returns (n_matched, [page_ids]) where the pages cover tokens
        [0, n_matched) in order; the last page is partially matched when
        n_matched isn't page-aligned (the borrower must COW-fork it
        before writing). `shard` restricts the match to chains whose
        pages live in that pool shard (the only pages a slot of that
        shard may attach); None matches any single shard's chain.
        Touches matched nodes (recency) and bumps their hit counts
        (eviction warmth). `count=False` is the scheduler's reclaim-loop
        retry path: the match is redone (an eviction may have dropped
        pages) but it is the SAME admission, so the lookup counter and
        the nodes' warmth stay where the first round put them — the
        recency touch still happens, since the node really was walked."""
        if count:
            self.lookups += 1
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        limit = len(toks) if max_tokens is None else min(max_tokens,
                                                        len(toks))
        shards = ((shard,) if shard is not None
                  else range(getattr(self.kv, "n_shards", 1)))
        node, matched, pages = self.root, 0, []
        while limit - matched > 0:
            rem = limit - matched
            if rem >= self.page:
                chunk = tuple(toks[matched:matched + self.page])
                child = None
                for sh in shards:
                    child = node.children.get((sh, chunk))
                    if child is not None:
                        break
                if child is not None:
                    pages.append(child.page)
                    matched += self.page
                    self._touch(child, hit=count)
                    node = child
                    # stay on the matched chain's shard from here on: a
                    # sequence can only attach pages of ONE shard
                    shards = (child.shard,)
                    continue
            # no whole-page step: take the best partial match among this
            # node's children (full or tail) and stop
            best, best_lcp = None, 0
            for cand in list(node.children.values()) + node.tails:
                if cand.shard not in shards:
                    continue
                lcp = min(_lcp(cand.key, toks[matched:]), rem,
                          cand.n_tokens)
                if lcp > best_lcp:
                    best, best_lcp = cand, lcp
            if best is not None:
                pages.append(best.page)
                matched += best_lcp
                self._touch(best, hit=count)
            break
        return matched, pages

    # ---------------- insert ----------------
    def insert(self, tokens, page_ids) -> None:
        """Index `tokens` (whose KV the caller's pages hold, in order:
        page_ids[i] covers tokens [i*page, (i+1)*page)). Existing nodes
        are reused (no duplicate refs); new nodes take one reference per
        page so the pages outlive the inserting sequence."""
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        n = len(toks)
        nfull = n // self.page
        assert len(page_ids) >= self.kv.pages_for(n) if n else True
        # one insert comes from one slot, so the whole chain shares the
        # first page's shard
        shard = (self.kv.shard_of_page(int(page_ids[0])) if len(page_ids)
                 else 0)
        node = self.root
        for i in range(nfull):
            chunk = tuple(toks[i * self.page:(i + 1) * self.page])
            child = node.children.get((shard, chunk))
            if child is None:
                child = _Node(chunk, int(page_ids[i]), self.page, node,
                              shard)
                node.children[(shard, chunk)] = child
                self.kv.ref(child.page)
                self._pages += 1
            self._touch(child)
            node = child
        rem = n - nfull * self.page
        if not rem:
            self._enforce_cap()
            return
        key = tuple(toks[nfull * self.page:])
        for t in node.tails:
            if t.key == key and t.shard == shard:
                self._touch(t)
                self._enforce_cap()
                return
        tail = _Node(key, int(page_ids[nfull]), rem, node, shard)
        node.tails.append(tail)
        self.kv.ref(tail.page)
        self._pages += 1
        self._touch(tail)
        if len(node.tails) > MAX_TAILS:
            victim = min(node.tails,
                         key=lambda t: (self.kv.refcount(t.page) > 1,
                                        t.hits, t.last_used))
            if self.kv.refcount(victim.page) == 1:
                node.tails.remove(victim)
                self.kv.unref(victim.page)
                self._pages -= 1
                self.evictions += 1
        self._enforce_cap()

    def _enforce_cap(self) -> None:
        """Evict LRU index-only pages down to max_cached_pages. Pages
        still referenced by running sequences are pinned, so this can
        undershoot; it re-runs on every insert."""
        excess = self._pages - self.max_cached_pages
        if excess > 0:
            self.evict(excess)

    # ---------------- eviction ----------------
    def _evictable(self, node: _Node) -> bool:
        return (node is not self.root and node.is_leaf()
                and self.kv.refcount(node.page) == 1)

    def evict(self, n_pages: int, shard: int | None = None) -> int:
        """Free up to n_pages index-only pages, coldest leaves first
        (fewest lookup hits, least-recently-used within a hit tier),
        restricted to `shard`'s chains when given (the allocator
        reclaims under per-shard pressure — draining another shard's
        cache would free nothing useful). One tree walk seeds a heap of
        evictable leaves; evicting a leaf pushes its parent if that
        just exposed it, so reclaim is O(tree + freed*log) — it sits on
        the allocation pressure path. Returns the number of pages
        actually freed."""
        import heapq

        def evictable(node):
            return (self._evictable(node)
                    and (shard is None or node.shard == shard))

        def key(node):
            # cold-first: a hot system prompt (many hits) outlives
            # one-shot prompts that merely happen to be recent
            return (node.hits, node.last_used)

        heap, stack = [], [self.root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            stack.extend(node.tails)
            if evictable(node):
                heapq.heappush(heap, (*key(node), id(node), node))
        freed = 0
        while freed < n_pages and heap:
            hits, tick, _, victim = heapq.heappop(heap)
            if (hits, tick) != key(victim) or not evictable(victim):
                continue              # stale entry (touched since seeded)
            parent = victim.parent
            if victim in parent.tails:
                parent.tails.remove(victim)
            else:
                del parent.children[(victim.shard, victim.key)]
            self.kv.unref(victim.page)
            self._pages -= 1
            self.evictions += 1
            freed += 1
            if evictable(parent):
                heapq.heappush(heap, (*key(parent), id(parent), parent))
        return freed

    def clear(self) -> int:
        """Drop every cached page (e.g. tests draining the pool)."""
        n = self.cached_pages()
        while self.evict(self.kv.n_pages):
            pass
        return n

    # ---------------- maintenance / stats ----------------
    def remap(self, fn) -> None:
        """Apply a page-id remapping (PagedKVCache.compact)."""
        stack = [self.root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            stack.extend(node.tails)
            if node is not self.root:
                node.page = fn(node.page)

    def cached_pages(self) -> int:
        """Pages the index currently retains (counter, O(1)); the tree
        walk `_count_nodes` cross-checks it in tests."""
        return self._pages

    def _count_nodes(self) -> int:
        n, stack = 0, [self.root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            stack.extend(node.tails)
            if node is not self.root:
                n += 1
        return n
