"""Continuous-batching scheduler: FCFS admission gated on free KV pages,
chunked prefill, preemption-by-eviction, and per-request metrics.

The scheduler owns the queue/lifecycle policy and the page accounting;
the engine owns the model calls. Separation matters: every later scaling
PR (sharded serving, multi-host routing) swaps the engine's model calls
while reusing this policy layer unchanged.

Policies (see docs/SERVING.md):
  - admission: FCFS. A request is admitted when a sequence slot is free
    AND the pool can hold its prompt pages plus `watermark` spare pages
    (the spare keeps one decode tick's growth from immediately starving).
  - prefill: optionally chunked — at most one chunk of one admitted
    request is processed per engine tick, so a long prompt cannot stall
    the decode ticks of already-running sequences.
  - preemption: when decode growth runs out of pages, the *youngest*
    active sequence (LIFO) is evicted — its pages are freed and the
    request re-queued at the queue front with prompt := prompt + tokens
    generated so far (recompute-on-resume, the classic vLLM recovery).
    Greedy decoding makes the recomputation exact.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.serve.kv_cache import OutOfPages, PagedKVCache


@dataclass
class RequestMetrics:
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    n_prompt: int = 0
    n_generated: int = 0
    n_preemptions: int = 0

    @property
    def ttft_s(self) -> float:
        return (self.t_first_token - self.t_submit) if self.t_first_token else 0.0

    @property
    def tpot_s(self) -> float:
        """Time per output token after the first."""
        if self.n_generated <= 1 or not self.t_done:
            return 0.0
        return (self.t_done - self.t_first_token) / (self.n_generated - 1)


@dataclass
class _Entry:
    req: object                       # engine Request
    prompt: np.ndarray                # current (possibly extended) prompt
    metrics: RequestMetrics = field(default_factory=RequestMetrics)
    slot: int = -1
    prefilled: int = 0                # prompt tokens already in pages


class Scheduler:
    """FCFS continuous batching over a PagedKVCache."""

    def __init__(self, kv: PagedKVCache, *, watermark: int = 1,
                 prefill_chunk: int | None = None):
        self.kv = kv
        self.watermark = int(watermark)
        self.prefill_chunk = prefill_chunk
        self.waiting: deque[_Entry] = deque()
        self.running: dict[int, _Entry] = {}   # slot -> entry
        self.preemptions = 0

    # ---------------- queue ----------------
    def submit(self, req) -> None:
        e = _Entry(req=req, prompt=np.asarray(req.prompt, np.int32))
        e.metrics.t_submit = time.time()
        e.metrics.n_prompt = len(e.prompt)
        self.waiting.append(e)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ---------------- admission ----------------
    def admission_need(self, prompt_len: int, *, resumed: bool = False) -> int:
        """Pages required to admit a prompt: its pages + one decode
        token + the watermark. Resumed (preempted) entries skip the
        watermark: their grown prompt is already bounded by the engine's
        capacity truncation, and they must get back in to finish. The
        engine's run()-time validation uses the same arithmetic."""
        wm = 0 if resumed else self.watermark
        return self.kv.pages_for(prompt_len + 1) + wm

    def try_admit(self) -> _Entry | None:
        """Admit the queue head if a slot + its prompt pages fit."""
        if not self.waiting:
            return None
        e = self.waiting[0]
        need = self.admission_need(len(e.prompt),
                                   resumed=e.metrics.n_preemptions > 0)
        if need > self.kv.usable_pages:
            raise ValueError(
                f"request needs {need} pages but the pool only has "
                f"{self.kv.usable_pages}; it can never be admitted")
        if need > self.kv.free_page_count:
            return None
        slot = self.kv.alloc_slot()
        if slot is None:
            return None
        self.waiting.popleft()
        e.slot = slot
        e.prefilled = 0
        e.metrics.t_admit = time.time()
        self.running[slot] = e
        return e

    # ---------------- preemption ----------------
    def _preempt_slot(self, slot: int) -> _Entry:
        """Evict one running sequence: free its pages, requeue it at the
        queue front with prompt := prompt + generated-so-far (recompute
        on resume; exact under greedy decoding)."""
        e = self.running.pop(slot)
        self.kv.release(slot)
        if e.req.out:
            gen = np.asarray(e.req.out, np.int32)
            e.prompt = np.concatenate([np.asarray(e.req.prompt, np.int32),
                                       gen])
        e.slot = -1
        e.prefilled = 0
        e.metrics.n_preemptions += 1
        self.preemptions += 1
        self.waiting.appendleft(e)
        return e

    def preempt_one(self) -> _Entry | None:
        """Evict the youngest running sequence (LIFO victim policy) that
        actually owns pages — evicting a freshly admitted zero-page entry
        (chunked mode reserves the slot before any pages) frees nothing."""
        if not self.running:
            return None
        owners = [s for s in self.running if self.kv.owned_pages(s)]
        slot = max(owners or self.running,
                   key=lambda s: self.running[s].metrics.t_admit)
        return self._preempt_slot(slot)

    def ensure_decode_capacity(self, slot: int, n_tokens: int) -> bool:
        """Grow `slot` to hold n_tokens, evicting other sequences while
        the pool is dry. Returns False if `slot` itself got evicted
        (it was the youngest, or nothing else was left to take from)."""
        while True:
            try:
                self.kv.ensure(slot, n_tokens)
                return True
            except OutOfPages:
                if len(self.running) > 1:
                    self.preempt_one()
                else:
                    self._preempt_slot(slot)
                if slot not in self.running:
                    return False

    # ---------------- completion ----------------
    def finish(self, slot: int) -> None:
        e = self.running.pop(slot)
        self.kv.release(slot)
        e.metrics.t_done = time.time()
        e.metrics.n_generated = len(e.req.out)
        e.req.done = True

    def metrics_summary(self, entries) -> dict:
        ms = [e.metrics for e in entries]
        done = [m for m in ms if m.t_done]
        return {
            "n_done": len(done),
            "preemptions": self.preemptions,
            "ttft_avg_s": float(np.mean([m.ttft_s for m in done])) if done else 0.0,
            "tpot_avg_s": float(np.mean([m.tpot_s for m in done])) if done else 0.0,
            "kv_high_water_pages": self.kv.high_water,
            "kv_usable_pages": self.kv.usable_pages,
        }
