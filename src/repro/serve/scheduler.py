"""Continuous-batching scheduler: FCFS admission gated on free KV pages,
prefix-sharing-aware accounting, chunked prefill, preemption-by-eviction,
and per-request metrics.

The scheduler owns the queue/lifecycle policy and the page accounting;
the engine owns the model calls. Separation matters: every later scaling
PR (sharded serving, multi-host routing) swaps the engine's model calls
while reusing this policy layer unchanged.

Policies (see docs/SERVING.md):
  - admission: FCFS. A request is admitted when a sequence slot is free
    AND the pool can hold its prompt pages plus `watermark` spare pages
    (the spare keeps one decode tick's growth from immediately starving).
    With a prefix index attached, admission charges only the *unshared*
    suffix of the prompt: matched pages are attached by reference, plus
    one fork page when the match ends mid-page (copy-on-write).
  - prefill: optionally chunked — at most one chunk of one admitted
    request is processed per engine tick, so a long prompt cannot stall
    the decode ticks of already-running sequences.
  - preemption: when decode growth runs out of pages, the allocator
    first reclaims unreferenced prefix-index pages; only then is the
    *youngest* active sequence (LIFO) evicted — its references are
    dropped and the request re-queued at the queue front with prompt :=
    prompt + tokens generated so far (recompute-on-resume, the classic
    vLLM recovery). Greedy decoding makes the recomputation exact, and
    index-retained prefix pages make it cheap: the resumed prompt
    usually re-matches its own pages.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.serve.kv_cache import OutOfPages, PagedKVCache


@dataclass
class RequestMetrics:
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    n_prompt: int = 0
    n_generated: int = 0
    n_preemptions: int = 0
    n_prefix_tokens: int = 0          # prompt tokens served from the index

    @property
    def ttft_s(self) -> float:
        return (self.t_first_token - self.t_submit) if self.t_first_token else 0.0

    @property
    def tpot_s(self) -> float:
        """Time per output token after the first."""
        if self.n_generated <= 1 or not self.t_done:
            return 0.0
        return (self.t_done - self.t_first_token) / (self.n_generated - 1)


@dataclass
class _Entry:
    req: object                       # engine Request
    prompt: np.ndarray                # current (possibly extended) prompt
    metrics: RequestMetrics = field(default_factory=RequestMetrics)
    slot: int = -1
    prefilled: int = 0                # prompt tokens already in pages
    shared_tokens: int = 0            # prefix tokens matched at admission
    shared_pages: list = field(default_factory=list)


class Scheduler:
    """FCFS continuous batching over a PagedKVCache."""

    def __init__(self, kv: PagedKVCache, *, watermark: int = 1,
                 prefill_chunk: int | None = None, prefix=None,
                 slab=None):
        self.kv = kv
        self.watermark = int(watermark)
        self.prefill_chunk = prefill_chunk
        self.prefix = prefix              # RadixPrefixCache or None
        # StateSlabPool (serve/state_slab.py) for recurrent-state
        # configs: admission additionally claims one fixed-size state
        # slab, and slab exhaustion is declined/preempted exactly like
        # page exhaustion (same OutOfPages)
        self.slab = slab
        self.waiting: deque[_Entry] = deque()
        self.running: dict[int, _Entry] = {}   # slot -> entry
        self.preemptions = 0

    # ---------------- queue ----------------
    def submit(self, req) -> None:
        e = _Entry(req=req, prompt=np.asarray(req.prompt, np.int32))
        e.metrics.t_submit = time.time()
        e.metrics.n_prompt = len(e.prompt)
        self.waiting.append(e)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ---------------- admission ----------------
    def admission_need(self, prompt_len: int, *, resumed: bool = False,
                       shared_tokens: int = 0) -> int:
        """Free pages required to admit a prompt: its pages + one decode
        token + the watermark, minus pages covering the shared prefix
        (attached by reference, not allocated), plus one fork page when
        the match ends mid-page (the borrower COW-forks that page before
        writing its suffix into it). Resumed (preempted) entries skip
        the watermark: their grown prompt is already bounded by the
        engine's capacity truncation, and they must get back in to
        finish. The engine's run()-time validation uses the same
        arithmetic with shared_tokens=0 (sharing is best-effort)."""
        wm = 0 if resumed else self.watermark
        need = self.kv.pages_for(prompt_len + 1) + wm
        if shared_tokens:
            need -= self.kv.pages_for(shared_tokens)
            if shared_tokens % self.kv.page_size:
                need += 1
        return need

    # thin shard adapters: PagedKVCache and DenseSlotPool both expose
    # the shard protocol (DenseSlotPool is trivially one shard)
    def _pick_shard(self):
        return self.kv.pick_shard()

    def _alloc_slot(self, shard):
        return self.kv.alloc_slot(shard=shard)

    def _free_in_shard(self, shard):
        return self.kv.free_in_shard(shard)

    def _usable_in_shard(self, shard):
        return self.kv.usable_in_shard(shard)

    def try_admit(self) -> _Entry | None:
        """Admit the queue head if a slot + its unshared prompt pages
        fit, reclaiming index-only pages when that is what stands in the
        way. The prefix match is re-run after every reclaim round: an
        eviction may have dropped pages the previous lookup matched.
        Over a sharded pool the target shard is chosen first (the one
        with the most free pages among shards with a free slot), and
        the prefix match, the page accounting and the reclaim all run
        against that shard alone — the admitted sequence's pages must
        come from the shard its slot lives on."""
        if not self.waiting:
            return None
        # no free sequence slot -> nothing to admit; bail before the
        # reclaim loop below so a full batch doesn't drain cached
        # prefixes that couldn't have helped anyway
        if len(self.running) >= self.kv.max_seqs:
            return None
        e = self.waiting[0]
        resumed = e.metrics.n_preemptions > 0
        shard = self._pick_shard()
        if shard is None:
            return None
        free_pages = lambda: self._free_in_shard(shard)
        # one admission == one lookup in the hit-rate stats, however
        # many reclaim rounds re-run the match (count=False retries keep
        # the result fresh without inflating lookups / node hit counters)
        count = True
        while True:
            shared_tokens, shared_pages = 0, []
            if self.prefix is not None and len(e.prompt) > 1:
                shared_tokens, shared_pages = self.prefix.lookup(
                    e.prompt, max_tokens=len(e.prompt) - 1, shard=shard,
                    count=count)
                count = False
            need = self.admission_need(len(e.prompt), resumed=resumed,
                                       shared_tokens=shared_tokens)
            if need > self._usable_in_shard(shard):
                raise ValueError(
                    f"request needs {need} pages but a pool shard only "
                    f"has {self._usable_in_shard(shard)}; it can never "
                    f"be admitted")
            if need <= free_pages():
                break
            shortfall = need - free_pages()
            if (self.prefix is None
                    or self.prefix.evict(shortfall, shard=shard) == 0):
                return None
        slot = self._alloc_slot(shard)
        if slot is None:
            return None
        if self.slab is not None:
            try:
                self.slab.alloc(slot)
            except OutOfPages:
                # state-slab exhaustion == page exhaustion: give the
                # slot back (nothing was allocated) and wait for a
                # running sequence to return its slab
                self.kv.release(slot)
                return None
        self.waiting.popleft()
        e.slot = slot
        e.prefilled = 0
        e.shared_tokens = shared_tokens
        e.shared_pages = list(shared_pages)
        if shared_tokens and self.prefix is not None:
            self.prefix.hits += 1
            self.prefix.tokens_saved += shared_tokens
            e.metrics.n_prefix_tokens += shared_tokens
        e.metrics.t_admit = time.time()
        self.running[slot] = e
        return e

    # ---------------- preemption ----------------
    def _preempt_slot(self, slot: int) -> _Entry:
        """Evict one running sequence: drop its page references, requeue
        it at the queue front with prompt := prompt + generated-so-far
        (recompute on resume; exact under greedy decoding)."""
        e = self.running.pop(slot)
        self.kv.release(slot)
        if self.slab is not None:
            self.slab.release(slot)
        if e.req.out:
            gen = np.asarray(e.req.out, np.int32)
            e.prompt = np.concatenate([np.asarray(e.req.prompt, np.int32),
                                       gen])
        e.slot = -1
        e.prefilled = 0
        e.shared_tokens = 0
        e.shared_pages = []
        e.metrics.n_preemptions += 1
        self.preemptions += 1
        self.waiting.appendleft(e)
        return e

    def preempt_one(self, shard: int | None = None) -> _Entry | None:
        """Evict the youngest running sequence (LIFO victim policy) that
        actually owns pages — evicting a freshly admitted zero-page entry
        (chunked mode reserves the slot before any pages) frees nothing.
        With `shard` given, only sequences of that shard are candidates:
        pages freed in another shard cannot relieve this shard's
        pressure."""
        cands = self.running
        if shard is not None:
            cands = {s: e for s, e in self.running.items()
                     if self.kv.shard_of_slot(s) == shard}
        if not cands:
            return None
        owners = [s for s in cands if self.kv.owned_pages(s)]
        slot = max(owners or cands,
                   key=lambda s: self.running[s].metrics.t_admit)
        return self._preempt_slot(slot)

    def ensure_write_capacity(self, slot: int, start_tok: int,
                              end_tok: int):
        """Grow `slot` to hold end_tok tokens AND fork any shared page
        in the write range [start_tok, end_tok) (copy-on-write), evicting
        other sequences of the same shard while the pool is dry (the
        allocator reclaims index-only pages of that shard first).
        Returns (ok, copies): ok is False if `slot` itself got evicted;
        copies are (src, dst) page pairs the engine must apply to the
        device pool before the write."""
        shard = self.kv.shard_of_slot(slot)
        while True:
            try:
                self.kv.ensure(slot, end_tok)
                return True, self.kv.cow_for_write(slot, start_tok,
                                                   end_tok)
            except OutOfPages:
                others = [s for s in self.running
                          if s != slot
                          and self.kv.shard_of_slot(s) == shard]
                if others:
                    self.preempt_one(shard=shard)
                else:
                    self._preempt_slot(slot)
                if slot not in self.running:
                    return False, []

    # ---------------- completion ----------------
    def finish(self, slot: int, cached_tokens=None) -> None:
        """Complete a request. `cached_tokens` (engine-provided when a
        prefix index is attached) is the token sequence whose KV the
        slot's pages actually hold — prompt + generated-minus-last; it
        is inserted into the radix index *before* the slot's references
        are dropped, so the pages outlive the request and seed future
        prefix hits."""
        e = self.running.pop(slot)
        if (self.prefix is not None and cached_tokens is not None
                and len(cached_tokens)):
            n = self.kv.pages_for(len(cached_tokens))
            self.prefix.insert(cached_tokens,
                               self.kv.owned_pages(slot)[:n])
        self.kv.release(slot)
        if self.slab is not None:
            self.slab.release(slot)
        e.metrics.t_done = time.time()
        e.metrics.n_generated = len(e.req.out)
        e.req.done = True

    def metrics_summary(self, entries) -> dict:
        """Aggregate per-request metrics. Alongside the averages, the
        raw per-request TTFT/TPOT sample lists are exported so the
        bench subsystem (repro.bench.metrics) can report percentiles —
        tail latency is the serving number that matters, and an average
        hides it."""
        ms = [e.metrics for e in entries]
        done = [m for m in ms if m.t_done]
        ttft = [m.ttft_s for m in done]
        tpot = [m.tpot_s for m in done if m.n_generated > 1]
        out = {
            "n_done": len(done),
            "preemptions": self.preemptions,
            "ttft_avg_s": float(np.mean(ttft)) if ttft else 0.0,
            # average over the same filtered sample list as the
            # percentile export: single-token requests have no
            # after-first-token interval, and counting their 0.0s
            # deflated the average the percentiles didn't see
            "tpot_avg_s": float(np.mean(tpot)) if tpot else 0.0,
            "ttft_samples_s": ttft,
            "tpot_samples_s": tpot,
            "kv_high_water_pages": self.kv.high_water,
            "kv_usable_pages": self.kv.usable_pages,
            "pages_allocated": getattr(self.kv, "pages_allocated", 0),
            "cow_forks": getattr(self.kv, "cow_forks", 0),
            "prefix_hits": 0,
            "prefix_lookups": 0,
            "prefix_hit_rate": 0.0,
            "prefix_tokens_saved": 0,
            "prefix_cached_pages": 0,
            "prefix_evictions": 0,
            "slab_usable_slabs": 0,
            "slab_high_water": 0,
            "slabs_allocated": 0,
        }
        if self.slab is not None:
            out["slab_usable_slabs"] = self.slab.usable_slabs
            out["slab_high_water"] = self.slab.high_water
            out["slabs_allocated"] = self.slab.slabs_allocated
        if self.prefix is not None:
            out["prefix_hits"] = self.prefix.hits
            out["prefix_lookups"] = self.prefix.lookups
            out["prefix_hit_rate"] = self.prefix.hit_rate
            out["prefix_tokens_saved"] = self.prefix.tokens_saved
            out["prefix_cached_pages"] = self.prefix.cached_pages()
            out["prefix_evictions"] = self.prefix.evictions
        return out
