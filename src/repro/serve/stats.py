"""Structured serving statistics: the EngineStats snapshot.

The engine accumulates raw counters in a plain dict while it runs (hot
path: no attribute machinery per token). `EngineStats.capture(engine)`
freezes that dict plus the allocator, prefix-index and compile-cache
counters into one typed, immutable record — the thing benchmarks and
monitoring consume. Every field is a real field: a typo'd stats key in
a benchmark is an AttributeError here, not a silent 0 from `.get()`,
and `as_dict()` gives the JSON-ready form the bench schema records.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class EngineStats:
    """One point of the serving perf trajectory (see docs/BENCHMARKS.md
    for which bench counters are derived from which fields)."""

    # phase timings / token accounting (engine accumulators)
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens: int = 0
    ticks: int = 0
    prefill_tokens: int = 0

    # request lifecycle (scheduler summary)
    n_done: int = 0
    preemptions: int = 0
    ttft_avg_s: float = 0.0
    tpot_avg_s: float = 0.0
    ttft_samples_s: Tuple[float, ...] = ()
    tpot_samples_s: Tuple[float, ...] = ()

    # KV page pool
    kv_high_water_pages: int = 0
    kv_usable_pages: int = 0
    pages_allocated: int = 0
    cow_forks: int = 0

    # binary-coded KV (0 bits == raw fp pages)
    kv_bits: int = 0
    kv_bytes_per_page: int = 0
    kv_pool_bytes: int = 0

    # self-speculative decoding (0 == speculation off)
    speculate_k: int = 0
    draft_bits: int = 0
    draft_tokens: int = 0
    accepted_tokens: int = 0
    acceptance_rate: float = 0.0

    # recurrent state slab pool (0 == no recurrent layers / dense)
    slab_usable_slabs: int = 0
    slab_high_water: int = 0
    slabs_allocated: int = 0
    slab_bytes_per_slab: int = 0

    # radix prefix index
    prefix_hits: int = 0
    prefix_lookups: int = 0
    prefix_hit_rate: float = 0.0
    prefix_tokens_saved: int = 0
    prefix_cached_pages: int = 0
    prefix_evictions: int = 0

    # process-wide jit compile cache
    compile_cache_entries: int = 0
    compile_cache_hits: int = 0
    compile_cache_misses: int = 0

    @property
    def decode_tok_s(self) -> float:
        """Decode throughput over the engine's lifetime so far."""
        return self.tokens / max(self.decode_s, 1e-9)

    @property
    def us_per_token(self) -> float:
        return 1e6 * self.decode_s / max(self.tokens, 1)

    def as_dict(self) -> dict:
        """JSON-ready form (sample tuples become lists), including the
        derived throughput fields."""
        d = asdict(self)
        d["ttft_samples_s"] = list(self.ttft_samples_s)
        d["tpot_samples_s"] = list(self.tpot_samples_s)
        d["decode_tok_s"] = self.decode_tok_s
        d["us_per_token"] = self.us_per_token
        return d

    @classmethod
    def capture(cls, engine) -> "EngineStats":
        """Snapshot a ServeEngine *now*: its accumulator dict, a fresh
        scheduler summary (so mid-run captures see current requests,
        not the last run()'s), and the pool/index/compile-cache
        counters."""
        from repro.serve import compile_cache

        s = dict(engine.stats)
        s.update(engine.sched.metrics_summary(engine._entries))
        cc = compile_cache.stats()
        fields = {
            "prefill_s": float(s.get("prefill_s", 0.0)),
            "decode_s": float(s.get("decode_s", 0.0)),
            "tokens": int(s.get("tokens", 0)),
            "ticks": int(s.get("ticks", 0)),
            "prefill_tokens": int(s.get("prefill_tokens", 0)),
            "n_done": int(s.get("n_done", 0)),
            "preemptions": int(s.get("preemptions", 0)),
            "ttft_avg_s": float(s.get("ttft_avg_s", 0.0)),
            "tpot_avg_s": float(s.get("tpot_avg_s", 0.0)),
            "ttft_samples_s": tuple(s.get("ttft_samples_s", ())),
            "tpot_samples_s": tuple(s.get("tpot_samples_s", ())),
            "kv_high_water_pages": int(s.get("kv_high_water_pages", 0)),
            "kv_usable_pages": int(s.get("kv_usable_pages", 0)),
            "pages_allocated": int(s.get("pages_allocated", 0)),
            "cow_forks": int(s.get("cow_forks", 0)),
            "kv_bits": int(getattr(engine, "kv_bits", 0)),
            "kv_bytes_per_page": (
                int(engine.kv.bytes_per_page())
                if hasattr(engine.kv, "bytes_per_page") else 0),
            "kv_pool_bytes": (
                int(engine.kv.pool_bytes())
                if hasattr(engine.kv, "pool_bytes") else 0),
            "speculate_k": int(getattr(engine, "speculate", 0)),
            "draft_bits": (int(getattr(engine, "draft_bits", 0))
                           if getattr(engine, "speculate", 0) else 0),
            "draft_tokens": int(s.get("draft_tokens", 0)),
            "accepted_tokens": int(s.get("accepted_tokens", 0)),
            "acceptance_rate": (
                int(s.get("accepted_tokens", 0))
                / max(int(s.get("draft_tokens", 0)), 1)),
            "slab_usable_slabs": int(s.get("slab_usable_slabs", 0)),
            "slab_high_water": int(s.get("slab_high_water", 0)),
            "slabs_allocated": int(s.get("slabs_allocated", 0)),
            "slab_bytes_per_slab": (
                int(engine.slab.bytes_per_slab())
                if getattr(engine, "slab", None) is not None else 0),
            "prefix_hits": int(s.get("prefix_hits", 0)),
            "prefix_lookups": int(s.get("prefix_lookups", 0)),
            "prefix_hit_rate": float(s.get("prefix_hit_rate", 0.0)),
            "prefix_tokens_saved": int(s.get("prefix_tokens_saved", 0)),
            "prefix_cached_pages": int(s.get("prefix_cached_pages", 0)),
            "prefix_evictions": int(s.get("prefix_evictions", 0)),
            "compile_cache_entries": int(cc["entries"]),
            "compile_cache_hits": int(cc["hits"]),
            "compile_cache_misses": int(cc["misses"]),
        }
        return cls(**fields)
