"""Batched serving engine: fixed-slot continuous batching over the
prefill/decode steps. Works with plain bf16/fp32 weights or GPTQT-packed
QuantizedTensor params (the paper's deployment mode) — the model code
dispatches per leaf, so the engine is representation-agnostic.

Slot model: `batch_size` concurrent sequences. A request is prefilled
into a free slot (per-request prefill, padded to the slot's max_len) and
then advanced one token per engine tick together with every other active
slot — the standard decode-batched regime the paper's Tab. IV measures
(batch 1, 128 new tokens => single-slot latency test).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import decode_step, init_cache, prefill


@dataclass
class Request:
    prompt: np.ndarray
    max_new_tokens: int = 32
    eos: int | None = None
    out: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, batch_size=4, max_len=512,
                 dtype=None, greedy=True):
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.greedy = greedy
        dtype = dtype or cfg.dtype
        self.cache = init_cache(cfg, batch_size, max_len, dtype)
        self.pos = np.zeros((batch_size,), np.int32)
        self.cur = np.zeros((batch_size,), np.int32)
        self.active: list[Request | None] = [None] * batch_size
        self._decode = jax.jit(lambda p, c, t, s: decode_step(cfg, p, c, t, s),
                               donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, t: prefill(cfg, p, t, max_len),
            static_argnums=())
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0, "tokens": 0,
                      "ticks": 0}

    # ---------------- slot management ----------------
    def _free_slot(self):
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _admit(self, req: Request, slot: int):
        t0 = time.time()
        prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
        last_logits, cache1 = self._prefill(self.params, prompt)
        # merge the single-row cache into the batch cache at `slot`
        def merge(batch_leaf, one_leaf):
            # leaves: (G, B, ...) vs (G, 1, ...)
            return batch_leaf.at[:, slot].set(one_leaf[:, 0])
        self.cache = jax.tree.map(merge, self.cache, cache1)
        tok = int(jnp.argmax(last_logits[0]))
        req.out.append(tok)
        self.active[slot] = req
        self.pos[slot] = len(req.prompt)
        self.cur[slot] = tok
        self.stats["prefill_s"] += time.time() - t0

    # ---------------- engine ----------------
    def run(self, requests: list[Request]):
        pending = list(requests)
        while pending or any(r is not None for r in self.active):
            # admit
            while pending:
                slot = self._free_slot()
                if slot is None:
                    break
                self._admit(pending.pop(0), slot)
            # decode tick
            t0 = time.time()
            toks = jnp.asarray(self.cur[:, None], jnp.int32)
            pos = jnp.asarray(self.pos, jnp.int32)
            logits, self.cache = self._decode(self.params, self.cache,
                                              toks, pos)
            logits.block_until_ready()
            self.stats["decode_s"] += time.time() - t0
            self.stats["ticks"] += 1
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for i, req in enumerate(self.active):
                if req is None:
                    continue
                self.stats["tokens"] += 1
                tok = int(nxt[i])
                req.out.append(tok)
                self.pos[i] += 1
                self.cur[i] = tok
                hit_eos = req.eos is not None and tok == req.eos
                if (len(req.out) >= req.max_new_tokens or hit_eos
                        or self.pos[i] >= self.max_len - 1):
                    req.done = True
                    self.active[i] = None
        return requests
