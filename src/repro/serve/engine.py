"""Batched serving engine: continuous batching over prefill/decode with
two cache backends behind one switch.

  cache_kind="dense"  — the classic fixed-slot regime: `batch_size`
    sequences, each owning a dense max_len KV slab (the paper's Tab. IV
    measurement setup). Memory = B * max_len regardless of live tokens.
  cache_kind="paged"  — block-table paged KV (serve/kv_cache.py): all
    sequences share a global page pool; admission is gated on free pages
    (not slots), so short/finished sequences return their memory and the
    engine sustains more concurrency under the same byte budget. With
    prefix sharing (default on for attention-only configs) a radix index
    (serve/prefix_cache.py) maps completed prefill pages to token
    prefixes: a request with an N-token cached prefix attaches those
    pages by reference, skips N tokens of prefill, and allocates only
    its suffix pages — shared pages fork copy-on-write before any write.

Both run on the same FCFS Scheduler (serve/scheduler.py) for queueing,
admission, preemption and TTFT/TPOT metrics. Works with plain bf16/fp32
weights or GPTQT-packed QuantizedTensor params — the model dispatches
per leaf, so the engine is representation-agnostic.

Prompt lengths are padded to power-of-two buckets before the jitted
prefill (attention-only, no-window configs), so admission compiles once
per bucket instead of once per distinct prompt length.

Sharded serving: pass `mesh=` (a jax.sharding.Mesh with a "data" axis,
see launch/mesh.py:make_serve_mesh) and the engine becomes mesh-native
— the paged page pool is partitioned over the data axis (per-shard
allocator, serve/kv_cache.py), the device pool and block-table mirror
are placed with dist.sharding's cache rules, and the decode/extend
steps run under the mesh context so batch activations stay anchored to
the data axis. Model-axis tensor parallelism composes through the
params' own shardings (ckpt/packed.py:load_packed(mesh=...) places a
packed artifact straight onto the mesh). All jitted step wrappers are
borrowed from the process-wide serve/compile_cache.py, so N engines —
or N restarts of the serving loop — share one warmup per (config,
mesh).
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import init_cache
from repro.serve import compile_cache
from repro.serve.kv_cache import PagedKVCache
from repro.serve.scheduler import Scheduler

MIN_BUCKET = 8


def bucket_len(n: int, cap: int) -> int:
    """Smallest power-of-two >= n (floor MIN_BUCKET), clamped to cap."""
    b = MIN_BUCKET
    while b < n:
        b *= 2
    return min(b, cap)


def pad_pow2(seq: list, fill) -> list:
    """Pad to the next power-of-two length with `fill` so jits keyed on
    the list length compile once per bucket, not once per count."""
    n = 1
    while n < len(seq):
        n *= 2
    return seq + [fill] * (n - len(seq))


@dataclass
class Request:
    prompt: np.ndarray
    max_new_tokens: int = 32
    eos: int | None = None
    out: list = field(default_factory=list)
    done: bool = False


class DenseSlotPool:
    """Slot accounting shim so the Scheduler drives the dense engine
    too: one fixed max_len 'page' per sequence, and a trivial single
    shard for the scheduler's shard protocol."""

    n_shards = 1

    def __init__(self, n_slots: int, max_len: int):
        self.max_seqs = n_slots
        self.max_len = max_len
        self._active = np.zeros((n_slots,), bool)
        self.high_water = 0
        self.usable_pages = n_slots

    def pages_for(self, n_tokens: int) -> int:
        return 1

    @property
    def free_page_count(self) -> int:
        return int((~self._active).sum())

    @property
    def used_pages(self) -> int:
        return int(self._active.sum())

    # shard protocol (one trivial shard)
    def shard_of_slot(self, slot: int) -> int:
        return 0

    def pick_shard(self):
        return 0 if self.free_page_count else None

    def free_in_shard(self, shard: int) -> int:
        return self.free_page_count

    def usable_in_shard(self, shard: int) -> int:
        return self.usable_pages

    def alloc_slot(self, shard=None):
        for i in range(self.max_seqs):
            if not self._active[i]:
                self._active[i] = True
                self.high_water = max(self.high_water, self.used_pages)
                return i
        return None

    def ensure(self, slot: int, n_tokens: int) -> None:
        assert n_tokens <= self.max_len, (n_tokens, self.max_len)

    def cow_for_write(self, slot: int, start_tok: int, end_tok: int):
        return []

    def owned_pages(self, slot: int):
        return [slot] if self._active[slot] else []

    def release(self, slot: int) -> None:
        self._active[slot] = False


class ServeEngine:
    def __init__(self, cfg, params, *, batch_size=4, max_len=512,
                 dtype=None, greedy=True, cache_kind="dense",
                 page_size=64, n_pages=None, prefill_chunk=None,
                 bucket_prompts=True, watermark=1, prefix_sharing=True,
                 prefix_max_pages=None, mesh=None, kv_bits=0,
                 kv_group_size=0, speculate=0, draft_bits=2,
                 draft_params=None, accept_rule="greedy",
                 typical_tau=0.3, state_slabs=None):
        assert cache_kind in ("dense", "paged"), cache_kind
        if kv_bits and cache_kind != "paged":
            raise ValueError(
                "kv_bits requires cache_kind='paged': the binary-coded "
                "KV layout lives in the page pool (quantize-on-write "
                "needs page-granular scatter)")
        if speculate and cache_kind != "paged":
            raise ValueError(
                "speculate requires cache_kind='paged': draft KV is "
                "written speculatively into the page pool and rejected "
                "tokens roll back by page truncation")
        if accept_rule not in ("greedy", "typical"):
            raise ValueError(
                f"accept_rule={accept_rule!r}; expected 'greedy' or "
                f"'typical'")
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.greedy = greedy
        self.cache_kind = cache_kind
        self.kv_bits = int(kv_bits)
        self.mesh = mesh
        # pool shards = the mesh's data-axis size: page blocks land on
        # the same devices as the batch rows whose sequences use them
        data_shards = 1
        if mesh is not None:
            from repro.dist.sharding import mesh_axis_sizes
            data_shards = int(mesh_axis_sizes(mesh).get("data", 1))
        n_shards = 1
        if cache_kind == "paged" and data_shards > 1:
            if batch_size % data_shards:
                raise ValueError(
                    f"batch_size={batch_size} must divide over the "
                    f"{data_shards}-way data axis so every sequence "
                    f"slot maps to exactly one page-pool shard")
            n_shards = data_shards
        dtype = dtype or cfg.dtype

        # MLA counts as attention here: its latent pages ride the same
        # block-table/COW/prefix machinery, and its extend path exists
        # (models/mla.py:mla_extend_paged)
        attn_only = all(s.kind == "attn" for s in cfg.pattern)
        no_window = all(s.window is None for s in cfg.pattern)
        if speculate and (not attn_only or cfg.mla is not None):
            raise NotImplementedError(
                "speculate>0 verifies k+1 positions through the paged "
                "extend path, which needs a standard attention-only "
                "pattern (MLA drafts are not wired up)")
        # bucketed prefill needs padding tokens to be harmless: causal
        # attention masks them and decode overwrites their cache slots,
        # but rolling window buffers and recurrent mamba state both mix
        # pad tokens in — keep those configs on exact-length prefill.
        self._bucket = bool(bucket_prompts and attn_only and no_window)

        # window layers: prefill()'s rolling buffer cannot be scattered
        # into absolute page slots, so the paged engine prefills them
        # through the extend path (which is attention-only)
        self._extend_prefill = cache_kind == "paged" and \
            (bool(prefill_chunk) or not no_window)
        self._prefix = None
        self.slab = None
        if cache_kind == "paged":
            if self._extend_prefill and not attn_only:
                raise NotImplementedError(
                    "paged prefill via extend (chunked or sliding-window) "
                    "needs an attention-only pattern")
            pages_per_seq = -(-max_len // page_size)
            if n_pages is None:
                # parity with the dense engine's byte budget, + one
                # reserve (null) page per shard
                n_pages = batch_size * pages_per_seq + n_shards
            # the page axis must split evenly over the shards (it is
            # the GSPMD-partitioned dim of the pool)
            n_pages = -(-n_pages // n_shards) * n_shards
            self.kv = PagedKVCache(cfg, n_pages=n_pages,
                                   page_size=page_size,
                                   max_seqs=batch_size,
                                   max_pages_per_seq=pages_per_seq,
                                   dtype=dtype, n_shards=n_shards,
                                   kv_bits=kv_bits,
                                   kv_group_size=kv_group_size)
            self.page_size = page_size
            # recurrent layers: pooled fixed-size state slabs under the
            # page-pool's allocator invariants — admission claims one
            # slab per sequence, exhaustion is declined like OutOfPages
            if not attn_only:
                from repro.serve.state_slab import StateSlabPool
                n_slabs = (batch_size + n_shards if state_slabs is None
                           else int(state_slabs))
                n_slabs = -(-n_slabs // n_shards) * n_shards
                self.slab = StateSlabPool(cfg, n_slabs=n_slabs,
                                          max_seqs=batch_size,
                                          n_shards=n_shards, dtype=dtype)
            # prefix sharing skips matched prefill via the extend path,
            # so it has the same attention-only requirement
            if prefix_sharing and attn_only:
                from repro.serve.prefix_cache import RadixPrefixCache
                self._prefix = RadixPrefixCache(
                    self.kv, max_cached_pages=prefix_max_pages)
            self.cache = self.kv.take_pool()
            # device-resident block-table mirror: rows are pushed only
            # when the allocator bumps their version (admission, growth,
            # COW, release) instead of re-uploading the whole table per
            # decode tick; the per-tick traffic is just the (B,) live
            # mask that routes inactive rows to their shard's null page
            self._bt_dev = jnp.zeros((batch_size, pages_per_seq), jnp.int32)
            self._bt_applied = np.full((batch_size,), -1, np.int64)
            # per-slot null-page row: all zeros unsharded; shard s's
            # reserve page for slots living on shard s
            self._null_row = jnp.asarray(
                [self.kv.null_page_of_shard(self.kv.shard_of_slot(s))
                 for s in range(batch_size)], jnp.int32)
            self._bt_update = compile_cache.get("bt_update", None, mesh)
            self._decode = compile_cache.get("decode_paged", cfg, mesh)
            self._scatter = compile_cache.get("scatter_prefill", cfg,
                                              mesh)
            self._extend = compile_cache.get("extend_paged", cfg, mesh)
            self._copy = compile_cache.get("copy_pages", None, mesh)
            if speculate:
                self._draft_propose = compile_cache.get("draft_propose",
                                                        cfg, mesh)
                self._verify = compile_cache.get("verify_paged", cfg,
                                                 mesh)
        else:
            if prefill_chunk:
                raise NotImplementedError(
                    "chunked prefill requires cache_kind='paged'")
            self.kv = DenseSlotPool(batch_size, max_len)
            self.cache = init_cache(cfg, batch_size, max_len, dtype)
            self._decode = compile_cache.get("decode_dense", cfg, mesh)
        if mesh is not None:
            # place the cache (page pools / dense slabs, block-table
            # mirror) onto the mesh with the shared GSPMD cache rules:
            # pages and batch rows ride the data axis, KV heads the
            # model axis when divisible
            from repro.dist.sharding import batch_pspec, cache_shardings
            from jax.sharding import NamedSharding
            self.cache = jax.device_put(
                self.cache, cache_shardings(cfg, self.cache, mesh))
            if cache_kind == "paged":
                row = NamedSharding(mesh, batch_pspec(mesh, batch_size))
                self._bt_dev = jax.device_put(self._bt_dev, row)
                self._null_row = jax.device_put(
                    self._null_row,
                    NamedSharding(mesh, batch_pspec(mesh, batch_size,
                                                    ())))

        self.prefill_chunk = prefill_chunk
        self.sched = Scheduler(
            self.kv, watermark=watermark if cache_kind == "paged" else 0,
            prefill_chunk=prefill_chunk, prefix=self._prefix,
            slab=self.slab)
        self.pos = np.zeros((batch_size,), np.int32)
        self.cur = np.zeros((batch_size,), np.int32)
        self._prefill = compile_cache.get("prefill", cfg, mesh)
        # self-speculative decoding: the draft shares the target's
        # packed sign words and differs only in its (re-fit) scales —
        # zero extra HBM beyond the draft alphas/betas (quant/draft.py)
        self.speculate = int(speculate)
        self.draft_bits = int(draft_bits)
        self.accept_rule = accept_rule
        self.typical_tau = float(typical_tau)
        self.draft_params = None
        if self.speculate:
            if draft_params is None:
                from repro.quant.draft import make_draft_params
                from repro.quant.qlinear import QuantizedTensor
                has_qt = any(
                    isinstance(leaf, QuantizedTensor)
                    for leaf in jax.tree.leaves(
                        params,
                        is_leaf=lambda x: isinstance(x, QuantizedTensor)))
                if not has_qt:
                    raise ValueError(
                        "speculate>0 needs GPTQT-quantized params (the "
                        "draft is a code-plane prefix of the target) or "
                        "an explicit draft_params tree")
                draft_params = make_draft_params(params, self.draft_bits)
            self.draft_params = draft_params
        # raw accumulators (hot path); `stats_snapshot()` freezes them
        # plus the pool/index/compile-cache counters into an EngineStats
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0, "tokens": 0,
                      "ticks": 0, "prefill_tokens": 0,
                      "draft_tokens": 0, "accepted_tokens": 0}
        self._entries = []

    def stats_snapshot(self):
        """Structured snapshot of every serving counter — engine
        accumulators, scheduler request metrics (incl. per-request
        TTFT/TPOT samples), page-pool/prefix-index counters and the
        process-wide compile-cache stats — as an immutable EngineStats.
        This is the export the bench scenarios record; the `stats` dict
        stays the mutable in-flight accumulator."""
        from repro.serve.stats import EngineStats
        return EngineStats.capture(self)

    def _mesh_ctx(self):
        """The engine's mesh context (no-op single-device): every jitted
        step is traced inside it so constrain_batch anchors activations
        to the data axis."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.dist.context import mesh_context
        return mesh_context(self.mesh)

    # ---------------- COW fork application ----------------
    def _apply_copies(self, copies) -> None:
        """Apply allocator COW forks to the device pool. The copy list
        is padded with (0, 0) null-page no-ops to a power-of-two length
        so the jit compiles once per bucket, not once per fork count."""
        if not copies:
            return
        padded = pad_pow2(copies, (0, 0))
        src = [s for s, _ in padded]
        dst = [d for _, d in padded]
        self.cache = self._copy(self.cache,
                                jnp.asarray(src, jnp.int32),
                                jnp.asarray(dst, jnp.int32),
                                self.kv.n_pages)

    # ---------------- device block-table mirror ----------------
    def _sync_block_tables(self) -> None:
        """Push block-table rows whose allocator version moved since the
        last sync. The row-index list is padded to a power-of-two length
        (repeating the last row — an idempotent rewrite) so the scatter
        jit compiles once per bucket, not once per dirty count."""
        dirty = [s for s in range(self.B)
                 if self._bt_applied[s] != self.kv.bt_version[s]]
        if not dirty:
            return
        idx = pad_pow2(dirty, dirty[-1])
        rows = self.kv.block_tables[idx]
        self._bt_dev = self._bt_update(self._bt_dev,
                                       jnp.asarray(idx, jnp.int32),
                                       jnp.asarray(rows, jnp.int32))
        for s in dirty:
            self._bt_applied[s] = self.kv.bt_version[s]

    # ---------------- admission ----------------
    def _padded_prompt(self, prompt):
        L = len(prompt)
        S = bucket_len(L, self.max_len) if self._bucket else L
        padded = np.zeros((S,), np.int32)
        padded[:L] = prompt
        return padded, L

    def _admit(self, e):
        t0 = time.time()
        if e.shared_tokens:
            # attach the matched prefix pages by reference BEFORE any
            # allocation: the attach pins them (refcount >= 2) against
            # the allocator's index reclaim
            self.kv.share(e.slot, e.shared_pages)
            e.prefilled = e.shared_tokens
        if self.prefill_chunk:
            # chunked mode: admission only reserves the slot (plus any
            # shared prefix); prompt tokens flow through _prefill_tick
            # one chunk per engine tick
            self.pos[e.slot] = 0
            self.stats["prefill_s"] += time.time() - t0
            return
        L = len(e.prompt)
        if e.shared_tokens:
            # prefix hit: prefill only the unshared suffix through the
            # extend path; the COW fork (if the match ends mid-page)
            # happens before the suffix K/V lands in pages
            N = e.shared_tokens
            suffix = e.prompt[N:]
            nv = len(suffix)
            C = bucket_len(nv, self.max_len) if self._bucket else nv
            padded = np.zeros((C,), np.int32)
            padded[:nv] = suffix
            self.kv.ensure(e.slot, L)
            self._apply_copies(self.kv.cow_for_write(e.slot, N, L))
            bt = self._bt_slice(e.slot, L)
            logits, self.cache = self._extend(
                self.params, self.cache,
                jnp.asarray(padded[None], jnp.int32),
                jnp.asarray([N], jnp.int32), bt,
                jnp.asarray([nv], jnp.int32))
            self.stats["prefill_tokens"] += nv
            self._emit_first_token(e, logits, L)
            self.stats["prefill_s"] += time.time() - t0
            return
        padded, L = self._padded_prompt(e.prompt)
        tokens = jnp.asarray(padded[None, :], jnp.int32)
        last = jnp.asarray([L - 1], jnp.int32)
        self.stats["prefill_tokens"] += L
        if self._extend_prefill:
            # sliding-window layers: write the prompt at absolute page
            # slots via one whole-prompt extend step
            self.kv.ensure(e.slot, L)
            bt = self._bt_slice(e.slot, L)
            logits, self.cache = self._extend(
                self.params, self.cache, tokens,
                jnp.asarray([0], jnp.int32), bt,
                jnp.asarray([L], jnp.int32))
            self._emit_first_token(e, logits, L)
            self.stats["prefill_s"] += time.time() - t0
            return
        if self.cache_kind == "paged":
            self.kv.ensure(e.slot, L)
            last_logits, row_cache = self._prefill(self.params, tokens,
                                                   last, len(padded))
            npg = -(-len(padded) // self.page_size)
            ids = self.kv.owned_pages(e.slot)
            ids = (ids + [0] * npg)[:npg]       # null-page pad: masked out
            self.cache = self._scatter(self.cache, row_cache,
                                       jnp.int32(e.slot),
                                       jnp.asarray(ids, jnp.int32),
                                       jnp.int32(L))
        else:
            last_logits, cache1 = self._prefill(self.params, tokens, last,
                                                self.max_len)
            slot = e.slot

            def merge(batch_leaf, one_leaf):
                # leaves: (G, B, ...) vs (G, 1, ...)
                return batch_leaf.at[:, slot].set(one_leaf[:, 0])
            self.cache = jax.tree.map(merge, self.cache, cache1)
        self._emit_first_token(e, last_logits, L)
        self.stats["prefill_s"] += time.time() - t0

    def _emit_first_token(self, e, last_logits, prompt_len):
        tok = int(jnp.argmax(last_logits[0]))
        e.req.out.append(tok)
        if not e.metrics.t_first_token:
            e.metrics.t_first_token = time.time()
        self.pos[e.slot] = prompt_len
        self.cur[e.slot] = tok
        e.prefilled = prompt_len
        if self._prefix is not None:
            # index the prompt's full pages right away so concurrent
            # same-prefix requests share them; these pages are never
            # written again (decode lands at positions >= prompt_len).
            # The partial tail page is indexed at finish() instead —
            # indexing it now would force a COW fork on the very next
            # decode token.
            nfull = prompt_len // self.page_size
            if nfull:
                self._prefix.insert(
                    np.asarray(e.prompt[:nfull * self.page_size]),
                    self.kv.owned_pages(e.slot)[:nfull])
        # the prefill-produced token can already satisfy the request
        if (len(e.req.out) >= e.req.max_new_tokens
                or (e.req.eos is not None and tok == e.req.eos)):
            self._finish(e)

    def _finish(self, e):
        """Complete a request, handing the tokens whose KV its pages
        hold (prompt + generated-minus-last) to the scheduler so the
        radix index can retain them for future prefix hits."""
        slot = e.slot
        if self._prefix is None:
            self.sched.finish(slot)
            return
        n_cached = int(self.pos[slot])
        folded = len(e.prompt) - e.metrics.n_prompt   # resumed prompts
        toks = np.concatenate([
            e.prompt, np.asarray(e.req.out[folded:], np.int32)])[:n_cached]
        self.sched.finish(slot, cached_tokens=toks)

    def _bt_slice(self, slot, n_tokens):
        """Block-table row cut to the pages covering n_tokens, so the
        extend gather is O(live tokens) — not O(max_len) — per chunk.
        The jit retraces per distinct page count (bounded by
        max_pages_per_seq)."""
        npg = self.kv.pages_for(n_tokens)
        return jnp.asarray(self.kv.block_tables[slot:slot + 1, :npg])

    # ---------------- chunked prefill ----------------
    def _prefill_tick(self):
        """Advance the oldest admitted-but-unprefilled sequence by one
        chunk; long prompts therefore never stall decode ticks. With a
        prefix hit, chunking starts at the matched offset (prefilled
        was set to shared_tokens at admission)."""
        pending = [e for e in self.sched.running.values()
                   if e.prefilled < len(e.prompt)]
        if not pending:
            return
        e = min(pending, key=lambda x: x.metrics.t_admit)
        t0 = time.time()
        C = self.prefill_chunk
        s = e.prefilled
        chunk = e.prompt[s:s + C]
        nv = len(chunk)
        padded = np.zeros((C,), np.int32)
        padded[:nv] = chunk
        ok, copies = self.sched.ensure_write_capacity(e.slot, s, s + nv)
        if not ok:
            return    # evicted while growing; it will be re-admitted
        self._apply_copies(copies)
        bt = self._bt_slice(e.slot, s + nv)
        logits, self.cache = self._extend(
            self.params, self.cache, jnp.asarray(padded[None], jnp.int32),
            jnp.asarray([s], jnp.int32), bt,
            jnp.asarray([nv], jnp.int32))
        e.prefilled = s + nv
        self.stats["prefill_tokens"] += nv
        if e.prefilled >= len(e.prompt):
            self._emit_first_token(e, logits, len(e.prompt))
        self.stats["prefill_s"] += time.time() - t0

    # ---------------- decode ----------------
    def _decode_ready(self):
        return [s for s, e in self.sched.running.items()
                if e.prefilled >= len(e.prompt)]

    def _decode_tick(self):
        if self.speculate:
            return self._spec_decode_tick()
        ready = self._decode_ready()
        if not ready:
            return
        if self.cache_kind == "paged":
            grown = []
            for slot in ready:
                if slot not in self.sched.running:
                    continue    # evicted while growing an earlier slot
                # the new token lands at pos -> need pos+1 capacity, and
                # a COW fork if that page is shared (its forks must hit
                # the device pool before this slot is marked ready)
                p = int(self.pos[slot])
                ok, copies = self.sched.ensure_write_capacity(slot, p,
                                                              p + 1)
                if ok:
                    self._apply_copies(copies)
                    grown.append(slot)
            # a later growth may have evicted an earlier grown slot
            ready = [s for s in grown if s in self.sched.running]
            if not ready:
                return
        t0 = time.time()
        toks = jnp.asarray(self.cur[:, None], jnp.int32)
        pos = jnp.asarray(self.pos, jnp.int32)
        if self.cache_kind == "paged":
            self._sync_block_tables()
            live = np.zeros((self.B,), np.int32)
            live[ready] = 1         # masked rows write to the null page
            logits, self.cache = self._decode(self.params, self.cache,
                                              toks, pos, self._bt_dev,
                                              jnp.asarray(live),
                                              self._null_row)
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              toks, pos)
        logits.block_until_ready()
        self.stats["decode_s"] += time.time() - t0
        self.stats["ticks"] += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for slot in ready:
            e = self.sched.running[slot]
            self.stats["tokens"] += 1
            tok = int(nxt[slot])
            e.req.out.append(tok)
            self.pos[slot] += 1
            self.cur[slot] = tok
            hit_eos = e.req.eos is not None and tok == e.req.eos
            if (len(e.req.out) >= e.req.max_new_tokens or hit_eos
                    or self.pos[slot] >= self._seq_cap() - 1):
                self._finish(e)

    # ---------------- speculative decode ----------------
    def _spec_decode_tick(self):
        """Propose -> verify -> accept. The draft proposes up to k
        tokens per ready sequence (k draft decode steps; draft KV lands
        speculatively at pos..pos+k-1), then ONE batched target pass
        scores the k+1 positions [cur, draft...] with causal masking —
        and, crucially, overwrites every speculatively-written K/V slot
        with the target's own K/V, which is what makes greedy
        speculative decode token-identical to target-only decode for
        ANY draft. Acceptance takes the longest draft prefix the target
        agrees with plus the target's token at the first disagreement
        (or the bonus token after full acceptance); rejected tokens
        roll back by truncating pos and unref'ing whole pages past the
        accept point (kv.truncate) — stale K/V inside the kept tail
        page is masked by context length and overwritten by the next
        write, exactly like any partial tail page."""
        k = self.speculate
        cap = self._seq_cap()
        ready = self._decode_ready()
        if not ready:
            return
        k_eff = {}
        grown = []
        for slot in ready:
            if slot not in self.sched.running:
                continue    # evicted while growing an earlier slot
            p = int(self.pos[slot])
            # clamp speculation depth at the sequence capacity: the
            # verify pass writes k_eff+1 positions starting at pos
            ke = min(k, cap - 1 - p)
            ok, copies = self.sched.ensure_write_capacity(
                slot, p, p + ke + 1)
            if ok:
                self._apply_copies(copies)
                k_eff[slot] = ke
                grown.append(slot)
        ready = [s for s in grown if s in self.sched.running]
        if not ready:
            return
        t0 = time.time()
        self._sync_block_tables()
        base_pos = self.pos.copy()

        # ---- propose: ONE fused k-step draft pass (on-device argmax
        # feedback loop, models/model.py:draft_propose_paged) instead of
        # k host round-trips — the per-step dispatch + transfer overhead
        # used to dominate the tick and cancel the speculation gain.
        # Rows whose clamped depth is exhausted (k_eff <= j) write to
        # their shard's null page at position 0, like any inactive row.
        ke_arr = np.zeros((self.B,), np.int32)
        for s in ready:
            ke_arr[s] = k_eff[s]
        dt_dev, self.cache = self._draft_propose(
            self.draft_params, self.cache,
            jnp.asarray(self.cur, jnp.int32),
            jnp.asarray(base_pos, jnp.int32), self._bt_dev,
            jnp.asarray(ke_arr), self._null_row, k)

        # ---- verify: one batched target pass over k+1 positions; the
        # verify tokens are assembled on device so draft tokens never
        # round-trip through the host before verify is dispatched
        verify_toks = jnp.concatenate(
            [jnp.asarray(self.cur[:, None], jnp.int32), dt_dev], axis=1)
        live = np.zeros((self.B,), np.int32)
        live[ready] = 1
        n_valid = np.zeros((self.B,), np.int32)
        for s in ready:
            n_valid[s] = k_eff[s] + 1
        logits_all, self.cache = self._verify(
            self.params, self.cache, verify_toks,
            jnp.asarray(np.where(live > 0, base_pos, 0), jnp.int32),
            self._bt_dev, jnp.asarray(n_valid), jnp.asarray(live),
            self._null_row)
        draft_toks = np.asarray(dt_dev)                        # (B, k)
        greedy = np.asarray(jnp.argmax(logits_all, axis=-1))   # (B, k+1)
        probs = (np.asarray(jax.nn.softmax(logits_all, axis=-1))
                 if self.accept_rule == "typical" else None)
        self.stats["decode_s"] += time.time() - t0
        self.stats["ticks"] += 1

        # ---- accept
        for slot in ready:
            e = self.sched.running[slot]
            ke = k_eff[slot]
            dt, g = draft_toks[slot], greedy[slot]
            self.stats["draft_tokens"] += ke
            m = 0
            if probs is not None:
                # typical acceptance: keep a draft token the target
                # gives at least typical_tau of its own argmax mass
                while m < ke:
                    pm = probs[slot, m]
                    if pm[dt[m]] < self.typical_tau * pm.max():
                        break
                    m += 1
            else:
                while m < ke and dt[m] == g[m]:
                    m += 1
            self.stats["accepted_tokens"] += m
            # accepted draft prefix + the target's token at position m
            # (correction on mismatch, bonus after full acceptance) —
            # emitted one by one under the vanilla stop conditions
            burst = [int(dt[j]) for j in range(m)] + [int(g[m])]
            emitted, fin = 0, False
            for tok in burst:
                e.req.out.append(tok)
                self.stats["tokens"] += 1
                emitted += 1
                hit_eos = e.req.eos is not None and tok == e.req.eos
                if (len(e.req.out) >= e.req.max_new_tokens or hit_eos
                        or int(base_pos[slot]) + emitted >= cap - 1):
                    fin = True
                    break
            new_pos = int(base_pos[slot]) + emitted
            self.pos[slot] = new_pos
            self.cur[slot] = burst[emitted - 1]
            # rollback: KV is cached for [0, new_pos); whole pages past
            # that point return to the pool (or to their other readers)
            self.kv.truncate(slot, new_pos)
            if fin:
                self._finish(e)

    # ---------------- engine ----------------
    def _seq_cap(self) -> int:
        """Per-sequence token capacity: max_len, further bounded by what
        one page-pool shard can ever hold for one sequence — sequences
        truncate here (like dense at max_len) instead of outgrowing the
        pool (a sequence's pages all come from its slot's shard)."""
        if self.cache_kind == "dense":
            return self.max_len
        return min(self.max_len,
                   self.kv.usable_in_shard(0) * self.page_size)

    def run(self, requests: list[Request]):
        cap = self._seq_cap()
        # validate the whole batch BEFORE submitting anything: a rejected
        # request must not leave earlier ones queued in the scheduler
        for r in requests:
            if len(r.prompt) >= cap:
                raise ValueError(
                    f"prompt of {len(r.prompt)} tokens cannot fit the "
                    f"engine capacity of {cap} tokens")
            if self.cache_kind == "paged":
                # same arithmetic as the admission gate (with sharing
                # counted as zero — it is best-effort), so an unservable
                # request is rejected here instead of crashing mid-run
                need = self.sched.admission_need(len(r.prompt))
                if need > self.kv.usable_in_shard(0):
                    raise ValueError(
                        f"prompt of {len(r.prompt)} tokens needs {need} "
                        f"pages (incl. watermark) but a pool shard only "
                        f"has {self.kv.usable_in_shard(0)}")
        for r in requests:
            self.sched.submit(r)
        self._entries = list(self.sched.waiting)
        with self._mesh_ctx():
            while self.sched.has_work():
                while True:
                    e = self.sched.try_admit()
                    if e is None:
                        break
                    self._admit(e)
                if self.cache_kind == "paged" and self.prefill_chunk:
                    self._prefill_tick()
                self._decode_tick()
        self.stats.update(self.sched.metrics_summary(self._entries))
        return requests
