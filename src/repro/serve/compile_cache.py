"""Process-wide cache of the serving jit wrappers, keyed by
(wrapper kind, model config, mesh fingerprint).

Before this existed every ServeEngine built its own `jax.jit` closures,
so each engine owned a private XLA compilation cache: N engines (or N
constructions of the same engine after a restart of the serving loop)
paid N warmups for byte-identical programs. Engines now *borrow* the
jitted callable from here — the first engine traces and compiles, every
later engine with the same config and mesh reuses the compiled steps
outright (`jax.jit` keys executables by argument shapes/shardings, so
distinct batch shapes still compile independently inside one entry).

Keying rules:
  - `cfg` is the frozen ModelConfig (hashable); wrappers close over it,
    so it must be part of the key. Pass None for config-independent
    wrappers (page copies, block-table scatter).
  - the mesh participates via `mesh_fingerprint` (axis names, shape and
    device ids): traces capture sharding constraints from the active
    mesh context, so callables are never shared across meshes. None
    (single-device serving) is its own key.

`stats()` exposes hit/miss counters; tests assert that constructing a
second engine adds zero entries and that its runs add zero XLA
compilations (`jitted._cache_size()` is flat).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import (copy_pages, decode_step, decode_step_paged,
                                draft_propose_paged, extend_paged, forward,
                                prefill, scatter_prefill_cache, verify_paged)

_CACHE: dict = {}
_STATS = {"hits": 0, "misses": 0}


def mesh_fingerprint(mesh):
    """Hashable identity of a mesh: axis names, shape, device ids."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), tuple(np.shape(mesh.devices)),
            tuple(int(d.id) for d in np.ravel(mesh.devices)))


def _build(kind, cfg):
    if kind == "decode_dense":
        return jax.jit(lambda p, c, t, s: decode_step(cfg, p, c, t, s),
                       donate_argnums=(1,))
    if kind == "decode_paged":
        def step(p, c, t, s, bt, live, null_row):
            # masked (inactive) rows write to their shard's reserve page
            # instead of block-table garbage; null_row is all zeros for
            # unsharded pools (the classic `bt * live` null-page trick)
            bt = jnp.where(live[:, None] > 0, bt, null_row[:, None])
            return decode_step_paged(cfg, p, c, t, s, bt)
        return jax.jit(step, donate_argnums=(1,))
    if kind == "prefill":
        return jax.jit(
            lambda p, t, lp, ml: prefill(cfg, p, t, ml, last_pos=lp),
            static_argnums=(3,))
    if kind == "extend_paged":
        return jax.jit(
            lambda p, c, t, sp, bt, nv: extend_paged(cfg, p, c, t, sp,
                                                     bt, nv),
            donate_argnums=(1,))
    if kind == "draft_propose":
        # the draft's k-step propose pass (fused argmax feedback loop;
        # see models/model.py:draft_propose_paged). A separate kind from
        # decode_paged keeps warmup/hit accounting per role honest; the
        # draft params' smaller alpha shapes would key separate
        # executables anyway. k (the unroll depth) is static — one
        # executable per distinct speculation depth.
        def propose(p, c, cur, sp, bt, ke, null_row, k):
            return draft_propose_paged(cfg, p, c, cur, sp, bt, ke,
                                       null_row, k)
        return jax.jit(propose, donate_argnums=(1,), static_argnums=(7,))
    if kind == "verify_paged":
        # speculative verify: k+1 positions in one pass, logits kept at
        # EVERY position (k is keyed implicitly by the token width —
        # jax.jit compiles one executable per distinct k+1)
        def verify_step(p, c, t, sp, bt, nv, live, null_row):
            bt = jnp.where(live[:, None] > 0, bt, null_row[:, None])
            return verify_paged(cfg, p, c, t, sp, bt, nv)
        return jax.jit(verify_step, donate_argnums=(1,))
    if kind == "scatter_prefill":
        return jax.jit(
            lambda c, r, sl, pi, nv: scatter_prefill_cache(cfg, c, r, sl,
                                                           pi, nv),
            donate_argnums=(0,))
    if kind == "copy_pages":
        return jax.jit(copy_pages, donate_argnums=(0,),
                       static_argnums=(3,))
    if kind == "bt_update":
        return jax.jit(lambda bt, idx, rows: bt.at[idx].set(rows),
                       donate_argnums=(0,))
    if kind == "eval_forward":
        # logits-only forward for perplexity eval (data/evaluate.py):
        # repeated evals of the same config — the GPTQ sweeps run dozens
        # — share one trace instead of re-jitting per perplexity() call
        return jax.jit(lambda p, x: forward(cfg, p, x)[0])
    raise KeyError(kind)


def get(kind: str, cfg=None, mesh=None):
    """The shared jitted wrapper for (kind, cfg, mesh) — built on first
    request, borrowed ever after."""
    key = (kind, cfg, mesh_fingerprint(mesh))
    fn = _CACHE.get(key)
    if fn is None:
        fn = _CACHE[key] = _build(kind, cfg)
        _STATS["misses"] += 1
    else:
        _STATS["hits"] += 1
    return fn


def stats() -> dict:
    return {"entries": len(_CACHE), **_STATS}


def clear() -> None:
    """Drop every cached wrapper (tests isolating warmup accounting)."""
    _CACHE.clear()
    _STATS.update(hits=0, misses=0)
