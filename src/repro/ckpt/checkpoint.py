"""Fault-tolerant checkpointing (no orbax in this environment).

Layout:  <dir>/step_<N>/arrays.npz + meta.json + COMMITTED
Crash-safety: everything is written into step_<N>.tmp and atomically
renamed; the COMMITTED marker is written (and fsynced) last, so a crash
mid-save leaves the previous checkpoint as the restore target. Saves can
run on a background thread (async_save); keep_n garbage-collection prunes
old steps. Restores are mesh-agnostic — arrays are stored unsharded, so a
restart may use a different data-parallel size (elastic rescale) and
reshard on load via the usual sharding rules.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}


def _unflatten_into(template, arrays):
    import jax.numpy as jnp
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        a = arrays[key]
        if tuple(a.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint shape {a.shape} != "
                             f"expected {leaf.shape}")
        # return jax arrays: downstream code (calibration taps, jit
        # donation) relies on leaves being jax.Array
        leaves.append(jnp.asarray(a.astype(leaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory, keep_n: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # ---------------- save ----------------
    def save(self, step: int, tree, metadata: dict | None = None,
             block: bool = False):
        # snapshot to host memory synchronously (cheap), write async
        arrays = _flatten(tree)
        meta = {"step": int(step), "time": time.time(),
                **(metadata or {})}
        self.wait()   # never two writers (async then sync same step)
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, arrays, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, arrays, meta)

    def _write(self, step, arrays, meta):
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **arrays)
        (tmp / "meta.json").write_text(json.dumps(meta))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        commit = final / "COMMITTED"
        with open(commit, "w") as f:
            f.write(str(meta["step"]))
            f.flush()
            os.fsync(f.fileno())
        self._gc()

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------- restore ----------------
    def committed_steps(self):
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if p.suffix == ".tmp" or not (p / "COMMITTED").exists():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return out

    def latest_step(self):
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None):
        """-> (tree matching template, metadata). template supplies
        structure/shapes/dtypes (e.g. freshly-initialized state)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        arrays = dict(np.load(path / "arrays.npz"))
        meta = json.loads((path / "meta.json").read_text())
        return _unflatten_into(template, arrays), meta
