from repro.ckpt.checkpoint import CheckpointManager
from repro.ckpt.packed import load_draft_scales, load_packed, save_packed

__all__ = ["CheckpointManager", "save_packed", "load_packed",
           "load_draft_scales"]
