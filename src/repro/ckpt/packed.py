"""Packed-quantized model artifacts: save/load a param tree whose leaves
are plain arrays and/or QuantizedTensors, plus the QuantSpec that
produced it — so serving boots a quantized model without re-running
calibration or the GPTQ solves.

Layout:  <dir>/arrays.npz + manifest.json + COMMITTED

The manifest mirrors the (nested-dict) param tree; each leaf entry is
either {"kind": "array", "key", "dtype"} or {"kind": "qt", codes/alphas/
betas keys + k_in + orig_dtype}, where keys index arrays.npz. Arrays are
stored verbatim (codes are uint32 bitplanes, alphas/betas fp32, dense
leaves at their own dtype), so a save -> load round trip is bit-exact —
the round-trip test serves both trees and checks token-identical output.

Crash-safety follows repro.ckpt.checkpoint: everything is written into
<dir>.tmp, atomically renamed, and a fsynced COMMITTED marker lands
last, so a crash mid-save never leaves a half-written artifact that
load_packed would accept.
"""
from __future__ import annotations

import json
import os
import shutil
import warnings
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.quant.qlinear import QuantizedTensor
from repro.quant.spec import QuantSpec

FORMAT_VERSION = 2

# one warning per process for legacy per-channel artifacts loaded under
# a spec that asks for group-wise scales
_WARNED_LEGACY_GROUPS = False


def _encode(tree, arrays: dict):
    """Nested dict tree -> manifest node; arrays collected by key."""
    if isinstance(tree, dict):
        return {k: _encode(v, arrays) for k, v in tree.items()}
    if isinstance(tree, QuantizedTensor):
        ent = {"kind": "qt", "k_in": tree.k_in,
               "orig_dtype": tree.orig_dtype,
               # the scale-group axis is explicit in the manifest (not
               # just implied by array shapes) so readers can reason
               # about grouping without touching arrays.npz
               "groups": int(tree.n_groups),
               "group_size": int(tree.group_size)}
        for field in ("codes", "alphas", "betas"):
            key = f"a{len(arrays)}"
            arrays[key] = np.asarray(getattr(tree, field))
            ent[field] = key
        return ent
    key = f"a{len(arrays)}"
    arr = np.asarray(tree)
    dt = str(arr.dtype)
    # npz has no bfloat16: store the raw bits, restore via view on load
    arrays[key] = arr.view(np.uint16) if dt == "bfloat16" else arr
    return {"kind": "array", "key": key, "dtype": dt}


def _decode(node, arrays):
    if "kind" not in node or not isinstance(node.get("kind"), str):
        return {k: _decode(v, arrays) for k, v in node.items()}
    if node["kind"] == "qt":
        alphas = jnp.asarray(arrays[node["alphas"]])
        if "groups" in node and alphas.shape[-3] != node["groups"]:
            raise ValueError(
                f"corrupt packed artifact: manifest says {node['groups']} "
                f"scale groups but alphas have shape {alphas.shape}")
        return QuantizedTensor(
            codes=jnp.asarray(arrays[node["codes"]]),
            alphas=alphas,
            betas=jnp.asarray(arrays[node["betas"]]),
            k_in=node["k_in"], orig_dtype=node["orig_dtype"])
    arr = jnp.asarray(arrays[node["key"]])
    if node["dtype"] == "bfloat16":
        arr = arr.view(jnp.bfloat16)
    return arr


def save_packed(directory, params, *, spec: QuantSpec | None = None,
                meta: dict | None = None) -> Path:
    """Write a packed model artifact; returns the final directory."""
    final = Path(directory)
    tmp = final.with_name(final.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    arrays: dict = {}
    manifest = {
        "format_version": FORMAT_VERSION,
        "spec": spec.to_dict() if spec is not None else None,
        "meta": meta or {},
        "tree": _encode(params, arrays),
    }
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    commit = final / "COMMITTED"
    with open(commit, "w") as f:
        f.write(str(FORMAT_VERSION))
        f.flush()
        os.fsync(f.fileno())
    return final


def load_packed(directory):
    """-> (params tree, QuantSpec or None, meta dict). Bit-exact inverse
    of save_packed; refuses uncommitted (crashed mid-save) artifacts."""
    d = Path(directory)
    if not (d / "COMMITTED").exists():
        raise FileNotFoundError(
            f"{d} is not a committed packed artifact (missing COMMITTED)")
    manifest = json.loads((d / "manifest.json").read_text())
    if manifest["format_version"] > FORMAT_VERSION:
        raise ValueError(
            f"packed artifact format {manifest['format_version']} is newer "
            f"than this code ({FORMAT_VERSION})")
    arrays = dict(np.load(d / "arrays.npz"))
    params = _decode(manifest["tree"], arrays)
    spec = (QuantSpec.from_dict(manifest["spec"])
            if manifest.get("spec") else None)
    _warn_legacy_groups(d, params, spec)
    return params, spec, manifest.get("meta", {})


def _warn_legacy_groups(d, params, spec) -> None:
    """One-time warning: the artifact's spec asks for group-wise scales
    but its QuantizedTensor leaves are per-channel (G=1) — it predates
    group-wise solvers (group_size was carried in the spec but silently
    dropped). Re-quantize to actually get per-group scales."""
    global _WARNED_LEGACY_GROUPS
    if _WARNED_LEGACY_GROUPS or spec is None or spec.group_size <= 0:
        return
    import jax
    legacy = [
        leaf for leaf in jax.tree.leaves(
            params, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        if isinstance(leaf, QuantizedTensor)
        and leaf.n_groups == 1 and leaf.k_in > spec.group_size]
    if legacy:
        _WARNED_LEGACY_GROUPS = True
        warnings.warn(
            f"packed artifact {d} requests group_size="
            f"{spec.group_size} in its spec but {len(legacy)} quantized "
            f"leaves carry per-channel (G=1) scales — it was written "
            f"before group-wise solvers existed; re-quantize and re-save "
            f"to get true per-group scales", UserWarning, stacklevel=3)
