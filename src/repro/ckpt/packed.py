"""Packed-quantized model artifacts: save/load a param tree whose leaves
are plain arrays and/or QuantizedTensors, plus the QuantSpec that
produced it — so serving boots a quantized model without re-running
calibration or the GPTQ solves.

Layout:  <dir>/arrays.npz + manifest.json + COMMITTED

The manifest mirrors the (nested-dict) param tree; each leaf entry is
either {"kind": "array", "key", "dtype"} or {"kind": "qt", codes/alphas/
betas keys + k_in + orig_dtype + groups/group_size}, where keys index
arrays.npz. Arrays are stored verbatim (codes are uint32 bitplanes,
alphas/betas fp32 — or bf16 bits under `scale_dtype="bfloat16"` —
dense leaves at their own dtype), so a save -> load round trip is
bit-exact at the stored precision — the round-trip test serves both
trees and checks token-identical output.

Format history (manifest["format_version"], loaders accept <= current):
  v1 (PR 3)  — tree + arrays + spec; per-channel scales only.
  v2 (PR 4)  — qt leaves record groups/group_size (G-axis scales).
  v3 (PR 5)  — "sharding" block (symbolic mesh axes) + per-leaf
               symbolic PartitionSpecs, so `load_packed(mesh=...)`
               places every leaf straight onto a jax.sharding mesh with
               no host-side full-tree materialization; optional
               `scale_dtype="bfloat16"` halves alpha/beta bytes
               (manifest-flagged; fp32 artifacts load unchanged).
  v4 (this)  — optional per-leaf "draft" block: offline re-fit scales
               for a `draft_bits` prefix of the code planes
               (quant/draft.py), read back by `load_draft_scales` so a
               self-speculative boot skips the on-the-fly LS refit.
               Also: bf16-stored scales now STAY bf16 in memory (the
               kernels expand them in fp32 in VMEM); pre-v4 loads
               rehydrated them to fp32.

Sharding metadata is *symbolic* — axis names from dist.sharding's rules
with no sizes — so one artifact serves any mesh shape: at load the spec
is re-guarded against the real mesh (`guard_pspec` drops an axis when
the dim doesn't divide it) and, by default, the "data" axis is dropped
from weight leaves (serving replicates weights across data-parallel
shards; pass fsdp=True to keep FSDP-style K-dim sharding). v1/v2
artifacts carry no specs: with a mesh they load replicated, with a
one-time warning.

Crash-safety follows repro.ckpt.checkpoint: everything is written into
<dir>.tmp, atomically renamed, and a fsynced COMMITTED marker lands
last, so a crash mid-save never leaves a half-written artifact that
load_packed would accept.
"""
from __future__ import annotations

import json
import os
import shutil
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.qlinear import QuantizedTensor
from repro.quant.spec import QuantSpec

FORMAT_VERSION = 4
SCALE_DTYPES = (None, "float32", "bfloat16")

# one warning per process for legacy per-channel artifacts loaded under
# a spec that asks for group-wise scales
_WARNED_LEGACY_GROUPS = False
# one warning per process for pre-v3 artifacts loaded onto a mesh
_WARNED_NO_PSPEC = False


def _symbolic_spec(names, leaf):
    """The leaf's symbolic PartitionSpec (JSON-safe) under the shared
    GSPMD rules — size-free, guarded against the real mesh at load."""
    from repro.dist.sharding import named_pspec, pspec_to_json, symbolic_mesh
    return pspec_to_json(named_pspec(None, list(names), leaf,
                                     symbolic_mesh()))


def _store_scale(arr, arrays: dict, scale_dtype):
    """Collect one alpha/beta array; returns (key, flagged_bf16). bf16
    is stored as raw uint16 bits (npz has no bfloat16 and would degrade
    it to a void dtype). Scales that are ALREADY bf16 (e.g. via
    cast_scales) take this path unconditionally — storing them verbatim
    would commit an artifact load_packed cannot read."""
    key = f"a{len(arrays)}"
    arr = np.asarray(arr)
    bf16 = scale_dtype == "bfloat16" or str(arr.dtype) == "bfloat16"
    arrays[key] = (arr.astype(jnp.bfloat16).view(np.uint16) if bf16
                   else arr)
    return key, bf16


def _encode(tree, arrays: dict, path=(), scale_dtype=None,
            draft_bits=None):
    """Nested dict tree -> manifest node; arrays collected by key."""
    if isinstance(tree, dict):
        return {k: _encode(v, arrays, path + (k,), scale_dtype,
                           draft_bits)
                for k, v in tree.items()}
    if isinstance(tree, QuantizedTensor):
        ent = {"kind": "qt", "k_in": tree.k_in,
               "orig_dtype": tree.orig_dtype,
               # the scale-group axis is explicit in the manifest (not
               # just implied by array shapes) so readers can reason
               # about grouping without touching arrays.npz
               "groups": int(tree.n_groups),
               "group_size": int(tree.group_size),
               "pspec": {f: _symbolic_spec(path + ("." + f,),
                                           getattr(tree, f))
                         for f in ("codes", "alphas", "betas")}}
        key = f"a{len(arrays)}"
        arrays[key] = np.asarray(tree.codes)
        ent["codes"] = key
        for field in ("alphas", "betas"):
            # halve the G-axis scale bytes under scale_dtype="bfloat16"
            key, bf16 = _store_scale(getattr(tree, field), arrays,
                                     scale_dtype)
            if bf16:
                ent["scale_dtype"] = "bfloat16"
            ent[field] = key
        if draft_bits is not None and draft_bits < tree.bits:
            # v4 optional block: offline re-fit scales for the leading
            # draft_bits code planes (quant/draft.py); codes are shared
            # with the target so this is the draft's entire footprint
            from repro.quant.draft import refit_draft_scales
            da, db = refit_draft_scales(tree, draft_bits)
            ka, bf16 = _store_scale(da, arrays, scale_dtype)
            kb, _ = _store_scale(db, arrays, scale_dtype)
            ent["draft"] = {"bits": int(draft_bits),
                            "alphas": ka, "betas": kb}
            if bf16:
                ent["draft"]["scale_dtype"] = "bfloat16"
        return ent
    key = f"a{len(arrays)}"
    arr = np.asarray(tree)
    dt = str(arr.dtype)
    # npz has no bfloat16: store the raw bits, restore via view on load
    arrays[key] = arr.view(np.uint16) if dt == "bfloat16" else arr
    return {"kind": "array", "key": key, "dtype": dt,
            "pspec": _symbolic_spec(path, tree)}


class _Placer:
    """Per-leaf device placement: with a mesh, each array goes straight
    from the (lazily-read) npz member onto its guarded NamedSharding —
    at no point is a fully-materialized host tree plus a device tree
    alive together. Without a mesh this is a plain jnp.asarray."""

    def __init__(self, mesh, fsdp: bool):
        self.mesh = mesh
        self.fsdp = fsdp

    def __call__(self, arr, pspec_json):
        if self.mesh is None:
            return jnp.asarray(arr)
        from jax.sharding import NamedSharding
        from repro.dist.sharding import (drop_axes, guard_pspec,
                                         pspec_from_json)
        from jax.sharding import PartitionSpec as P
        spec = pspec_from_json(pspec_json) if pspec_json is not None else P()
        if not self.fsdp:
            spec = drop_axes(spec, ("data",))
        spec = guard_pspec(np.shape(arr), spec, self.mesh)
        return jax.device_put(arr, NamedSharding(self.mesh, spec))


def _decode(node, arrays, place: _Placer):
    if "kind" not in node or not isinstance(node.get("kind"), str):
        return {k: _decode(v, arrays, place) for k, v in node.items()}
    pspec = node.get("pspec")
    if node["kind"] == "qt":
        def scales(field):
            a = arrays[node[field]]
            if node.get("scale_dtype") == "bfloat16":
                # bf16 scales stay bf16 IN MEMORY (half the resident
                # scale bytes); the matmul kernels and the jnp
                # reference both expand them in fp32, so numerics match
                # the old rehydrate-to-fp32 load path exactly
                a = np.asarray(a).view(jnp.bfloat16)
            return place(a, pspec[field] if pspec else None)
        alphas = scales("alphas")
        if "groups" in node and alphas.shape[-3] != node["groups"]:
            raise ValueError(
                f"corrupt packed artifact: manifest says {node['groups']} "
                f"scale groups but alphas have shape {alphas.shape}")
        return QuantizedTensor(
            codes=place(arrays[node["codes"]],
                        pspec["codes"] if pspec else None),
            alphas=alphas,
            betas=scales("betas"),
            k_in=node["k_in"], orig_dtype=node["orig_dtype"])
    arr = arrays[node["key"]]
    if node["dtype"] == "bfloat16":
        arr = np.asarray(arr).view(jnp.bfloat16)
    return place(arr, pspec)


def save_packed(directory, params, *, spec: QuantSpec | None = None,
                meta: dict | None = None, scale_dtype: str | None = None,
                draft_bits: int | None = None) -> Path:
    """Write a packed model artifact; returns the final directory.
    `scale_dtype="bfloat16"` stores QuantizedTensor alphas/betas as
    bf16 (half the G-axis scale bytes; values round once — parity is
    within bf16 epsilon of the fp32 artifact). `draft_bits=d` also
    stores LS re-fit scales for the leading d code planes of every
    quantized leaf (the v4 optional draft block) so a self-speculative
    boot (`serve --speculate k --draft-bits d`) skips the refit."""
    if scale_dtype not in SCALE_DTYPES:
        raise ValueError(f"scale_dtype={scale_dtype!r}; "
                         f"expected one of {SCALE_DTYPES}")
    if draft_bits is not None and draft_bits < 1:
        raise ValueError(f"draft_bits must be >= 1, got {draft_bits}")
    from repro.dist.sharding import SYMBOLIC_AXES
    final = Path(directory)
    tmp = final.with_name(final.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    arrays: dict = {}
    manifest = {
        "format_version": FORMAT_VERSION,
        "spec": spec.to_dict() if spec is not None else None,
        "meta": meta or {},
        # symbolic axes the per-leaf pspecs refer to; sizes are a load-
        # time property of the real mesh, never baked into the artifact
        "sharding": {"axes": list(SYMBOLIC_AXES),
                     "rule": "repro.dist.sharding.named_pspec"},
        "tree": _encode(params, arrays,
                        scale_dtype=None if scale_dtype == "float32"
                        else scale_dtype,
                        draft_bits=draft_bits),
    }
    if draft_bits is not None:
        manifest["draft_bits"] = int(draft_bits)
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    commit = final / "COMMITTED"
    with open(commit, "w") as f:
        f.write(str(FORMAT_VERSION))
        f.flush()
        os.fsync(f.fileno())
    return final


def load_packed(directory, *, mesh=None, fsdp: bool = False):
    """-> (params tree, QuantSpec or None, meta dict). Bit-exact inverse
    of save_packed (at the stored scale precision); refuses uncommitted
    (crashed mid-save) artifacts.

    With `mesh`, every leaf is placed directly onto its manifest-
    recorded PartitionSpec, guarded against the real mesh — codes,
    alphas and G-axis scale leaves land sharded without a host-side
    gather of the full tree. `fsdp=False` (default) drops the "data"
    axis from weight specs (serving replicates weights over the
    data-parallel shards); `fsdp=True` keeps it (memory-tight boots).
    """
    d = Path(directory)
    if not (d / "COMMITTED").exists():
        raise FileNotFoundError(
            f"{d} is not a committed packed artifact (missing COMMITTED)")
    manifest = json.loads((d / "manifest.json").read_text())
    if manifest["format_version"] > FORMAT_VERSION:
        raise ValueError(
            f"packed artifact format {manifest['format_version']} is newer "
            f"than this code ({FORMAT_VERSION})")
    if mesh is not None and manifest["format_version"] < 3:
        _warn_no_pspec(d, manifest["format_version"])
    # npz members are read lazily, one leaf at a time, as _decode places
    # them — no dict(np.load(...)) bulk materialization
    arrays = np.load(d / "arrays.npz")
    params = _decode(manifest["tree"], arrays, _Placer(mesh, fsdp))
    spec = (QuantSpec.from_dict(manifest["spec"])
            if manifest.get("spec") else None)
    _warn_legacy_groups(d, params, spec)
    return params, spec, manifest.get("meta", {})


def load_draft_scales(directory):
    """Read the v4 draft block: a nested dict mirroring the param tree
    with {"bits", "alphas", "betas"} at quantized-leaf positions, ready
    for quant.draft.make_draft_params(scales_tree=...). Returns None
    when the artifact carries no draft block (pre-v4, or saved without
    `draft_bits`) — callers fall back to the on-the-fly LS refit."""
    d = Path(directory)
    if not (d / "COMMITTED").exists():
        raise FileNotFoundError(
            f"{d} is not a committed packed artifact (missing COMMITTED)")
    manifest = json.loads((d / "manifest.json").read_text())
    arrays = np.load(d / "arrays.npz")
    found = [False]

    def walk(node):
        if "kind" not in node or not isinstance(node.get("kind"), str):
            return {k: walk(v) for k, v in node.items()}
        blk = node.get("draft") if node["kind"] == "qt" else None
        if blk is None:
            return None
        found[0] = True

        def scale(key):
            a = arrays[blk[key]]
            if blk.get("scale_dtype") == "bfloat16":
                a = np.asarray(a).view(jnp.bfloat16)
            return jnp.asarray(a)
        return {"bits": int(blk["bits"]),
                "alphas": scale("alphas"), "betas": scale("betas")}

    tree = walk(manifest["tree"])
    return tree if found[0] else None


def _warn_no_pspec(d, version) -> None:
    """One-time warning: a pre-v3 artifact has no per-leaf specs, so a
    mesh load can only replicate every leaf."""
    global _WARNED_NO_PSPEC
    if _WARNED_NO_PSPEC:
        return
    _WARNED_NO_PSPEC = True
    warnings.warn(
        f"packed artifact {d} is format v{version} (pre-sharding-"
        f"metadata): leaves will be REPLICATED onto the mesh; re-save "
        f"with this code to record per-leaf PartitionSpecs",
        UserWarning, stacklevel=3)


def _warn_legacy_groups(d, params, spec) -> None:
    """One-time warning: the artifact's spec asks for group-wise scales
    but its QuantizedTensor leaves are per-channel (G=1) — it predates
    group-wise solvers (group_size was carried in the spec but silently
    dropped). Re-quantize to actually get per-group scales."""
    global _WARNED_LEGACY_GROUPS
    if _WARNED_LEGACY_GROUPS or spec is None or spec.group_size <= 0:
        return
    legacy = [
        leaf for leaf in jax.tree.leaves(
            params, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        if isinstance(leaf, QuantizedTensor)
        and leaf.n_groups == 1 and leaf.k_in > spec.group_size]
    if legacy:
        _WARNED_LEGACY_GROUPS = True
        warnings.warn(
            f"packed artifact {d} requests group_size="
            f"{spec.group_size} in its spec but {len(legacy)} quantized "
            f"leaves carry per-channel (G=1) scales — it was written "
            f"before group-wise solvers existed; re-quantize and re-save "
            f"to get true per-group scales", UserWarning, stacklevel=3)
