"""AdamW with optional fp32 master weights, implemented natively (no
optax in this environment). The optimizer state mirrors the param tree,
so the same PartitionSpec rules shard it (ZeRO comes for free from the
FSDP `data` axis in the param specs).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_fp32: bool = True     # keep fp32 master copy when params are bf16


def adamw_init(params, cfg: AdamWConfig):
    f32 = lambda t: jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), t)
    state = {"mu": f32(params), "nu": f32(params),
             "step": jnp.zeros((), jnp.int32)}
    if cfg.master_fp32:
        state["master"] = jax.tree.map(
            lambda a: a.astype(jnp.float32), params)
    return state


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(a.astype(jnp.float32)))
                        for a in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    src = state.get("master", params)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        pf = p.astype(jnp.float32)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        pf = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + wd * pf)
        return m, v, pf

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_v = treedef.flatten_up_to(state["nu"])
    flat_p = treedef.flatten_up_to(src)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    mu = treedef.unflatten([o[0] for o in out])
    nu = treedef.unflatten([o[1] for o in out])
    new_f32 = treedef.unflatten([o[2] for o in out])

    tgt_dtypes = jax.tree.leaves(jax.tree.map(lambda a: a.dtype, params))
    new_params = treedef.unflatten([
        a.astype(dt) for a, dt in zip(jax.tree.leaves(new_f32), tgt_dtypes)])
    new_state = {"mu": mu, "nu": nu, "step": step}
    if "master" in state:
        new_state["master"] = new_f32
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
