"""train_step factory: loss -> grads (with remat + microbatch scan and
optional int8 gradient-accumulator compression) -> AdamW update.

Microbatching: the global batch is split into `microbatches` slices and
scanned, accumulating gradients; the fp32 accumulator is optionally
stored as int8 + per-leaf scale with an error-feedback residual
(grad_compress="int8"), which cuts accumulator memory 4x at large scale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.model import loss_fn
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.schedule import cosine_with_warmup


def _quantize_leaf(g):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def make_train_step(cfg, opt_cfg: AdamWConfig, *, microbatches: int = 1,
                    warmup: int = 100, total_steps: int = 10000,
                    grad_compress: str = "none", aux_coef: float = 0.01):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch = {"inputs": (B, S) or (B, S, D), "labels": (B, S)}.
    """

    def grads_of(params, batch):
        def lf(p):
            loss, met = loss_fn(cfg, p, batch, aux_coef=aux_coef)
            return loss, met
        (loss, met), grads = jax.value_and_grad(lf, has_aux=True)(params)
        return loss, met, grads

    def accumulate(params, batch):
        if microbatches == 1:
            return grads_of(params, batch)
        B = batch["inputs"].shape[0]
        mb = microbatches
        assert B % mb == 0, (B, mb)
        resh = lambda a: a.reshape(mb, B // mb, *a.shape[1:])
        micro = jax.tree.map(resh, batch)

        zero_g = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32),
                              params)

        if grad_compress == "int8":
            acc0 = jax.tree.map(
                lambda a: (jnp.zeros(a.shape, jnp.int8),
                           jnp.ones((), jnp.float32)), params,
                is_leaf=lambda x: isinstance(x, jax.Array))
            resid0 = zero_g

            def body(carry, mb_batch):
                acc, resid, loss_sum = carry
                loss, met, g = grads_of(params, mb_batch)
                # dequant + add + requant with error feedback
                def upd(acc_leaf, r, gl):
                    q, s = acc_leaf
                    full = q.astype(jnp.float32) * s + r + gl.astype(jnp.float32)
                    q2, s2 = _quantize_leaf(full)
                    r2 = full - q2.astype(jnp.float32) * s2
                    return (q2, s2), r2
                flat_a = jax.tree.leaves(acc, is_leaf=lambda x: isinstance(x, tuple))
                flat_r, td = jax.tree.flatten(resid)
                flat_g = td.flatten_up_to(g)
                outs = [upd(a, r, gl) for a, r, gl in zip(flat_a, flat_r, flat_g)]
                acc2 = td.unflatten([o[0] for o in outs])
                resid2 = td.unflatten([o[1] for o in outs])
                return (acc2, resid2, loss_sum + loss), None

            (acc, resid, loss_sum), _ = jax.lax.scan(
                body, (acc0, resid0, 0.0), micro)
            grads = jax.tree.map(
                lambda a, r: (a[0].astype(jnp.float32) * a[1] + r) / mb,
                acc, resid, is_leaf=lambda x: isinstance(x, tuple))
            return loss_sum / mb, {}, grads

        def body(carry, mb_batch):
            acc, loss_sum = carry
            loss, met, g = grads_of(params, mb_batch)
            acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
            return (acc, loss_sum + loss), None

        (acc, loss_sum), _ = jax.lax.scan(body, (zero_g, 0.0), micro)
        grads = jax.tree.map(lambda a: a / mb, acc)
        return loss_sum / mb, {}, grads

    def train_step(params, opt_state, batch):
        loss, met, grads = accumulate(params, batch)
        lr_scale = cosine_with_warmup(opt_state["step"], warmup=warmup,
                                      total=total_steps)
        params, opt_state, om = adamw_update(grads, opt_state, params,
                                             opt_cfg, lr_scale)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def init_train_state(cfg, params, opt_cfg: AdamWConfig):
    return adamw_init(params, opt_cfg)
