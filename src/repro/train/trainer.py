"""Training loop with fault tolerance: auto-resume from the latest
committed checkpoint, periodic async saves, preemption-signal handling
(SIGTERM -> checkpoint + clean exit), and straggler detection (per-step
wall-time EWMA; steps slower than `straggler_factor` x EWMA are logged —
at fleet scale this feeds the controller that reschedules the slow host;
here it drives the logging/abort hook).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.models.model import init_params
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


@dataclass
class TrainerConfig:
    steps: int = 200
    ckpt_every: int = 50
    ckpt_dir: str = "artifacts/ckpt"
    keep_n: int = 3
    log_every: int = 10
    microbatches: int = 1
    warmup: int = 20
    straggler_factor: float = 3.0
    seed: int = 0
    opt: AdamWConfig = field(default_factory=AdamWConfig)


class Trainer:
    def __init__(self, cfg, tcfg: TrainerConfig, data_iter, *, dtype=None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.data = data_iter
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep_n=tcfg.keep_n)
        key = jax.random.PRNGKey(tcfg.seed)
        self.params = init_params(cfg, key, dtype=dtype)
        self.opt_state = init_train_state(cfg, self.params, self.tcfg.opt)
        self.step = 0
        self.metrics_log: list = []
        self._preempted = False
        self._step_fn = jax.jit(
            make_train_step(cfg, tcfg.opt, microbatches=tcfg.microbatches,
                            warmup=tcfg.warmup, total_steps=tcfg.steps),
            donate_argnums=(0, 1))

    # ---- fault tolerance ----
    def try_resume(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        state, meta = self.ckpt.restore(
            {"params": self.params, "opt": self.opt_state})
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = meta["step"]
        return True

    def _save(self, block=False):
        self.ckpt.save(self.step, {"params": self.params,
                                   "opt": self.opt_state}, block=block)

    def _on_preempt(self, *_):
        self._preempted = True

    # ---- loop ----
    def run(self):
        resumed = self.try_resume()
        old = signal.signal(signal.SIGTERM, self._on_preempt)
        ewma = None
        stragglers = 0
        try:
            while self.step < self.tcfg.steps and not self._preempted:
                batch = next(self.data)
                t0 = time.time()
                self.params, self.opt_state, m = self._step_fn(
                    self.params, self.opt_state, batch)
                loss = float(m["loss"])  # also blocks until step done
                dt = time.time() - t0
                ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
                if dt > self.tcfg.straggler_factor * ewma and self.step > 5:
                    stragglers += 1
                    print(f"[straggler] step {self.step}: {dt:.2f}s vs "
                          f"EWMA {ewma:.2f}s")
                self.step += 1
                self.metrics_log.append(
                    {"step": self.step, "loss": loss, "sec": dt})
                if self.step % self.tcfg.log_every == 0:
                    print(f"step {self.step:5d} loss {loss:.4f} "
                          f"({dt:.2f}s/step)", flush=True)
                if self.step % self.tcfg.ckpt_every == 0:
                    self._save()
            self._save(block=True)
        finally:
            signal.signal(signal.SIGTERM, old)
            self.ckpt.wait()
        return {"resumed": resumed, "final_step": self.step,
                "stragglers": stragglers,
                "final_loss": self.metrics_log[-1]["loss"]
                if self.metrics_log else float("nan")}
